//! Dense row-major f32 tensors and Gaussian activation tensors.
//!
//! Deliberately minimal (ndarray is not in the offline crate set): shape +
//! contiguous data, with the handful of views/reshapes the operator
//! library needs. The probabilistic activation type [`ProbTensor`] carries
//! the paper's representation discipline — a mean tensor plus either a
//! variance or a second-raw-moment tensor — so the executor can track and
//! convert representations exactly as Section 5 prescribes.

pub mod gaussian;

pub use gaussian::{convert_in_place, ProbTensor, Rep};

use crate::error::{Error, Result};

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Size of dimension `d`.
    pub fn dim(&self, d: usize) -> usize {
        self.shape[d]
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        debug_assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    /// Cols of a 2-D tensor.
    pub fn cols(&self) -> usize {
        debug_assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    /// Row `i` of a 2-D tensor as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    // ---- transforms ------------------------------------------------------

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} -> {:?}",
                self.shape, shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Flatten to 2-D `[rows, everything-else]`.
    pub fn flatten_2d(self) -> Self {
        let rows = self.shape[0];
        let cols = self.data.len() / rows.max(1);
        Self { shape: vec![rows, cols], data: self.data }
    }

    /// Elementwise map into a new tensor.
    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Self {
        Self {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise binary zip into a new tensor; shapes must match.
    pub fn zip<F: Fn(f32, f32) -> f32>(&self, other: &Tensor, f: F) -> Result<Self> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "zip shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(Self {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    /// Squared elements (E[x^2] of a deterministic tensor).
    pub fn squared(&self) -> Self {
        self.map(|x| x * x)
    }

    /// Maximum absolute difference to another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality with absolute + relative tolerance.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Slice of leading `n` rows of a 2-D+ tensor (copy).
    pub fn first_rows(&self, n: usize) -> Tensor {
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor { shape, data: self.data[..n * row].to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 6], (0..12).map(|i| i as f32).collect()).unwrap();
        let r = t.clone().reshape(vec![3, 4]).unwrap();
        assert_eq!(r.shape(), &[3, 4]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![5, 5]).is_err());
    }

    #[test]
    fn row_access() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn zip_and_map() {
        let a = Tensor::from_vec(vec![1., 2., 3.]);
        let b = Tensor::from_vec(vec![10., 20., 30.]);
        let c = a.zip(&b, |x, y| x + y).unwrap();
        assert_eq!(c.data(), &[11., 22., 33.]);
        assert_eq!(a.squared().data(), &[1., 4., 9.]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(vec![1.0, 100.0]);
        let b = Tensor::from_vec(vec![1.0005, 100.05]);
        assert!(a.allclose(&b, 1e-3, 1e-3));
        assert!(!a.allclose(&b, 1e-5, 1e-6));
    }

    #[test]
    fn flatten_2d_works() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.flatten_2d().shape(), &[2, 12]);
    }
}
