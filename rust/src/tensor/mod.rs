//! Dense row-major f32 tensors and Gaussian activation tensors.
//!
//! Deliberately minimal (ndarray is not in the offline crate set): shape +
//! contiguous data, with the handful of views/reshapes the operator
//! library needs. The probabilistic activation type [`ProbTensor`] carries
//! the paper's representation discipline — a mean tensor plus either a
//! variance or a second-raw-moment tensor — so the executor can track and
//! convert representations exactly as Section 5 prescribes.
//!
//! Storage is copy-on-write: a tensor either owns its `Vec<f32>` or
//! borrows an aligned little-endian `<f4` slice out of a shared
//! memory-mapped file ([`Tensor::mapped`]). Reads are uniform (`data()`);
//! any mutation or move-out (`data_mut`, `into_data`, `reshape`) promotes
//! a mapped tensor to an owned copy first, so the rest of the crate never
//! sees the difference. Registry weights stay page-cache resident this
//! way; activations are always owned.

pub mod gaussian;

pub use gaussian::{convert_in_place, ProbTensor, Rep};

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::util::mmap::MappedFile;

#[derive(Clone, Debug)]
enum Storage {
    Owned(Vec<f32>),
    /// A `len`-float window at `byte_off` into a shared mapping. The
    /// constructor guarantees 4-byte alignment, in-bounds extent, and a
    /// little-endian target, so reinterpreting the bytes is sound.
    Mapped {
        region: Arc<MappedFile>,
        byte_off: usize,
        len: usize,
    },
}

/// A dense row-major f32 tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Storage,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Self { shape, data: Storage::Owned(data) })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: Storage::Owned(vec![0.0; n]) }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: Storage::Owned(vec![v; n]) }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data: Storage::Owned(data) }
    }

    /// Zero-copy view into a mapped file: `len = shape.product()` f32
    /// values starting at `byte_off`. Returns `None` when the window is
    /// misaligned, out of bounds, or the target is big-endian — callers
    /// fall back to a copying load in those cases.
    pub fn mapped(
        shape: Vec<usize>,
        region: Arc<MappedFile>,
        byte_off: usize,
    ) -> Option<Self> {
        if !cfg!(target_endian = "little") {
            return None;
        }
        let n: usize = shape.iter().product();
        let end = byte_off.checked_add(n.checked_mul(4)?)?;
        if end > region.len() {
            return None;
        }
        let ptr = region.bytes()[byte_off..].as_ptr();
        if (ptr as usize) % std::mem::align_of::<f32>() != 0 {
            return None;
        }
        Some(Self {
            shape,
            data: Storage::Mapped { region, byte_off, len: n },
        })
    }

    /// Whether this tensor still borrows mmap'd storage (vs owning a Vec).
    pub fn is_mapped(&self) -> bool {
        matches!(self.data, Storage::Mapped { .. })
    }

    /// Promote mapped storage to an owned copy; no-op when already owned.
    fn make_owned(&mut self) {
        if let Storage::Mapped { .. } = self.data {
            self.data = Storage::Owned(self.data_slice().to_vec());
        }
    }

    fn data_slice(&self) -> &[f32] {
        match &self.data {
            Storage::Owned(v) => v,
            Storage::Mapped { region, byte_off, len } => {
                let bytes = &region.bytes()[*byte_off..*byte_off + *len * 4];
                // SAFETY: 4-byte alignment, bounds and little-endian layout
                // were validated by `Tensor::mapped` before this variant
                // could be constructed; the mapping is immutable for its
                // whole lifetime and kept alive by the Arc'd region, so the
                // reborrow as `&[f32]` reads initialized, stable memory.
                unsafe {
                    std::slice::from_raw_parts(bytes.as_ptr() as *const f32, *len)
                }
            }
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Storage::Owned(v) => v.len(),
            Storage::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn data(&self) -> &[f32] {
        self.data_slice()
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        self.make_owned();
        match &mut self.data {
            Storage::Owned(v) => v,
            Storage::Mapped { .. } => unreachable!("make_owned promoted"),
        }
    }

    pub fn into_data(mut self) -> Vec<f32> {
        self.make_owned();
        match self.data {
            Storage::Owned(v) => v,
            Storage::Mapped { .. } => unreachable!("make_owned promoted"),
        }
    }

    /// Size of dimension `d`.
    pub fn dim(&self, d: usize) -> usize {
        self.shape[d]
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        debug_assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    /// Cols of a 2-D tensor.
    pub fn cols(&self) -> usize {
        debug_assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    /// Row `i` of a 2-D tensor as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data_slice()[i * c..(i + 1) * c]
    }

    // ---- transforms ------------------------------------------------------

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} -> {:?}",
                self.shape, shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Flatten to 2-D `[rows, everything-else]`.
    pub fn flatten_2d(mut self) -> Self {
        let rows = self.shape[0];
        let cols = self.len() / rows.max(1);
        self.shape = vec![rows, cols];
        self
    }

    /// Elementwise map into a new tensor.
    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Self {
        Self {
            shape: self.shape.clone(),
            data: Storage::Owned(self.data_slice().iter().map(|&x| f(x)).collect()),
        }
    }

    /// Elementwise binary zip into a new tensor; shapes must match.
    pub fn zip<F: Fn(f32, f32) -> f32>(&self, other: &Tensor, f: F) -> Result<Self> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "zip shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(Self {
            shape: self.shape.clone(),
            data: Storage::Owned(
                self.data_slice()
                    .iter()
                    .zip(other.data_slice())
                    .map(|(&a, &b)| f(a, b))
                    .collect(),
            ),
        })
    }

    /// Squared elements (E[x^2] of a deterministic tensor).
    pub fn squared(&self) -> Self {
        self.map(|x| x * x)
    }

    /// Maximum absolute difference to another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data_slice()
            .iter()
            .zip(other.data_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality with absolute + relative tolerance.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        self.shape == other.shape
            && self
                .data_slice()
                .iter()
                .zip(other.data_slice())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Slice of leading `n` rows of a 2-D+ tensor (copy).
    pub fn first_rows(&self, n: usize) -> Tensor {
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor {
            shape,
            data: Storage::Owned(self.data_slice()[..n * row].to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 6], (0..12).map(|i| i as f32).collect()).unwrap();
        let r = t.clone().reshape(vec![3, 4]).unwrap();
        assert_eq!(r.shape(), &[3, 4]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![5, 5]).is_err());
    }

    #[test]
    fn row_access() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn zip_and_map() {
        let a = Tensor::from_vec(vec![1., 2., 3.]);
        let b = Tensor::from_vec(vec![10., 20., 30.]);
        let c = a.zip(&b, |x, y| x + y).unwrap();
        assert_eq!(c.data(), &[11., 22., 33.]);
        assert_eq!(a.squared().data(), &[1., 4., 9.]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(vec![1.0, 100.0]);
        let b = Tensor::from_vec(vec![1.0005, 100.05]);
        assert!(a.allclose(&b, 1e-3, 1e-3));
        assert!(!a.allclose(&b, 1e-5, 1e-6));
    }

    #[test]
    fn flatten_2d_works() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.flatten_2d().shape(), &[2, 12]);
    }

    // ---- copy-on-write / mapped storage ---------------------------------

    fn mapped_fixture(vals: &[f32]) -> (Arc<MappedFile>, std::path::PathBuf) {
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let path = std::env::temp_dir()
            .join(format!("pfp_tensor_map_{}_{}.bin", std::process::id(), vals.len()));
        std::fs::write(&path, &bytes).unwrap();
        (Arc::new(MappedFile::open(&path).unwrap()), path)
    }

    #[test]
    fn mapped_tensor_reads_and_promotes() {
        let vals = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let (region, path) = mapped_fixture(&vals);
        let t = Tensor::mapped(vec![2, 3], region.clone(), 0).unwrap();
        assert!(t.is_mapped() || !region.is_mapped() || cfg!(not(target_endian = "little")));
        assert_eq!(t.data(), &vals[..]);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);

        // mutation promotes to owned without touching the mapping
        let mut m = t.clone();
        m.data_mut()[0] = 99.0;
        assert!(!m.is_mapped());
        assert_eq!(t.data()[0], 1.0);
        assert_eq!(m.into_data()[0], 99.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapped_tensor_equals_owned_twin() {
        let vals = [0.5f32, -1.5, 2.25, 8.0];
        let (region, path) = mapped_fixture(&vals);
        let t = Tensor::mapped(vec![4], region, 0).unwrap();
        let owned = Tensor::from_vec(vals.to_vec());
        assert_eq!(t, owned);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapped_rejects_bad_windows() {
        let vals = [1.0f32, 2.0];
        let (region, path) = mapped_fixture(&vals);
        // out of bounds
        assert!(Tensor::mapped(vec![3], region.clone(), 0).is_none());
        // misaligned offset (1 byte into a page-aligned mapping)
        assert!(Tensor::mapped(vec![1], region, 1).is_none());
        std::fs::remove_file(&path).ok();
    }
}
