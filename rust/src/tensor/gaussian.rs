//! Gaussian activation tensors and the paper's representation discipline.
//!
//! A probabilistic activation is a mean tensor plus an *auxiliary* tensor
//! holding either its **variance** or its **second raw moment** `E[x^2]`
//! (paper Section 5). Compute layers consume E[x^2] and produce variances;
//! activation functions consume variances and produce E[x^2]; max-pool
//! consumes and produces variances. [`ProbTensor::to_rep`] performs the
//! `E[x^2] = mu^2 + var` conversions exactly where the layers disagree —
//! conversions cost real time (Fig. 6's "tooling"), so the executor counts
//! them.

use super::Tensor;

/// In-place representation conversion over raw moment slices: rewrites
/// `aux` from `from` to `to` given the mean values. This is the
/// allocation-free core the compiled plan's explicit conversion steps run
/// on; [`ProbTensor::to_rep`] is the tensor-level wrapper.
pub fn convert_in_place(mu: &[f32], aux: &mut [f32], from: Rep, to: Rep) {
    debug_assert_eq!(mu.len(), aux.len());
    match (from, to) {
        (Rep::Var, Rep::E2) => {
            // E[x^2] = mu^2 + var
            for (a, &m) in aux.iter_mut().zip(mu) {
                *a += m * m;
            }
        }
        (Rep::E2, Rep::Var) => {
            // var = max(E[x^2] - mu^2, 0)
            for (a, &m) in aux.iter_mut().zip(mu) {
                *a = (*a - m * m).max(0.0);
            }
        }
        _ => {}
    }
}

/// Which moment the auxiliary tensor holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rep {
    /// aux = Var[x]
    Var,
    /// aux = E[x^2]
    E2,
}

/// Mean + (variance | second-raw-moment) activation pair.
#[derive(Clone, Debug)]
pub struct ProbTensor {
    pub mu: Tensor,
    pub aux: Tensor,
    pub rep: Rep,
}

impl ProbTensor {
    pub fn new(mu: Tensor, aux: Tensor, rep: Rep) -> Self {
        debug_assert_eq!(mu.shape(), aux.shape());
        Self { mu, aux, rep }
    }

    /// A deterministic tensor viewed as zero-variance Gaussian.
    pub fn deterministic(mu: Tensor) -> Self {
        let aux = Tensor::zeros(mu.shape().to_vec());
        Self { mu, aux, rep: Rep::Var }
    }

    pub fn shape(&self) -> &[usize] {
        self.mu.shape()
    }

    /// Convert (in place, consuming) to the requested representation.
    /// Returns `(tensor, converted)` where `converted` reports whether a
    /// conversion pass actually ran (for conversion-cost accounting).
    pub fn to_rep(mut self, rep: Rep) -> (Self, bool) {
        if self.rep == rep {
            return (self, false);
        }
        let from = self.rep;
        // the two moment tensors are separate allocations, so the aux
        // rewrite can borrow mu immutably
        let Self { mu, aux, .. } = &mut self;
        convert_in_place(mu.data(), aux.data_mut(), from, rep);
        self.rep = rep;
        (self, true)
    }

    /// Variance view (converting if needed).
    pub fn into_var(self) -> Self {
        self.to_rep(Rep::Var).0
    }

    /// Reshape both moment tensors.
    pub fn reshape(self, shape: Vec<usize>) -> crate::error::Result<Self> {
        Ok(Self {
            mu: self.mu.reshape(shape.clone())?,
            aux: self.aux.reshape(shape)?,
            rep: self.rep,
        })
    }

    /// Flatten to `[batch, features]`.
    pub fn flatten_2d(self) -> Self {
        Self {
            mu: self.mu.flatten_2d(),
            aux: self.aux.flatten_2d(),
            rep: self.rep,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ProbTensor {
        let mu = Tensor::from_vec(vec![1.0, -2.0, 0.5]);
        let var = Tensor::from_vec(vec![0.25, 1.0, 4.0]);
        ProbTensor::new(mu, var, Rep::Var)
    }

    #[test]
    fn var_to_e2_roundtrip() {
        let p = sample();
        let (e2, conv1) = p.clone().to_rep(Rep::E2);
        assert!(conv1);
        assert_eq!(e2.aux.data(), &[1.25, 5.0, 4.25]);
        let (back, conv2) = e2.to_rep(Rep::Var);
        assert!(conv2);
        let orig = sample();
        assert!(back.aux.allclose(&orig.aux, 1e-6, 1e-6));
    }

    #[test]
    fn same_rep_is_noop() {
        let p = sample();
        let (q, converted) = p.to_rep(Rep::Var);
        assert!(!converted);
        assert_eq!(q.aux.data(), &[0.25, 1.0, 4.0]);
    }

    #[test]
    fn e2_to_var_clamps_negative() {
        let mu = Tensor::from_vec(vec![2.0]);
        let e2 = Tensor::from_vec(vec![3.0]); // < mu^2 -> clamp to 0
        let (v, _) = ProbTensor::new(mu, e2, Rep::E2).to_rep(Rep::Var);
        assert_eq!(v.aux.data(), &[0.0]);
    }

    #[test]
    fn deterministic_has_zero_variance() {
        let p = ProbTensor::deterministic(Tensor::from_vec(vec![3.0, 4.0]));
        assert_eq!(p.rep, Rep::Var);
        assert_eq!(p.aux.data(), &[0.0, 0.0]);
        let (e2, _) = p.to_rep(Rep::E2);
        assert_eq!(e2.aux.data(), &[9.0, 16.0]);
    }
}
