//! `pfp` — CLI entrypoint for the PFP-BNN serving stack.
//!
//! Commands:
//!   info                     inspect artifacts / manifest / metrics
//!   serve                    start the uncertainty-aware inference server
//!   eval                     Table-1 evaluation (accuracy / AUROC) on the
//!                            synthetic Dirty-MNIST test sets
//!   profile                  per-layer latency profile (Table 4 / Fig. 6)
//!   tune                     auto-tune operator schedules, persist records
//!
//! Argument parsing is hand-rolled (clap is not in the offline crate set).

use std::collections::HashMap;

use pfp::coordinator::{Server, ServerConfig, Service, SviBackend, XlaPfpBackend};
use pfp::data::DirtyMnist;
use pfp::model::{Arch, FusePolicy, PfpExecutor, PosteriorWeights, Schedules};
use pfp::runtime::Engine;
use pfp::tensor::Tensor;
use pfp::tuner::{self, SearchSpace, TuningRecords};
use pfp::uncertainty;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, opts) = parse_args(&args);
    let result = match cmd.as_str() {
        "info" => cmd_info(&opts),
        "serve" => cmd_serve(&opts),
        "eval" => cmd_eval(&opts),
        "profile" => cmd_profile(&opts),
        "tune" => cmd_tune(&opts),
        "help" | "" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "pfp — Probabilistic Forward Pass BNN serving\n\
         \n\
         USAGE: pfp <command> [--key value ...]\n\
         \n\
         COMMANDS:\n\
           info                       show artifacts and Table-1 metrics\n\
           serve   [--arch mlp] [--backend native|xla|svi] [--addr 127.0.0.1:7878]\n\
                   [--threads 1] [--plan-threads 0] [--pool-threads 0] [--max-batch 10]\n\
                   [--max-connections 64] [--pipeline-depth 0 (= max-batch)]\n\
                   [--io-threads 2] [--tenant-quota 0] [--outbuf-kb 256]\n\
                   [--write-stall-ms 2000]\n\
                   [--isa scalar|native] [--fuse on|off|auto] [--precision f32|f16|bf16]\n\
                   [--models <dir>] [--memory-budget <MB>] [--no-mmap] [--calib 1.0]\n\
                   (--io-threads sets the fixed reactor thread count that\n\
                    owns every socket; --tenant-quota sheds requests past\n\
                    N in flight per model with an explicit error;\n\
                    --outbuf-kb caps one connection's buffered responses\n\
                    and --write-stall-ms disconnects a peer that stops\n\
                    draining them.)\n\
                   (--plan-threads N partitions the compiled-plan compute/\n\
                    relu/vectorized-pool steps into N tile tasks;\n\
                    0 defers to the tuned schedules. --isa forces every\n\
                    kernel onto one ISA; default: runtime-detected SIMD\n\
                    with scalar fallback, PFP_FORCE_SCALAR=1 honored.\n\
                    --fuse controls epilogue fusion of dense/conv -> ReLU\n\
                    (-> convert) chains into one plan step: on fuses every\n\
                    fusable pattern, off never fuses, auto (default)\n\
                    defers to each layer's tuned `fuse` knob.\n\
                    --precision forces f16/bf16 moment storage on every\n\
                    layer (accumulation stays f32); default: each tuned\n\
                    schedule's own precision knob, f32 when untuned.\n\
                    native backend serves through the model registry:\n\
                    --models preloads every weights_<arch>.npz in <dir>,\n\
                    weights are mmap'd zero-copy (--no-mmap forces the\n\
                    heap loader), --memory-budget caps resident compiled-\n\
                    plan bytes across all models with global LRU eviction,\n\
                    and the admin commands load/swap/unload/models are\n\
                    live on the wire protocol)\n\
           eval    [--arch mlp] [--samples 30]\n\
           profile [--arch mlp] [--batch 10] [--passes 20] [--schedules tuned|baseline]\n\
           tune    [--arch mlp] [--batch 10] [--trials 24] [--plan-threads nproc]\n\
                   [--isa scalar|native] [--fuse on|off|auto] [--precision f32|f16|bf16]\n\
                   (per-layer workload search over parallel x tile-size x\n\
                    ISA x fused-epilogue x storage-precision candidates,\n\
                    measured on the planned tile executor; --isa narrows\n\
                    the ISA dimension to one backend, --fuse on|off pins\n\
                    the fusion knob, --precision pins moment storage to\n\
                    one format)\n"
    );
}

fn parse_args(args: &[String]) -> (String, HashMap<String, String>) {
    let mut opts = HashMap::new();
    let cmd = args.first().cloned().unwrap_or_default();
    let mut i = 1;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_else(|| "true".into());
            opts.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    (cmd, opts)
}

fn opt<'a>(opts: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    opts.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn opt_usize(opts: &HashMap<String, String>, key: &str, default: usize) -> usize {
    opts.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Parse the optional `--fuse on|off|auto` flag; absent = Auto (each
/// bound schedule's tuner-searched `fuse` knob decides per layer).
fn opt_fuse(opts: &HashMap<String, String>) -> pfp::Result<FusePolicy> {
    match opts.get("fuse").map(|s| s.as_str()) {
        None | Some("auto") => Ok(FusePolicy::Auto),
        Some("on") => Ok(FusePolicy::On),
        Some("off") => Ok(FusePolicy::Off),
        Some(s) => Err(pfp::Error::Config(format!(
            "unknown --fuse '{s}' (expected on|off|auto)"
        ))),
    }
}

/// Parse the optional `--precision f32|f16|bf16` flag; absent = None
/// (each bound schedule's own tuner-searched precision knob decides).
fn opt_precision(
    opts: &HashMap<String, String>,
) -> pfp::Result<Option<pfp::util::half::Precision>> {
    match opts.get("precision").map(|s| s.as_str()) {
        None => Ok(None),
        Some(s) => pfp::util::half::Precision::parse(s).map(Some).ok_or_else(|| {
            pfp::Error::Config(format!(
                "unknown --precision '{s}' (expected f32|f16|bf16)"
            ))
        }),
    }
}

/// Parse the optional `--isa scalar|native` flag; absent = None (each
/// schedule's own knob decides, elementwise ops default to native).
fn opt_isa(opts: &HashMap<String, String>) -> pfp::Result<Option<pfp::ops::Isa>> {
    match opts.get("isa").map(|s| s.as_str()) {
        None => Ok(None),
        Some(s) => pfp::ops::Isa::parse(s).map(Some).ok_or_else(|| {
            pfp::Error::Config(format!("unknown --isa '{s}' (expected scalar|native)"))
        }),
    }
}

fn load_arch_weights(arch_name: &str) -> pfp::Result<(Arch, PosteriorWeights, f32)> {
    let dir = pfp::artifacts_dir();
    let arch = Arch::by_name(arch_name)?;
    let manifest = pfp::runtime::Manifest::load(&dir.join("manifest.json"))?;
    let calib = manifest.calibration_factor(arch_name);
    let weights = PosteriorWeights::load(&dir, &arch, calib)?;
    Ok((arch, weights, calib))
}

fn cmd_info(_opts: &HashMap<String, String>) -> pfp::Result<()> {
    let dir = pfp::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    let manifest = pfp::runtime::Manifest::load(&dir.join("manifest.json"))?;
    println!("{} AOT artifacts:", manifest.entries.len());
    for e in &manifest.entries {
        println!(
            "  {:<32} arch={:<6} variant={:<11} batch={:<4} outputs={:?}",
            e.name, e.arch, e.variant, e.batch, e.outputs
        );
    }
    println!("\nTable-1 metrics (python training pipeline):");
    println!("{}", manifest.metrics.dump());
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>) -> pfp::Result<()> {
    let arch_name = opt(opts, "arch", "mlp");
    let backend_kind = opt(opts, "backend", "native");
    let addr = opt(opts, "addr", "127.0.0.1:7878");

    let threads = opt_usize(opts, "threads", 1);
    let mut cfg = ServerConfig::default();
    cfg.addr = addr.to_string();
    cfg.batcher.max_batch = opt_usize(opts, "max-batch", 10);
    // 0 = share the process-wide pool; N = dedicated N-worker service pool
    cfg.pool_threads = opt_usize(opts, "pool-threads", 0);
    // accept-time connection admission limit
    cfg.max_connections = opt_usize(opts, "max-connections", cfg.max_connections);
    // per-connection in-flight window; 0 tracks max-batch so one pipelined
    // client can fill a whole probabilistic forward pass by itself
    cfg.pipeline_depth = opt_usize(opts, "pipeline-depth", 0);
    // reactor IO threads sharing all sockets (thread 0 owns the listener)
    cfg.io_threads = opt_usize(opts, "io-threads", cfg.io_threads);
    // per-model in-flight quota; past it, requests get a load-shed error
    cfg.tenant_quota = opt_usize(opts, "tenant-quota", cfg.tenant_quota);
    // slow-client policy: buffered-output cap and write-stall deadline
    if let Some(kb) = opts.get("outbuf-kb").and_then(|s| s.parse::<usize>().ok()) {
        cfg.max_outbuf_bytes = kb * 1024;
    }
    if let Some(ms) = opts.get("write-stall-ms").and_then(|s| s.parse::<u64>().ok()) {
        cfg.write_stall = std::time::Duration::from_millis(ms);
    }
    let max_batch = cfg.batcher.max_batch;
    let mut svc = Service::new(cfg);
    // every lane dispatches onto the service's one persistent pool, so
    // serving reuses the same workers across models and requests; the
    // tuning records ride along in `Schedules` so the executor re-resolves
    // the per-layer table for each batcher bucket size it cold-compiles
    let records = std::sync::Arc::new(TuningRecords::load_or_default(
        &pfp::artifacts_dir().join("tuning").join("records.json"),
    ));
    // One builder carries every serving knob: plan-time (--plan-threads
    // tile partitioning, --isa pinning) and bind-time (the service pool,
    // the tuning-records handle). Registry lanes clone it per model
    // version and resolve per-batch schedules lazily; static backends
    // resolve it eagerly for their serving shape via build_for.
    let builder = Schedules::builder(threads)
        .pool(svc.pool().clone())
        .plan_threads(opt_usize(opts, "plan-threads", 0))
        .isa_override(opt_isa(opts)?)
        .precision_override(opt_precision(opts)?)
        .fuse(opt_fuse(opts)?)
        .records(Some(records));

    match backend_kind {
        "native" => {
            // native serving goes through the model registry: mmap'd
            // weights, hot swap, and the admin wire commands
            let use_mmap = !opts.contains_key("no-mmap");
            let budget_mb = opt_usize(opts, "memory-budget", 0);
            let budget = (budget_mb > 0).then(|| budget_mb << 20);
            let registry = std::sync::Arc::new(pfp::registry::Registry::new(
                budget,
                use_mmap,
                builder.clone(),
            ));
            let specs = match opts.get("models") {
                Some(dir) => {
                    let calib = opts
                        .get("calib")
                        .and_then(|s| s.parse::<f32>().ok())
                        .unwrap_or(1.0);
                    pfp::registry::scan_models_dir(std::path::Path::new(dir), calib)?
                }
                None => {
                    let dir = pfp::artifacts_dir();
                    let arch = Arch::by_name(arch_name)?;
                    let manifest =
                        pfp::runtime::Manifest::load(&dir.join("manifest.json"))?;
                    vec![pfp::registry::ModelSpec {
                        name: arch_name.to_string(),
                        path: dir.join(format!("weights_{arch_name}.npz")),
                        arch,
                        calib: manifest.calibration_factor(arch_name),
                    }]
                }
            };
            if specs.is_empty() {
                return Err(pfp::Error::Config(
                    "no weights_<arch>.npz archives found to serve".into(),
                ));
            }
            let default_calib = specs[0].calib;
            svc.attach_registry(registry, default_calib);
            for spec in &specs {
                let ack = svc.admin_load(
                    &spec.name,
                    &spec.path.to_string_lossy(),
                    Some(&spec.arch.name),
                    Some(spec.calib as f64),
                )?;
                println!("loaded model: {}", ack.dump());
            }
            match budget {
                Some(b) => println!(
                    "registry: {} model(s), plan memory budget {} MiB",
                    specs.len(),
                    b >> 20
                ),
                None => println!(
                    "registry: {} model(s), no plan memory budget",
                    specs.len()
                ),
            }
        }
        "xla" => {
            let (arch, weights, calib) = load_arch_weights(arch_name)?;
            let engine = Engine::new(&pfp::artifacts_dir())?;
            // leak: engine must outlive the backend worker thread
            let engine: &'static Engine = Box::leak(Box::new(engine));
            let backend = Box::new(XlaPfpBackend::new(engine, arch_name, &weights)?);
            println!("serving {arch_name} (backend=xla, calib={calib}) on {addr}");
            svc.register(arch_name, arch.input_len(), backend);
        }
        "svi" => {
            let (arch, weights, calib) = load_arch_weights(arch_name)?;
            let schedules = builder.clone().build_for(&arch, max_batch);
            let backend = Box::new(SviBackend::new(
                arch.clone(),
                weights,
                schedules,
                opt_usize(opts, "samples", 30),
                0xC0DE,
            ));
            println!("serving {arch_name} (backend=svi, calib={calib}) on {addr}");
            svc.register(arch_name, arch.input_len(), backend);
        }
        other => {
            return Err(pfp::Error::Config(format!("unknown backend '{other}'")));
        }
    }
    println!(
        "pipelining: depth {} per connection, max {} connections",
        svc.pipeline_depth(),
        svc.max_connections()
    );
    let server = Server::bind(std::sync::Arc::new(svc))?;
    println!("listening on {}", server.addr);
    server.run()
}

fn cmd_eval(opts: &HashMap<String, String>) -> pfp::Result<()> {
    let arch_name = opt(opts, "arch", "mlp");
    let samples = opt_usize(opts, "samples", 30);
    let dir = pfp::artifacts_dir();
    let (arch, weights, calib) = load_arch_weights(arch_name)?;
    let data = DirtyMnist::load(&dir)?;
    let mut exec = PfpExecutor::new(arch.clone(), weights, Schedules::tuned(1));

    let mut eval_split = |x: &Tensor| -> uncertainty::Uncertainty {
        let (mu, var) = exec.forward(x);
        uncertainty::pfp_uncertainty(&mu, &var, samples, 42)
    };
    let u_mnist = eval_split(&data.test_mnist.x);
    let u_amb = eval_split(&data.test_ambiguous.x);
    let u_ood = eval_split(&data.test_ood.x);

    let acc = uncertainty::accuracy(&u_mnist.mean_p, arch.num_classes(), &data.test_mnist.y);
    let in_mi: Vec<f64> = u_mnist.mi.iter().chain(&u_amb.mi).cloned().collect();
    let roc = uncertainty::auroc(&u_ood.mi, &in_mi);
    println!("== native PFP evaluation ({arch_name}, calib={calib}) ==");
    println!("accuracy (in-domain): {:.3}", acc);
    println!("AUROC (MI, dirty vs OOD): {:.3}", roc);
    println!(
        "mean MI: mnist={:.3} ambiguous={:.3} ood={:.3}",
        mean(&u_mnist.mi),
        mean(&u_amb.mi),
        mean(&u_ood.mi)
    );
    println!(
        "mean SME: mnist={:.3} ambiguous={:.3} ood={:.3}",
        mean(&u_mnist.sme),
        mean(&u_amb.sme),
        mean(&u_ood.sme)
    );
    Ok(())
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

fn cmd_profile(opts: &HashMap<String, String>) -> pfp::Result<()> {
    let arch_name = opt(opts, "arch", "mlp");
    let batch = opt_usize(opts, "batch", 10);
    let passes = opt_usize(opts, "passes", 20);
    let schedules = match opt(opts, "schedules", "tuned") {
        "baseline" => Schedules::baseline(),
        _ => Schedules::tuned(1),
    };
    let (arch, weights, _) = load_arch_weights(arch_name)?;
    let dir = pfp::artifacts_dir();
    let data = DirtyMnist::load(&dir)?;
    let x = data.test_mnist.x.first_rows(batch);
    let mut exec = PfpExecutor::new(arch, weights, schedules).with_profiling();
    for _ in 0..passes {
        let _ = exec.forward(&x);
    }
    let profile = exec.profiler.take();
    print!("{}", profile.render(&format!("{arch_name} b{batch}")));
    println!("\nper-operator-type shares (Fig. 6):");
    for r in profile.by_op_type() {
        println!(
            "  {:<10} {:>6.1}%  {:>8.3}ms",
            r.label,
            r.fraction * 100.0,
            r.per_pass_ms
        );
    }
    Ok(())
}

fn cmd_tune(opts: &HashMap<String, String>) -> pfp::Result<()> {
    let arch_name = opt(opts, "arch", "mlp");
    let batch = opt_usize(opts, "batch", 10);
    let trials = opt_usize(opts, "trials", 24);
    let (arch, weights, _) = load_arch_weights(arch_name)?;
    let dir = pfp::artifacts_dir();

    // Tune every compute layer on its actual workload shape (the paper
    // tunes per operator workload and per mini-batch size): each layer's
    // best schedule lands in the per-layer table the compiled plans bind.
    // Candidates are measured on the planned tile executor, so the search
    // covers parallel (threads up to --plan-threads) x tile-size points
    // exactly as serving would run them.
    let max_threads =
        opt_usize(opts, "plan-threads", pfp::util::threadpool::default_threads());
    let mut space = SearchSpace::dense_default(max_threads);
    // --isa narrows the search's ISA dimension to one backend (the
    // detector still caps native at whatever the host supports)
    if let Some(isa) = opt_isa(opts)? {
        space.isas = vec![isa];
    }
    // --fuse pins the fused-epilogue dimension; auto (default) keeps both
    // so the search decides per layer whether fusing pays
    match opt_fuse(opts)? {
        FusePolicy::On => space.fuses = vec![true],
        FusePolicy::Off => space.fuses = vec![false],
        FusePolicy::Auto => {}
    }
    // --precision pins the storage-precision dimension to one format;
    // absent keeps all three so the search decides per layer whether
    // halved moment storage pays on this host
    if let Some(p) = opt_precision(opts)? {
        space.precisions = vec![p];
    }
    let topts = tuner::TuneOpts { random_trials: trials, ..Default::default() };
    println!(
        "tuning {arch_name} per layer at batch {batch} \
         ({trials} random trials/layer, up to {max_threads} threads, \
         simd backend: {}) ...",
        pfp::ops::simd::detect().name()
    );
    let layer_results = tuner::tune_per_layer(&arch, &weights, batch, topts, &space);

    let records_path = dir.join("tuning").join("records.json");
    let mut records = TuningRecords::load_or_default(&records_path);
    // heaviest workload per op class ("dense" and "conv" separately):
    // each becomes that class's fallback record
    let mut dominant: HashMap<&str, &tuner::LayerTuneResult> = HashMap::new();
    println!(
        "{:<12} {:<24} {:>10} {:>10} {:>7}  schedule",
        "layer", "workload", "baseline", "best", "speedup"
    );
    for lr in &layer_results {
        let wl = &lr.workload;
        println!(
            "{:<12} {:<24} {:>8.3}ms {:>8.3}ms {:>6.2}x  {}",
            wl.label,
            format!("[{}x{}x{}]", wl.m, wl.k, wl.n),
            lr.result.baseline_ms,
            lr.result.best_ms,
            lr.result.speedup(),
            lr.result.best.tag()
        );
        records.insert(
            TuningRecords::layer_key(wl.op, arch_name, wl.compute_idx, batch),
            lr.result.best,
            lr.result.best_ms,
        );
        let incumbent = dominant.get(wl.op);
        if incumbent.map_or(true, |d| {
            d.workload.m * d.workload.k * d.workload.n < wl.m * wl.k * wl.n
        }) {
            dominant.insert(wl.op, lr);
        }
    }
    for d in dominant.values() {
        records.insert(
            TuningRecords::key(d.workload.op, arch_name, batch),
            d.result.best,
            d.result.best_ms,
        );
    }
    records.save(&records_path)?;
    println!("saved tuning records to {}", records_path.display());
    Ok(())
}
