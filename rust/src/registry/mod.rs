//! Multi-model registry: every servable model, owned in one place.
//!
//! The ROADMAP north star is a fleet serving many BNN posteriors with
//! rolling weight updates and never a gap in uncertainty coverage. This
//! module is that control plane:
//!
//! * **mmap'd weights** — each version's posterior loads through
//!   [`PosteriorWeights::load_mapped`]: aligned `<f4` NPZ members stay
//!   zero-copy views into a shared mapping (page-cache friendly on the
//!   paper's embedded targets), everything else takes the bit-identical
//!   copy fallback;
//! * **versioned atomic cutover** — [`Registry::swap`] publishes a new
//!   [`ModelVersion`] under the model name while in-flight requests keep
//!   the `Arc` they captured at submit time and finish on the old
//!   version; the old executor (and its whole compiled-plan cache) drops
//!   at refcount zero. [`Registry::live_versions`] watches the `Weak`
//!   history so tests can assert the drain;
//! * **one global memory budget** — every version's plan cache carries
//!   globally-comparable LRU stamps (see `PLAN_CLOCK` in the executor),
//!   so [`Registry::enforce_budget`] evicts the least-recently-used
//!   compiled plan *across models* until the resident plan bytes fit.
//!
//! The serving wiring (admin `load`/`unload`/`swap`/`models` commands,
//! per-(model, version) batching) lives in `coordinator::server`; this
//! module is deliberately transport-free.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};

use crate::error::{Error, Result};
use crate::model::{Arch, Executor, PfpExecutor, PosteriorWeights, SchedulesBuilder};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// What one `load`/`swap` asks for.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// registry key (the wire protocol's `model` field)
    pub name: String,
    /// weight archive (`.npz`) path
    pub path: PathBuf,
    pub arch: Arch,
    /// calibration factor applied at load (`w_var = c * sigma^2`)
    pub calib: f32,
}

/// Plan-cache counter movement observed across one inference — what the
/// serving worker publishes to the global metrics as deltas.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanDelta {
    pub compiles: u64,
    pub evictions: u64,
}

/// One immutable published version of a model. Requests capture an
/// `Arc<ModelVersion>` at submit; whichever version they captured serves
/// them, regardless of concurrent swaps.
pub struct ModelVersion {
    pub name: String,
    /// Monotonic per-model version, starting at 1 on `load`.
    pub version: u64,
    pub arch: Arch,
    /// FNV-1a of the weight archive bytes.
    pub checksum: u64,
    /// weight archive this version was loaded from
    pub source: PathBuf,
    /// weights held by a live mmap (vs the heap fallback)
    pub mapped: bool,
    /// NPZ members served zero-copy out of the mapping
    pub zero_copy_members: usize,
    /// NPZ members that took the copy fallback
    pub copied_members: usize,
    /// requests served by this version
    pub requests: AtomicU64,
    exec: Mutex<Box<dyn Executor>>,
}

impl ModelVersion {
    /// Flattened input length this version expects.
    pub fn features(&self) -> usize {
        self.arch.input_len()
    }

    /// One batched inference on this version's executor, returning the
    /// logit moments plus the plan-cache counter deltas it caused.
    pub fn infer(&self, x: &Tensor) -> Result<(Tensor, Tensor, PlanDelta)> {
        let mut exec = self.exec.lock().unwrap();
        let before_c = exec.plan_compiles();
        let before_e = exec.plan_evictions();
        let (mu, var) = exec.forward(x)?;
        let delta = PlanDelta {
            compiles: exec.plan_compiles() - before_c,
            evictions: exec.plan_evictions() - before_e,
        };
        self.requests.fetch_add(x.dim(0) as u64, Ordering::Relaxed);
        Ok((mu, var, delta))
    }

    pub fn plan_compiles(&self) -> u64 {
        self.exec.lock().unwrap().plan_compiles()
    }

    pub fn plan_evictions(&self) -> u64 {
        self.exec.lock().unwrap().plan_evictions()
    }

    pub fn plan_bytes(&self) -> usize {
        self.exec.lock().unwrap().plan_bytes()
    }

    /// Weight tensors this version's cached plans hold as packed
    /// (f16/bf16) copies — 0 whenever serving at the default f32.
    pub fn packed_weight_tensors(&self) -> usize {
        self.exec.lock().unwrap().packed_weight_tensors()
    }

    pub fn cached_batches(&self) -> Vec<usize> {
        self.exec.lock().unwrap().cached_batches()
    }

    /// Non-blocking plan-cache probe: `None` when the lane is mid-infer.
    fn try_probe(&self) -> Option<(usize, Option<(usize, u64)>)> {
        let exec = self.exec.try_lock().ok()?;
        Some((exec.plan_bytes(), exec.lru_plan()))
    }

    fn try_evict(&self, batch: usize) -> bool {
        match self.exec.try_lock() {
            Ok(mut exec) => exec.evict_plan(batch),
            Err(_) => false,
        }
    }

    fn describe(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("version", Json::Num(self.version as f64)),
            ("arch", Json::Str(self.arch.name.clone())),
            ("checksum", Json::Str(format!("{:016x}", self.checksum))),
            ("source", Json::Str(self.source.display().to_string())),
            ("mapped", Json::Bool(self.mapped)),
            ("zero_copy_members", Json::Num(self.zero_copy_members as f64)),
            ("copied_members", Json::Num(self.copied_members as f64)),
            (
                "requests",
                Json::Num(self.requests.load(Ordering::Relaxed) as f64),
            ),
            ("plan_compiles", Json::Num(self.plan_compiles() as f64)),
            ("plan_evictions", Json::Num(self.plan_evictions() as f64)),
            ("plan_bytes", Json::Num(self.plan_bytes() as f64)),
            (
                "packed_weight_tensors",
                Json::Num(self.packed_weight_tensors() as f64),
            ),
            (
                "cached_batches",
                Json::Arr(
                    self.cached_batches()
                        .into_iter()
                        .map(|b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

impl std::fmt::Debug for ModelVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelVersion")
            .field("name", &self.name)
            .field("version", &self.version)
            .field("arch", &self.arch.name)
            .field("checksum", &format_args!("{:016x}", self.checksum))
            .finish()
    }
}

/// One registered model name: the active version plus weak handles to
/// every version ever published under it (drain observability).
struct Slot {
    active: Arc<ModelVersion>,
    history: Vec<Weak<ModelVersion>>,
    next_version: u64,
}

/// The model registry. Interior mutability throughout — the server shares
/// one `Arc<Registry>` between the admin surface, the per-model batch
/// workers, and metrics.
pub struct Registry {
    models: RwLock<HashMap<String, Slot>>,
    /// Global cap on resident compiled-plan bytes across all models
    /// (weights are mmap'd and accounted to the page cache, not here).
    budget_bytes: Option<usize>,
    /// `false` forces the heap weight-loading path (`--no-mmap`).
    use_mmap: bool,
    /// Schedule template every new version's executor is built from.
    schedules: SchedulesBuilder,
    /// Budget-driven evictions performed by [`enforce_budget`]
    /// (per-executor caches count their own cap evictions on top).
    budget_evictions: AtomicU64,
}

impl Registry {
    pub fn new(
        budget_bytes: Option<usize>,
        use_mmap: bool,
        schedules: SchedulesBuilder,
    ) -> Self {
        Self {
            models: RwLock::new(HashMap::new()),
            budget_bytes,
            use_mmap,
            schedules,
            budget_evictions: AtomicU64::new(0),
        }
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    pub fn budget_evictions(&self) -> u64 {
        self.budget_evictions.load(Ordering::Relaxed)
    }

    fn build_version(&self, spec: &ModelSpec, version: u64) -> Result<Arc<ModelVersion>> {
        let loaded = PosteriorWeights::load_mapped(
            &spec.path,
            &spec.arch,
            spec.calib,
            self.use_mmap,
        )?;
        let schedules = self.schedules.clone().build();
        let exec: Box<dyn Executor> = Box::new(PfpExecutor::new(
            spec.arch.clone(),
            loaded.weights,
            schedules,
        ));
        Ok(Arc::new(ModelVersion {
            name: spec.name.clone(),
            version,
            arch: spec.arch.clone(),
            checksum: loaded.checksum,
            source: spec.path.clone(),
            mapped: loaded.mapped,
            zero_copy_members: loaded.zero_copy_members,
            copied_members: loaded.copied_members,
            requests: AtomicU64::new(0),
            exec: Mutex::new(exec),
        }))
    }

    /// Publish a new model under `spec.name` at version 1. Errors if the
    /// name is already registered (that is what [`swap`](Self::swap) is
    /// for).
    pub fn load(&self, spec: &ModelSpec) -> Result<Arc<ModelVersion>> {
        if self.models.read().unwrap().contains_key(&spec.name) {
            return Err(Error::Coordinator(format!(
                "model '{}' already loaded (use swap to replace it)",
                spec.name
            )));
        }
        let version = self.build_version(spec, 1)?;
        let mut models = self.models.write().unwrap();
        // re-check under the write lock (two concurrent loads)
        if models.contains_key(&spec.name) {
            return Err(Error::Coordinator(format!(
                "model '{}' already loaded (use swap to replace it)",
                spec.name
            )));
        }
        models.insert(
            spec.name.clone(),
            Slot {
                active: Arc::clone(&version),
                history: vec![Arc::downgrade(&version)],
                next_version: 2,
            },
        );
        drop(models);
        self.enforce_budget();
        Ok(version)
    }

    /// Atomically publish the next version of an existing model. The
    /// swap is a pointer handoff: requests submitted before it keep (and
    /// are served by) the old `Arc`; requests submitted after it see the
    /// new one; nothing is dropped mid-flight.
    pub fn swap(&self, spec: &ModelSpec) -> Result<Arc<ModelVersion>> {
        let next = {
            let models = self.models.read().unwrap();
            let slot = models.get(&spec.name).ok_or_else(|| {
                Error::Coordinator(format!(
                    "model '{}' not loaded (use load first)",
                    spec.name
                ))
            })?;
            slot.next_version
        };
        // build outside the lock — weight loading and mmap setup must not
        // stall concurrent lookups
        let version = self.build_version(spec, next)?;
        let mut models = self.models.write().unwrap();
        let slot = models.get_mut(&spec.name).ok_or_else(|| {
            Error::Coordinator(format!("model '{}' was unloaded mid-swap", spec.name))
        })?;
        slot.active = Arc::clone(&version);
        slot.next_version = slot.next_version.max(version.version) + 1;
        slot.history.push(Arc::downgrade(&version));
        drop(models);
        self.enforce_budget();
        Ok(version)
    }

    /// Remove a model name. In-flight requests still holding the version
    /// Arc finish normally; the executor and plans free at refcount zero.
    pub fn unload(&self, name: &str) -> Result<()> {
        match self.models.write().unwrap().remove(name) {
            Some(_) => Ok(()),
            None => Err(Error::Coordinator(format!("model '{name}' not loaded"))),
        }
    }

    /// The active version for `name` — the Arc clone *is* the epoch
    /// handoff (callers pin whatever was active when they asked).
    pub fn get(&self, name: &str) -> Option<Arc<ModelVersion>> {
        self.models
            .read()
            .unwrap()
            .get(name)
            .map(|s| Arc::clone(&s.active))
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.models.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Version numbers still alive (reachable by anyone — the registry,
    /// a batcher queue, or an in-flight batch) for `name`, including
    /// versions already swapped out but not yet drained.
    pub fn live_versions(&self, name: &str) -> Vec<u64> {
        let models = self.models.read().unwrap();
        let Some(slot) = models.get(name) else {
            return Vec::new();
        };
        let mut v: Vec<u64> = slot
            .history
            .iter()
            .filter_map(|w| w.upgrade())
            .map(|m| m.version)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Resident compiled-plan bytes across every active version. Skips
    /// (undercounts) lanes that are mid-infer rather than blocking them.
    pub fn total_plan_bytes(&self) -> usize {
        let models = self.models.read().unwrap();
        models
            .values()
            .filter_map(|s| s.active.try_probe())
            .map(|(bytes, _)| bytes)
            .sum()
    }

    /// Evict globally-least-recently-used compiled plans until resident
    /// plan bytes fit the budget. Busy lanes (mid-infer) are skipped via
    /// `try_lock` — eviction never blocks serving; a lane that stays busy
    /// is touching its plan anyway and is exactly not the LRU. Returns
    /// the number of plans evicted.
    pub fn enforce_budget(&self) -> u64 {
        let Some(budget) = self.budget_bytes else {
            return 0;
        };
        let mut evicted = 0u64;
        // bounded pass count: each iteration drops one plan, and the
        // total number of resident plans is finite
        loop {
            let actives: Vec<Arc<ModelVersion>> = {
                let models = self.models.read().unwrap();
                models.values().map(|s| Arc::clone(&s.active)).collect()
            };
            let mut total = 0usize;
            let mut lru: Option<(Arc<ModelVersion>, usize, u64)> = None;
            for mv in &actives {
                let Some((bytes, lru_plan)) = mv.try_probe() else {
                    continue;
                };
                total += bytes;
                if let Some((batch, stamp)) = lru_plan {
                    let older = match &lru {
                        Some((_, _, best)) => stamp < *best,
                        None => true,
                    };
                    if older {
                        lru = Some((Arc::clone(mv), batch, stamp));
                    }
                }
            }
            if total <= budget {
                break;
            }
            let Some((victim, batch, _)) = lru else {
                break; // nothing evictable (all lanes busy)
            };
            if victim.try_evict(batch) {
                evicted += 1;
                self.budget_evictions.fetch_add(1, Ordering::Relaxed);
            } else {
                break; // lane went busy between probe and evict
            }
        }
        evicted
    }

    /// The `models` admin listing: one entry per registered name,
    /// plus the budget headline.
    pub fn models_json(&self) -> Json {
        let entries: Vec<Json> = {
            let models = self.models.read().unwrap();
            let mut names: Vec<&String> = models.keys().collect();
            names.sort();
            names
                .into_iter()
                .map(|n| models[n].active.describe())
                .collect()
        };
        Json::obj(vec![
            ("models", Json::Arr(entries)),
            (
                "memory_budget_bytes",
                match self.budget_bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            ("plan_bytes", Json::Num(self.total_plan_bytes() as f64)),
            (
                "budget_evictions",
                Json::Num(self.budget_evictions() as f64),
            ),
        ])
    }
}

/// Test-only: a published version backed by synthetic weights and no
/// archive on disk (identity-distinct `Arc` per call — what the batcher
/// tests need to exercise version-contiguous draining).
#[cfg(test)]
pub(crate) fn synthetic_version(name: &str, version: u64) -> Arc<ModelVersion> {
    let arch = Arch::mlp();
    let w = PosteriorWeights::synthetic(&arch, version);
    let exec: Box<dyn Executor> = Box::new(PfpExecutor::new(
        arch.clone(),
        w,
        SchedulesBuilder::tuned(1).build(),
    ));
    Arc::new(ModelVersion {
        name: name.to_string(),
        version,
        arch,
        checksum: version,
        source: PathBuf::new(),
        mapped: false,
        zero_copy_members: 0,
        copied_members: 0,
        requests: AtomicU64::new(0),
        exec: Mutex::new(exec),
    })
}

/// Scan a directory for `weights_<arch>.npz` archives and return the
/// specs `pfp serve --models <dir>` should preload. Only known arch
/// names are picked up; the model name is the arch name.
pub fn scan_models_dir(dir: &Path, calib: f32) -> Result<Vec<ModelSpec>> {
    let mut specs = Vec::new();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::Coordinator(format!("read {}: {e}", dir.display())))?;
    for entry in entries {
        let entry = entry.map_err(|e| Error::Coordinator(e.to_string()))?;
        let fname = entry.file_name();
        let fname = fname.to_string_lossy();
        let Some(arch_name) = fname
            .strip_prefix("weights_")
            .and_then(|s| s.strip_suffix(".npz"))
        else {
            continue;
        };
        let Ok(arch) = Arch::by_name(arch_name) else {
            continue; // unknown architecture: not servable, skip
        };
        specs.push(ModelSpec {
            name: arch_name.to_string(),
            path: entry.path(),
            arch,
            calib,
        });
    }
    specs.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    fn write_model(name: &str, seed: u64) -> (ModelSpec, PathBuf) {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, seed);
        let path = std::env::temp_dir().join(format!(
            "pfp_registry_{}_{name}_{seed}.npz",
            std::process::id()
        ));
        w.save_npz(&path).unwrap();
        (
            ModelSpec {
                name: name.to_string(),
                path: path.clone(),
                arch,
                calib: 1.0,
            },
            path,
        )
    }

    fn registry(budget: Option<usize>) -> Registry {
        Registry::new(budget, true, SchedulesBuilder::tuned(1))
    }

    fn input(batch: usize) -> Tensor {
        Tensor::new(vec![batch, 784], vec![0.5; batch * 784]).unwrap()
    }

    #[test]
    fn load_infer_unload_lifecycle() {
        let reg = registry(None);
        let (spec, path) = write_model("m", 40);
        let v = reg.load(&spec).unwrap();
        assert_eq!(v.version, 1);
        assert_eq!(v.features(), 784);
        assert!(v.zero_copy_members > 0);
        assert_eq!(v.copied_members, 0);

        // double load is an error; swap is the way
        assert!(reg.load(&spec).is_err());

        let (mu, var, delta) = v.infer(&input(2)).unwrap();
        assert_eq!(mu.shape(), &[2, 10]);
        assert_eq!(var.shape(), &[2, 10]);
        assert_eq!(delta.compiles, 1, "first batch size is a cold compile");
        assert_eq!(v.requests.load(Ordering::Relaxed), 2);
        assert_eq!(v.packed_weight_tensors(), 0, "f32 serving packs nothing");

        assert_eq!(reg.names(), vec!["m"]);
        reg.unload("m").unwrap();
        assert!(reg.get("m").is_none());
        assert!(reg.unload("m").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn packed_precision_serving_reports_packed_tensors() {
        // a registry built with --precision f16 packs every compiled
        // plan's weights and surfaces the count in the admin metadata
        use crate::util::half::Precision;
        let reg = Registry::new(
            None,
            true,
            SchedulesBuilder::tuned(1).precision_override(Some(Precision::F16)),
        );
        let (spec, path) = write_model("m16", 43);
        let v = reg.load(&spec).unwrap();
        assert_eq!(v.packed_weight_tensors(), 0, "no plan compiled yet");
        let (mu, var, _) = v.infer(&input(2)).unwrap();
        assert!(mu.data().iter().all(|x| x.is_finite()));
        assert!(var.data().iter().all(|&x| x >= 0.0));
        assert_eq!(v.packed_weight_tensors(), 6, "mu + aux per dense layer");
        assert_eq!(
            v.describe().num_field("packed_weight_tensors"),
            Some(6.0)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn swap_bumps_version_and_drops_old_at_refcount_zero() {
        let reg = registry(None);
        let (spec, p1) = write_model("m", 41);
        let v1 = reg.load(&spec).unwrap();
        let _ = v1.infer(&input(1)).unwrap();
        let c1 = v1.checksum;

        let (spec2, p2) = write_model("m", 42);
        assert!(reg.swap(&ModelSpec { name: "other".into(), ..spec2.clone() }).is_err());
        let v2 = reg.swap(&spec2).unwrap();
        assert_eq!(v2.version, 2);
        assert_ne!(v2.checksum, c1, "different weights, different checksum");

        // in-flight holders keep serving on v1 while v2 is active
        assert_eq!(reg.get("m").unwrap().version, 2);
        let (mu_old, _, _) = v1.infer(&input(1)).unwrap();
        assert_eq!(mu_old.shape(), &[1, 10]);
        assert_eq!(reg.live_versions("m"), vec![1, 2]);

        // dropping the last v1 handle frees it (plans included)
        let weak = Arc::downgrade(&v1);
        drop(v1);
        assert!(weak.upgrade().is_none(), "old version must die at refcount zero");
        assert_eq!(reg.live_versions("m"), vec![2]);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn budget_evicts_lru_plans_across_models() {
        // one mlp plan at batch 1 is ~ 4 * (4*hwm) bytes; a tiny budget
        // forces cross-model eviction of the least recently used plan.
        let reg = registry(Some(1)); // 1 byte: nothing fits
        let (spec_a, pa) = write_model("a", 43);
        let (spec_b, pb) = write_model("b", 44);
        let va = reg.load(&spec_a).unwrap();
        let vb = reg.load(&spec_b).unwrap();

        let _ = va.infer(&input(1)).unwrap();
        let _ = vb.infer(&input(1)).unwrap();
        assert!(va.plan_bytes() + vb.plan_bytes() > 0);

        let evicted = reg.enforce_budget();
        assert!(evicted >= 2, "both plans exceed a 1-byte budget, evicted {evicted}");
        assert_eq!(reg.total_plan_bytes(), 0);
        assert!(reg.budget_evictions() >= 2);
        // per-executor eviction counters saw it too
        assert_eq!(va.plan_evictions() + vb.plan_evictions(), evicted);
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
    }

    #[test]
    fn budget_keeps_hot_plan_evicts_cold() {
        let (spec_a, pa) = write_model("a", 45);
        let (spec_b, pb) = write_model("b", 46);
        let reg = registry(None);
        let va = reg.load(&spec_a).unwrap();
        let vb = reg.load(&spec_b).unwrap();
        let _ = va.infer(&input(1)).unwrap();
        let _ = vb.infer(&input(1)).unwrap();
        let _ = vb.infer(&input(1)).unwrap(); // b is hotter (later stamp)
        let one_plan = va.plan_bytes();

        // budget admits exactly one plan: the LRU (a's) must go
        let reg2 = Registry::new(Some(one_plan), true, SchedulesBuilder::tuned(1));
        // rebuild under the budgeted registry to keep the test hermetic
        let (sa, p3) = write_model("a", 45);
        let (sb, p4) = write_model("b", 46);
        let wa = reg2.load(&sa).unwrap();
        let wb = reg2.load(&sb).unwrap();
        let _ = wa.infer(&input(1)).unwrap();
        let _ = wb.infer(&input(1)).unwrap();
        let evicted = reg2.enforce_budget();
        assert_eq!(evicted, 1);
        assert_eq!(wa.cached_batches(), Vec::<usize>::new(), "LRU (a) evicted");
        assert_eq!(wb.cached_batches(), vec![1], "hot (b) retained");
        for p in [&pa, &pb, &p3, &p4] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn models_json_lists_metadata() {
        let reg = registry(Some(1 << 20));
        let (spec, path) = write_model("m", 47);
        let v = reg.load(&spec).unwrap();
        let _ = v.infer(&input(1)).unwrap();
        let json = reg.models_json();
        let models = match json.get("models") {
            Some(Json::Arr(a)) => a,
            other => panic!("models not an array: {other:?}"),
        };
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].str_field("name").unwrap(), "m");
        assert_eq!(models[0].num_field("version").unwrap(), 1.0);
        assert_eq!(models[0].str_field("arch").unwrap(), "mlp");
        assert_eq!(models[0].str_field("checksum").unwrap().len(), 16);
        assert!(models[0].num_field("plan_bytes").unwrap() > 0.0);
        assert_eq!(json.num_field("memory_budget_bytes").unwrap(), (1 << 20) as f64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scan_dir_picks_up_known_arches() {
        let dir = std::env::temp_dir().join(format!("pfp_scan_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let arch = Arch::mlp();
        PosteriorWeights::synthetic(&arch, 48)
            .save_npz(&dir.join("weights_mlp.npz"))
            .unwrap();
        std::fs::write(dir.join("weights_unknown.npz"), b"junk").unwrap();
        std::fs::write(dir.join("notes.txt"), b"ignored").unwrap();
        let specs = scan_models_dir(&dir, 0.5).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "mlp");
        assert!((specs[0].calib - 0.5).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }
}
