//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("npz error: {0}")]
    Npz(String),

    #[error("json error: {0}")]
    Json(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("config error: {0}")]
    Config(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<zip::result::ZipError> for Error {
    fn from(e: zip::result::ZipError) -> Self {
        Error::Npz(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
