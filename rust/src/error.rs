//! Crate-wide error type (hand-rolled Display/Error impls — `thiserror`
//! is not in the offline crate set).

#[derive(Debug)]
pub enum Error {
    Shape(String),
    Io(std::io::Error),
    Npz(String),
    Json(String),
    Manifest(String),
    Runtime(String),
    Xla(String),
    Coordinator(String),
    Config(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Npz(m) => write!(f, "npz error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla-runtime")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        assert_eq!(Error::Shape("2x3 vs 3x2".into()).to_string(), "shape mismatch: 2x3 vs 3x2");
        assert_eq!(Error::Coordinator("queue full".into()).to_string(), "coordinator error: queue full");
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
