//! Uncertainty toolkit (paper Section 2.2): logit sampling (Eq. 11),
//! Shannon/softmax entropy and mutual information (Eqs. 1-3), AUROC, and
//! the calibration-factor sweep.
//!
//! Mirrors `python/compile/metrics.py`; cross-checked against the
//! `uncertainty_{arch}.npz` goldens by the integration tests.

use crate::tensor::Tensor;
use crate::util::rng::SplitMix64;

pub const EPS: f64 = 1e-12;

/// Row-wise softmax of logits `[N, K]` (in place on a copy).
pub fn softmax(logits: &[f32], k: usize) -> Vec<f32> {
    let n = logits.len() / k;
    let mut out = vec![0.0f32; logits.len()];
    for i in 0..n {
        let row = &logits[i * k..(i + 1) * k];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in out[i * k..(i + 1) * k].iter_mut().zip(row) {
            let e = (v - max).exp();
            *o = e;
            sum += e;
        }
        for o in out[i * k..(i + 1) * k].iter_mut() {
            *o /= sum;
        }
    }
    out
}

/// Shannon entropy of each probability row `[N, K]`.
pub fn entropy_rows(probs: &[f32], k: usize) -> Vec<f64> {
    probs
        .chunks(k)
        .map(|row| -row.iter().map(|&p| p as f64 * (p as f64 + EPS).ln()).sum::<f64>())
        .collect()
}

/// Per-input uncertainty decomposition from sampled predictive
/// probabilities `[S, N, K]` (flattened sample-major).
#[derive(Clone, Debug)]
pub struct Uncertainty {
    /// Eq. 1 — total predictive uncertainty.
    pub total: Vec<f64>,
    /// Eq. 2 — softmax entropy (aleatoric).
    pub sme: Vec<f64>,
    /// Eq. 3 — mutual information (epistemic).
    pub mi: Vec<f64>,
    /// mean predictive distribution `[N, K]`.
    pub mean_p: Vec<f32>,
}

pub fn uncertainty_from_probs(probs: &[f32], s: usize, n: usize, k: usize) -> Uncertainty {
    assert_eq!(probs.len(), s * n * k);
    // mean over samples
    let mut mean_p = vec![0.0f32; n * k];
    for si in 0..s {
        for i in 0..n * k {
            mean_p[i] += probs[si * n * k + i] / s as f32;
        }
    }
    let total = entropy_rows(&mean_p, k);
    // mean of per-sample entropies
    let mut sme = vec![0.0f64; n];
    for si in 0..s {
        let ent = entropy_rows(&probs[si * n * k..(si + 1) * n * k], k);
        for i in 0..n {
            sme[i] += ent[i] / s as f64;
        }
    }
    let mi = total
        .iter()
        .zip(&sme)
        .map(|(t, a)| (t - a).max(0.0))
        .collect();
    Uncertainty { total, sme, mi, mean_p }
}

/// Eq. 11: sample `s` logit sets from `N(mu, var)` -> `[S, N, K]`.
pub fn sample_logits_gaussian(
    mu: &Tensor,
    var: &Tensor,
    s: usize,
    seed: u64,
) -> Vec<f32> {
    let n = mu.len();
    let mut out = vec![0.0f32; s * n];
    let mut rng = SplitMix64::new(seed);
    let mu_d = mu.data();
    let var_d = var.data();
    for si in 0..s {
        for i in 0..n {
            out[si * n + i] =
                mu_d[i] + var_d[i].max(0.0).sqrt() * rng.normal() as f32;
        }
    }
    out
}

/// Full PFP post-processing: logit moments -> sampled probs -> metrics.
pub fn pfp_uncertainty(
    mu: &Tensor,
    var: &Tensor,
    samples: usize,
    seed: u64,
) -> Uncertainty {
    let k = mu.cols();
    let n = mu.rows();
    let logits = sample_logits_gaussian(mu, var, samples, seed);
    let mut probs = vec![0.0f32; logits.len()];
    for si in 0..samples {
        let p = softmax(&logits[si * n * k..(si + 1) * n * k], k);
        probs[si * n * k..(si + 1) * n * k].copy_from_slice(&p);
    }
    uncertainty_from_probs(&probs, samples, n, k)
}

/// Classification accuracy of a mean predictive `[N, K]` vs labels.
pub fn accuracy(mean_p: &[f32], k: usize, labels: &[i32]) -> f64 {
    let n = labels.len();
    let mut correct = 0usize;
    for i in 0..n {
        let row = &mean_p[i * k..(i + 1) * k];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Expected Calibration Error of a mean predictive `[N, K]` vs labels:
/// confidence (max class probability) is bucketed into `bins` equal-width
/// bins and ECE is the confidence-vs-accuracy gap weighted by bin mass.
/// Used by the mixed-precision certification tests to bound how much
/// f16/bf16 moment storage may move calibration relative to f32.
pub fn ece(mean_p: &[f32], k: usize, labels: &[i32], bins: usize) -> f64 {
    assert!(bins > 0, "ece needs at least one bin");
    let n = labels.len();
    assert_eq!(mean_p.len(), n * k);
    let mut bin_conf = vec![0.0f64; bins];
    let mut bin_acc = vec![0.0f64; bins];
    let mut bin_count = vec![0usize; bins];
    for i in 0..n {
        let row = &mean_p[i * k..(i + 1) * k];
        let (pred, &conf) = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        // confidence 1.0 lands in the last bin, not one past it
        let b = (((conf as f64) * bins as f64) as usize).min(bins - 1);
        bin_conf[b] += conf as f64;
        bin_acc[b] += (pred as i32 == labels[i]) as u8 as f64;
        bin_count[b] += 1;
    }
    let mut e = 0.0f64;
    for b in 0..bins {
        if bin_count[b] == 0 {
            continue;
        }
        let c = bin_count[b] as f64;
        e += (c / n as f64) * (bin_conf[b] / c - bin_acc[b] / c).abs();
    }
    e
}

/// Rank-based AUROC (Mann-Whitney U, ties at 0.5) for separating
/// positives (OOD, high scores) from negatives (in-domain).
pub fn auroc(pos: &[f64], neg: &[f64]) -> f64 {
    let mut all: Vec<(f64, bool)> = pos
        .iter()
        .map(|&s| (s, true))
        .chain(neg.iter().map(|&s| (s, false)))
        .collect();
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let n = all.len();
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && all[j + 1].0 == all[i].0 {
            j += 1;
        }
        let avg_rank = 0.5 * (i + j) as f64 + 1.0;
        for item in all.iter().take(j + 1).skip(i) {
            if item.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let np = pos.len() as f64;
    let nn = neg.len() as f64;
    (rank_sum_pos - np * (np + 1.0) / 2.0) / (np * nn)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalises() {
        let p = softmax(&[1.0, 2.0, 3.0, 0.0, 0.0, 0.0], 3);
        for row in p.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!((p[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn entropy_bounds() {
        let uniform = vec![0.1f32; 10];
        let e = entropy_rows(&uniform, 10);
        assert!((e[0] - (10.0f64).ln()).abs() < 1e-6);
        let mut onehot = vec![0.0f32; 10];
        onehot[3] = 1.0;
        assert!(entropy_rows(&onehot, 10)[0] < 1e-9);
    }

    #[test]
    fn decomposition_identity() {
        // total = sme + mi must hold exactly
        let mut rng = SplitMix64::new(3);
        let (s, n, k) = (20, 8, 10);
        let mut probs = vec![0.0f32; s * n * k];
        for c in probs.chunks_mut(k) {
            let logits: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
            c.copy_from_slice(&softmax(&logits, k));
        }
        let u = uncertainty_from_probs(&probs, s, n, k);
        for i in 0..n {
            assert!((u.total[i] - u.sme[i] - u.mi[i]).abs() < 1e-9 || u.mi[i] == 0.0);
        }
    }

    #[test]
    fn disagreeing_onehots_high_mi() {
        let (s, n, k) = (30, 4, 10);
        let mut rng = SplitMix64::new(4);
        let mut probs = vec![1e-9f32; s * n * k];
        for si in 0..s {
            for i in 0..n {
                let c = rng.randint(k as u64) as usize;
                probs[(si * n + i) * k + c] = 1.0;
            }
        }
        let u = uncertainty_from_probs(&probs, s, n, k);
        for i in 0..n {
            assert!(u.sme[i] < 1e-6, "sme {}", u.sme[i]);
            assert!(u.mi[i] > 1.0, "mi {}", u.mi[i]);
        }
    }

    #[test]
    fn logit_sampling_moments() {
        let mu = Tensor::new(vec![1, 2], vec![1.0, -2.0]).unwrap();
        let var = Tensor::new(vec![1, 2], vec![0.25, 4.0]).unwrap();
        let s = 20_000;
        let samples = sample_logits_gaussian(&mu, &var, s, 5);
        for j in 0..2 {
            let vals: Vec<f64> = (0..s).map(|si| samples[si * 2 + j] as f64).collect();
            let mean = vals.iter().sum::<f64>() / s as f64;
            let v = vals.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / s as f64;
            assert!((mean - mu.data()[j] as f64).abs() < 0.05);
            assert!((v - var.data()[j] as f64).abs() < 0.15);
        }
    }

    #[test]
    fn auroc_perfect_random_ties() {
        assert_eq!(auroc(&[2.0, 3.0], &[0.0, 1.0]), 1.0);
        assert_eq!(auroc(&[0.0, 1.0], &[2.0, 3.0]), 0.0);
        // ties case from the python test: 8/9
        let a = auroc(&[1.0, 1.0, 2.0], &[1.0, 0.0, 0.0]);
        assert!((a - 8.0 / 9.0).abs() < 1e-9);
        let mut rng = SplitMix64::new(6);
        let pos: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let neg: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        assert!((auroc(&pos, &neg) - 0.5).abs() < 0.03);
    }

    #[test]
    fn accuracy_counts() {
        let p = vec![0.9, 0.1, 0.2, 0.8];
        assert_eq!(accuracy(&p, 2, &[0, 1]), 1.0);
        assert_eq!(accuracy(&p, 2, &[1, 1]), 0.5);
    }

    #[test]
    fn ece_perfect_and_overconfident() {
        // perfectly calibrated at confidence 1.0 and always right: ECE 0
        let p = vec![1.0, 0.0, 0.0, 1.0];
        assert!(ece(&p, 2, &[0, 1], 10) < 1e-9);
        // fully confident and always wrong: ECE 1
        assert!((ece(&p, 2, &[1, 0], 10) - 1.0).abs() < 1e-9);
        // confidence 0.6, half right: gap |0.6 - 0.5| weighted by all mass
        let p = vec![0.6, 0.4, 0.6, 0.4];
        let e = ece(&p, 2, &[0, 1], 10);
        assert!((e - 0.1).abs() < 1e-6, "got {e}");
        // top-bin edge case: confidence exactly 1.0 must not overflow bins
        let _ = ece(&[1.0, 0.0], 2, &[0], 1);
    }

    #[test]
    fn pfp_pipeline_runs() {
        let mu = Tensor::new(vec![3, 10], vec![0.1; 30]).unwrap();
        let var = Tensor::new(vec![3, 10], vec![0.5; 30]).unwrap();
        let u = pfp_uncertainty(&mu, &var, 30, 1);
        assert_eq!(u.total.len(), 3);
        assert!(u.total[0] > 0.0);
    }
}
