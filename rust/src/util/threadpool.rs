//! Scoped parallel-for built on `crossbeam_utils::thread::scope` (rayon is
//! not in the offline crate set).
//!
//! The PFP dense/conv operators use this for the paper's "Parallelization"
//! schedule knob (Table 2): output rows are split into contiguous chunks,
//! one scoped thread per chunk. On this container (1 hardware core) the
//! parallel rows of Table 2/5 measure scheduling overhead rather than
//! speedup — EXPERIMENTS.md reports this explicitly.

use crossbeam_utils::thread as cb;

/// Number of worker threads to use by default: `PFP_THREADS` env var or
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PFP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `n` items into at most `parts` contiguous ranges of near-equal
/// size. Never returns empty ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(range, chunk_index)` over `n` items split into `threads` chunks.
/// With `threads <= 1` runs inline (no spawn overhead).
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, usize) + Sync,
{
    if threads <= 1 || n <= 1 {
        f(0..n, 0);
        return;
    }
    let ranges = split_ranges(n, threads);
    cb::scope(|s| {
        for (i, r) in ranges.into_iter().enumerate() {
            let f = &f;
            s.spawn(move |_| f(r, i));
        }
    })
    .expect("worker thread panicked");
}

/// Parallel-for over disjoint mutable chunks of `out`, where chunk `i`
/// covers rows `ranges[i]` of a row-major `[n, row_len]` buffer.
pub fn parallel_rows<F>(out: &mut [f32], n_rows: usize, row_len: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), n_rows * row_len);
    if threads <= 1 || n_rows <= 1 {
        f(0..n_rows, out);
        return;
    }
    let ranges = split_ranges(n_rows, threads);
    // split the output buffer into per-range disjoint slices
    let mut slices: Vec<(&mut [f32], std::ops::Range<usize>)> = Vec::new();
    let mut rest = out;
    let mut consumed = 0usize;
    for r in ranges {
        let take = (r.end - r.start) * row_len;
        let (head, tail) = rest.split_at_mut(take);
        slices.push((head, r.clone()));
        rest = tail;
        consumed += take;
    }
    debug_assert_eq!(consumed, n_rows * row_len);
    cb::scope(|s| {
        for (chunk, r) in slices {
            let f = &f;
            s.spawn(move |_| f(r, chunk));
        }
    })
    .expect("worker thread panicked");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_ranges_covers_all() {
        for n in [0usize, 1, 7, 100] {
            for parts in [1usize, 2, 3, 8] {
                let rs = split_ranges(n, parts);
                let total: usize = rs.iter().map(|r| r.end - r.start).sum();
                assert_eq!(total, n, "n={n} parts={parts}");
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn parallel_for_visits_everything() {
        let count = AtomicUsize::new(0);
        parallel_for(1000, 4, |r, _| {
            count.fetch_add(r.end - r.start, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn parallel_rows_disjoint_writes() {
        let n_rows = 13;
        let row_len = 7;
        let mut out = vec![0.0f32; n_rows * row_len];
        parallel_rows(&mut out, n_rows, row_len, 4, |rows, chunk| {
            for (local, row) in rows.clone().enumerate() {
                for c in 0..row_len {
                    chunk[local * row_len + c] = (row * row_len + c) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn inline_when_single_thread() {
        let mut out = vec![0.0f32; 8];
        parallel_rows(&mut out, 4, 2, 1, |rows, chunk| {
            assert_eq!(rows, 0..4);
            chunk.fill(1.0);
        });
        assert!(out.iter().all(|&v| v == 1.0));
    }
}
