//! Persistent worker-pool runtime for the parallel operators.
//!
//! The PFP dense/conv/relu/pool operators use this for the paper's
//! "Parallelization" schedule knob (Table 2): output rows are split into
//! contiguous chunks, one task per chunk. The paper's tuning section warns
//! that scheduling overhead dominates parallel gains at the small batch
//! sizes PFP targets — so unlike the original scoped implementation
//! (kept as [`scoped_parallel_for`] for the overhead benchmark), the pool
//! spawns its OS threads **once** and feeds them work from a shared
//! condvar-guarded queue.
//!
//! Two dispatch paths with different cost models:
//!
//! * [`ThreadPool::scope`] — crossbeam-style borrowed closures, one boxed
//!   job per spawned task. Used by the Tensor-level operator API and the
//!   server's connection pool, where per-call boxing is noise.
//! * [`ThreadPool::run_tasks`] — **gang dispatch** for the compiled plan's
//!   pre-partitioned tile tasks: one shared `&dyn Fn(task_index)` closure
//!   is published in a broadcast slot, workers (and the calling thread,
//!   which always participates) claim task indices from it until drained.
//!   No boxing, no channel sends, no `Vec` growth — **zero heap
//!   allocation per dispatch**, which is what lets `CompiledPlan::execute`
//!   keep its zero-steady-state-allocation guarantee under parallel
//!   execution.
//!
//! One process-wide pool ([`global`]) backs the free-function helpers
//! ([`parallel_for`] / [`parallel_rows`]); the serving path shares a
//! single pool handle across all models and requests via
//! `model::Schedules::pool`.

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crossbeam_utils::thread as cb;

/// Number of worker threads to use by default: `PFP_THREADS` env var or
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PFP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `n` items into at most `parts` contiguous ranges of near-equal
/// size. Never returns empty ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One published gang: `n_tasks` task indices executed by whichever
/// threads participate (workers + the publishing leader). The raw task
/// pointer is only dereferenced while the publishing [`ThreadPool::run_tasks`]
/// frame is alive — it blocks until `next == n_tasks && active == 0`.
struct GangRun {
    task: *const (dyn Fn(usize) + Sync),
    n_tasks: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Claimed tasks still executing.
    active: usize,
    panicked: bool,
}

// SAFETY: the raw pointer crosses threads inside the state mutex; the
// pointee is `Sync` (bound on `run_tasks`) and outlives every access
// (the leader blocks until the gang fully drains).
unsafe impl Send for GangRun {}

struct PoolState {
    queue: VecDeque<Job>,
    gang: Option<GangRun>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for queued jobs or a published gang.
    work_cv: Condvar,
    /// Gang leaders wait here — for their gang to drain, or for the
    /// single broadcast slot to free up.
    sync_cv: Condvar,
}

thread_local! {
    /// Set while the current thread executes gang tasks: a nested
    /// `run_tasks` from inside a task runs inline instead of waiting on
    /// the (occupied) broadcast slot.
    static IN_GANG: Cell<bool> = const { Cell::new(false) };
}

/// Long-lived worker pool fed through a condvar-guarded queue, plus a
/// broadcast slot for allocation-free gang dispatch
/// ([`ThreadPool::run_tasks`]).
///
/// Workers run until the pool is dropped. Boxed tasks are submitted
/// through [`ThreadPool::scope`], which supports stack borrows by
/// blocking until all of its tasks complete.
///
/// Two sizing modes:
/// * [`ThreadPool::new`] spawns all `size` workers eagerly — right for
///   the operator pools, whose workers are hot from the first request;
/// * [`ThreadPool::new_lazy`] spawns **no** threads up front and grows on
///   demand, one worker per outstanding job, up to the cap — right for
///   the server's connection pool, where the eager `2 * max_connections`
///   threads (128 with defaults) would sit idle on an embedded target.
///   The growth rule (workers >= min(outstanding jobs, cap)) guarantees
///   long-running jobs (connection readers/writers) can never starve a
///   queued job of a worker. Gang dispatch never grows a lazy pool — the
///   leader runs any unclaimed tasks itself.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Jobs submitted and not yet finished (queued + running).
    outstanding: Arc<AtomicUsize>,
    /// Workers spawned so far (monotonic until drop).
    spawned: AtomicUsize,
    /// Worker cap.
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` (at least 1) persistent workers eagerly.
    pub fn new(size: usize) -> Self {
        let pool = Self::new_lazy(size);
        for i in 0..pool.size {
            pool.spawned.fetch_add(1, Ordering::SeqCst);
            pool.spawn_worker(i);
        }
        pool
    }

    /// A pool that spawns **no** OS threads until jobs arrive, then grows
    /// on demand up to `size` workers.
    pub fn new_lazy(size: usize) -> Self {
        let size = size.max(1);
        Self {
            shared: Arc::new(Shared {
                state: Mutex::new(PoolState {
                    queue: VecDeque::new(),
                    gang: None,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                sync_cv: Condvar::new(),
            }),
            workers: Mutex::new(Vec::new()),
            outstanding: Arc::new(AtomicUsize::new(0)),
            spawned: AtomicUsize::new(0),
            size,
        }
    }

    /// `id` is the slot uniquely claimed on `spawned` (a CAS or the eager
    /// loop index) — not `workers.len()`, which two concurrent growers
    /// could read identically.
    fn spawn_worker(&self, id: usize) {
        let shared = Arc::clone(&self.shared);
        let handle = std::thread::Builder::new()
            .name(format!("pfp-pool-{id}"))
            .spawn(move || worker_loop(&shared))
            .expect("spawn pool worker");
        self.workers.lock().unwrap().push(handle);
    }

    /// Push one job onto the queue and wake a worker.
    fn push_job(&self, job: Job) {
        let mut st = self.shared.state.lock().unwrap();
        assert!(!st.shutdown, "pool is shut down");
        st.queue.push_back(job);
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Queue one job, growing the worker set so that every outstanding
    /// job (queued or running) has a worker, up to the cap.
    fn submit(&self, job: Job) {
        // Pools at their cap (eager pools always; lazy pools once fully
        // grown) can never spawn again: skip the outstanding tracking and
        // keep the one-box dispatch on the hot kernel path.
        if self.spawned.load(Ordering::Relaxed) >= self.size {
            self.push_job(job);
            return;
        }
        let outstanding = Arc::clone(&self.outstanding);
        outstanding.fetch_add(1, Ordering::SeqCst);
        let tracked: Job = Box::new(move || {
            job();
            outstanding.fetch_sub(1, Ordering::SeqCst);
        });
        self.push_job(tracked);
        loop {
            let spawned = self.spawned.load(Ordering::SeqCst);
            if spawned >= self.size || spawned >= self.outstanding.load(Ordering::SeqCst) {
                break;
            }
            if self
                .spawned
                .compare_exchange(spawned, spawned + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                self.spawn_worker(spawned);
            }
        }
    }

    /// Pool sized from `PFP_THREADS` / available parallelism.
    pub fn with_default_threads() -> Self {
        Self::new(default_threads())
    }

    /// Worker cap (for lazy pools, the maximum, not the current count).
    pub fn size(&self) -> usize {
        self.size
    }

    /// OS threads actually spawned so far.
    pub fn spawned_workers(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Gang-dispatch `n_tasks` pre-partitioned tasks: `task(i)` runs
    /// exactly once for every `i in 0..n_tasks`, spread over the pool's
    /// workers *and* the calling thread, which always participates (so the
    /// call completes even on a lazy pool with zero spawned workers).
    /// Blocks until every task has finished.
    ///
    /// This is the compiled plan's execution primitive: unlike
    /// [`ThreadPool::scope`] it performs **zero heap allocation** — the
    /// shared closure is published by reference in a broadcast slot and
    /// task indices are claimed under the pool mutex, so the plan's
    /// zero-steady-state-allocation guarantee survives parallel execution.
    /// Concurrent `run_tasks` calls on one pool serialize on the slot;
    /// a nested call from inside a task runs inline. Task panics are
    /// propagated to the caller after the gang drains.
    pub fn run_tasks(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if n_tasks == 1 || IN_GANG.with(|g| g.get()) {
            for i in 0..n_tasks {
                task(i);
            }
            return;
        }
        // Erase the borrow lifetime for the broadcast slot. SAFETY: this
        // frame blocks until `next == n_tasks && active == 0`, so the
        // closure outlives every worker-side dereference.
        let erased: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                task,
            )
        };
        let mut st = self.shared.state.lock().unwrap();
        while st.gang.is_some() {
            st = self.shared.sync_cv.wait(st).unwrap();
        }
        st.gang = Some(GangRun {
            task: erased,
            n_tasks,
            next: 0,
            active: 0,
            panicked: false,
        });
        drop(st);
        self.shared.work_cv.notify_all();
        IN_GANG.with(|g| g.set(true));
        let mut st = self.shared.state.lock().unwrap();
        loop {
            let g = st.gang.as_mut().expect("gang retired under its leader");
            if g.next < g.n_tasks {
                let idx = g.next;
                g.next += 1;
                g.active += 1;
                drop(st);
                let ok = catch_unwind(AssertUnwindSafe(|| task(idx))).is_ok();
                st = self.shared.state.lock().unwrap();
                let g = st.gang.as_mut().expect("gang retired under its leader");
                g.active -= 1;
                if !ok {
                    g.panicked = true;
                }
            } else if g.active > 0 {
                // stragglers on worker threads: wait for the last one
                st = self.shared.sync_cv.wait(st).unwrap();
            } else {
                let panicked = g.panicked;
                st.gang = None;
                drop(st);
                // wake any leader waiting for the broadcast slot
                self.shared.sync_cv.notify_all();
                IN_GANG.with(|g| g.set(false));
                if panicked {
                    panic!("gang task panicked");
                }
                return;
            }
        }
    }

    /// Run `f` with a [`Scope`] that can spawn borrowed tasks onto the
    /// pool. Blocks until every spawned task has completed; panics from
    /// tasks are propagated (after all tasks finish), mirroring the
    /// `crossbeam` scope contract.
    pub fn scope<'scope, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'_, 'scope>) -> R,
    {
        let scope = Scope {
            pool: self,
            latch: Arc::new(Latch {
                pending: Mutex::new(0),
                done: Condvar::new(),
                panicked: AtomicBool::new(false),
            }),
            _marker: PhantomData,
        };
        // Even if `f` itself panics we must wait for already-spawned tasks
        // before unwinding, or they would race with freed stack frames.
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        scope.wait_all();
        match result {
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(r) => {
                if scope.latch.panicked.load(Ordering::SeqCst) {
                    panic!("worker task panicked");
                }
                r
            }
        }
    }
}

/// Worker body: gang tasks preempt queued jobs (the gang leader is
/// blocked waiting on them; queued jobs have their own waiters).
fn worker_loop(shared: &Shared) {
    let mut st = shared.state.lock().unwrap();
    loop {
        let claimed = match st.gang.as_mut() {
            Some(g) if g.next < g.n_tasks => {
                let idx = g.next;
                g.next += 1;
                g.active += 1;
                Some((idx, g.task))
            }
            _ => None,
        };
        if let Some((idx, task)) = claimed {
            drop(st);
            IN_GANG.with(|f| f.set(true));
            // SAFETY: the publishing `run_tasks` frame is alive (it blocks
            // on `active`), so the closure behind `task` is too.
            let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (*task)(idx) })).is_ok();
            IN_GANG.with(|f| f.set(false));
            st = shared.state.lock().unwrap();
            let g = st.gang.as_mut().expect("gang retired while tasks active");
            g.active -= 1;
            if !ok {
                g.panicked = true;
            }
            if g.next >= g.n_tasks && g.active == 0 {
                shared.sync_cv.notify_all();
            }
            continue;
        }
        if let Some(job) = st.queue.pop_front() {
            drop(st);
            job();
            st = shared.state.lock().unwrap();
            continue;
        }
        if st.shutdown {
            return;
        }
        st = shared.work_cv.wait(st).unwrap();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("size", &self.size)
            .field("spawned", &self.spawned_workers())
            .finish()
    }
}

/// Raw shareable view of a mutable `f32` buffer for gang tasks that write
/// provably disjoint ranges — the compiled plan's tile partitions. A
/// borrow-checker-visible `&mut` split is impossible for a closure shared
/// by every worker, so disjointness is promised by the caller instead —
/// and double-checked in debug builds, where [`slice`](Self::slice)
/// panics if two claims overlap.
pub struct DisjointMut {
    ptr: *mut f32,
    len: usize,
    /// Debug-build ledger of handed-out `(start, len)` ranges: a wrong
    /// tile partition becomes a loud panic instead of a silent data race.
    #[cfg(debug_assertions)]
    claims: Mutex<Vec<(usize, usize)>>,
}

// SAFETY: the pointer comes from a live `&mut [f32]` that outlives the
// view (its callers keep the borrow across the blocking `run_tasks`
// call), and the `slice` contract — enforced by the debug-build claims
// ledger — makes every concurrent access disjoint, so no aliased `&mut`
// can be formed on another thread. `f32` is plain old data: no drop
// glue, no interior mutability, every bit pattern valid.
unsafe impl Send for DisjointMut {}
// SAFETY: same argument as `Send` — `&DisjointMut` only exposes `slice`,
// whose disjointness contract is exactly the guarantee `Sync` needs.
unsafe impl Sync for DisjointMut {}

impl DisjointMut {
    pub fn new(s: &mut [f32]) -> Self {
        Self {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            #[cfg(debug_assertions)]
            claims: Mutex::new(Vec::new()),
        }
    }

    /// View `len` floats starting at `start` as a mutable slice.
    ///
    /// # Safety
    /// Concurrent callers must request non-overlapping ranges, and the
    /// backing buffer must outlive every returned slice (guaranteed when
    /// used inside [`ThreadPool::run_tasks`], which blocks its caller
    /// until all tasks finish). Debug builds verify the disjointness
    /// half of the contract and panic on overlap.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [f32] {
        debug_assert!(start + len <= self.len, "disjoint slice out of bounds");
        #[cfg(debug_assertions)]
        {
            let mut claims = self.claims.lock().unwrap();
            for &(s0, l0) in claims.iter() {
                assert!(
                    start + len <= s0 || s0 + l0 <= start,
                    "DisjointMut::slice overlap: [{start}, {}) vs prior claim [{s0}, {})",
                    start + len,
                    s0 + l0,
                );
            }
            claims.push((start, len));
        }
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

struct Latch {
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// Spawn handle passed to the closure of [`ThreadPool::scope`].
pub struct Scope<'pool, 'scope> {
    pool: &'pool ThreadPool,
    latch: Arc<Latch>,
    // Invariant over 'scope, like crossbeam's scope.
    _marker: PhantomData<&'scope mut &'scope ()>,
}

impl<'pool, 'scope> Scope<'pool, 'scope> {
    /// Submit a task that may borrow anything outliving the scope.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        *self.latch.pending.lock().unwrap() += 1;
        let latch = Arc::clone(&self.latch);
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            if catch_unwind(AssertUnwindSafe(f)).is_err() {
                latch.panicked.store(true, Ordering::SeqCst);
            }
            let mut pending = latch.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                latch.done.notify_all();
            }
        });
        // SAFETY: `ThreadPool::scope` calls `wait_all` before returning,
        // so this job runs to completion while every `'scope` borrow it
        // captures is still live; erasing the lifetime is therefore sound
        // (same argument as `scoped_threadpool` / `crossbeam::scope`).
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
        };
        self.pool.submit(job);
    }

    fn wait_all(&self) {
        let mut pending = self.latch.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.latch.done.wait(pending).unwrap();
        }
    }
}

/// The process-wide shared pool (sized by [`default_threads`]); spawned
/// lazily on first parallel call and reused for the process lifetime.
pub fn global() -> &'static Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| Arc::new(ThreadPool::with_default_threads()))
}

/// Run `f(range, chunk_index)` over `n` items split into `threads` chunks
/// on `pool`. With `threads <= 1` runs inline (no dispatch overhead).
pub fn parallel_for_in<F>(pool: &ThreadPool, n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, usize) + Sync,
{
    if threads <= 1 || n <= 1 {
        f(0..n, 0);
        return;
    }
    let ranges = split_ranges(n, threads);
    pool.scope(|s| {
        for (i, r) in ranges.into_iter().enumerate() {
            let f = &f;
            s.spawn(move || f(r, i));
        }
    });
}

/// [`parallel_for_in`] on the process-wide [`global`] pool.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, usize) + Sync,
{
    parallel_for_in(global(), n, threads, f);
}

/// Parallel-for over disjoint mutable chunks of `out`, where chunk `i`
/// covers rows `ranges[i]` of a row-major `[n, row_len]` buffer.
pub fn parallel_rows_in<F>(
    pool: &ThreadPool,
    out: &mut [f32],
    n_rows: usize,
    row_len: usize,
    threads: usize,
    f: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), n_rows * row_len);
    if threads <= 1 || n_rows <= 1 {
        f(0..n_rows, out);
        return;
    }
    let ranges = split_ranges(n_rows, threads);
    // split the output buffer into per-range disjoint slices
    let mut slices: Vec<(&mut [f32], std::ops::Range<usize>)> = Vec::new();
    let mut rest = out;
    let mut consumed = 0usize;
    for r in ranges {
        let take = (r.end - r.start) * row_len;
        let (head, tail) = rest.split_at_mut(take);
        slices.push((head, r.clone()));
        rest = tail;
        consumed += take;
    }
    debug_assert_eq!(consumed, n_rows * row_len);
    pool.scope(|s| {
        for (chunk, r) in slices {
            let f = &f;
            s.spawn(move || f(r, chunk));
        }
    });
}

/// [`parallel_rows_in`] on the process-wide [`global`] pool.
pub fn parallel_rows<F>(out: &mut [f32], n_rows: usize, row_len: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    parallel_rows_in(global(), out, n_rows, row_len, threads, f);
}

/// The original spawn-per-call scoped parallel-for, kept as the baseline
/// for the pool-dispatch-overhead micro-benchmark
/// (`benches/pool_overhead.rs`): every call pays `threads` OS-thread
/// spawns + joins.
pub fn scoped_parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, usize) + Sync,
{
    if threads <= 1 || n <= 1 {
        f(0..n, 0);
        return;
    }
    let ranges = split_ranges(n, threads);
    cb::scope(|s| {
        for (i, r) in ranges.into_iter().enumerate() {
            let f = &f;
            s.spawn(move |_| f(r, i));
        }
    })
    .expect("worker thread panicked");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_ranges_covers_all() {
        for n in [0usize, 1, 7, 100] {
            for parts in [1usize, 2, 3, 8] {
                let rs = split_ranges(n, parts);
                let total: usize = rs.iter().map(|r| r.end - r.start).sum();
                assert_eq!(total, n, "n={n} parts={parts}");
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn split_ranges_zero_items_is_empty() {
        assert!(split_ranges(0, 1).is_empty());
        assert!(split_ranges(0, 4).is_empty());
        assert!(split_ranges(0, 0).is_empty());
    }

    #[test]
    fn split_ranges_more_parts_than_items() {
        // parts is clamped to n: every range holds exactly one item.
        let rs = split_ranges(3, 8);
        assert_eq!(rs.len(), 3);
        assert!(rs.iter().all(|r| r.end - r.start == 1));
        assert_eq!(rs[0], 0..1);
        assert_eq!(rs[2], 2..3);
    }

    #[test]
    fn split_ranges_zero_parts_clamps_to_one() {
        let rs = split_ranges(5, 0);
        assert_eq!(rs, vec![0..5]);
    }

    #[test]
    fn parallel_for_visits_everything() {
        let count = AtomicUsize::new(0);
        parallel_for(1000, 4, |r, _| {
            count.fetch_add(r.end - r.start, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn parallel_rows_disjoint_writes() {
        let n_rows = 13;
        let row_len = 7;
        let mut out = vec![0.0f32; n_rows * row_len];
        parallel_rows(&mut out, n_rows, row_len, 4, |rows, chunk| {
            for (local, row) in rows.clone().enumerate() {
                for c in 0..row_len {
                    chunk[local * row_len + c] = (row * row_len + c) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn inline_when_single_thread() {
        let mut out = vec![0.0f32; 8];
        parallel_rows(&mut out, 4, 2, 1, |rows, chunk| {
            assert_eq!(rows, 0..4);
            chunk.fill(1.0);
        });
        assert!(out.iter().all(|&v| v == 1.0));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // timing/volume test, not a memory-safety target
    fn pool_reuses_workers_across_calls() {
        let pool = ThreadPool::new(3);
        assert_eq!(pool.size(), 3);
        for round in 0..50 {
            let count = AtomicUsize::new(0);
            parallel_for_in(&pool, 64, 3, |r, _| {
                count.fetch_add(r.end - r.start, Ordering::SeqCst);
            });
            assert_eq!(count.load(Ordering::SeqCst), 64, "round {round}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // timing/volume test, not a memory-safety target
    fn lazy_pool_spawns_no_threads_up_front() {
        let pool = ThreadPool::new_lazy(64);
        assert_eq!(pool.spawned_workers(), 0, "idle lazy pool owns no threads");
        assert_eq!(pool.size(), 64);
        // first work grows the pool on demand...
        let count = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                let count = &count;
                s.spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    count.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        let grown = pool.spawned_workers();
        assert!(grown >= 1, "demand must spawn workers");
        assert!(grown <= 64, "growth respects the cap");
        // ...and does not shrink-grow-thrash: a second burst of the same
        // size reuses the existing workers
        pool.scope(|s| {
            for _ in 0..4 {
                let count = &count;
                s.spawn(move || {
                    count.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert!(pool.spawned_workers() <= grown.max(4));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // timing/volume test, not a memory-safety target
    fn lazy_pool_growth_covers_outstanding_long_jobs() {
        // Long-running jobs (the server's connection readers/writers) must
        // each get their own worker: a queued job may never starve behind
        // a blocked one.
        let pool = ThreadPool::new_lazy(8);
        let release = Arc::new(AtomicBool::new(false));
        let running = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..6 {
                let release = Arc::clone(&release);
                let running = Arc::clone(&running);
                s.spawn(move || {
                    running.fetch_add(1, Ordering::SeqCst);
                    while !release.load(Ordering::SeqCst) {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                });
            }
            // all six blocked jobs must be running concurrently
            let t0 = std::time::Instant::now();
            while running.load(Ordering::SeqCst) < 6 {
                assert!(
                    t0.elapsed() < std::time::Duration::from_secs(5),
                    "lazy growth starved a job: {} of 6 running",
                    running.load(Ordering::SeqCst)
                );
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            release.store(true, Ordering::SeqCst);
        });
        assert!(pool.spawned_workers() >= 6);
        assert!(pool.spawned_workers() <= 8);
    }

    #[test]
    fn eager_pool_reports_full_spawn() {
        let pool = ThreadPool::new(3);
        assert_eq!(pool.spawned_workers(), 3);
    }

    #[test]
    fn scope_waits_for_all_tasks() {
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..32 {
                let count = &count;
                s.spawn(move || {
                    count.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        // all 32 tasks must have completed by the time scope returns
        assert_eq!(count.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn scope_supports_stack_borrows() {
        let pool = ThreadPool::new(2);
        let data = vec![1u32, 2, 3, 4];
        let sum = AtomicUsize::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(2) {
                let sum = &sum;
                s.spawn(move || {
                    sum.fetch_add(
                        chunk.iter().map(|&v| v as usize).sum(),
                        Ordering::SeqCst,
                    );
                });
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn concurrent_scopes_share_one_pool() {
        // Two OS threads driving scopes on the same pool (the serving
        // topology: many requests, one pool) must not interfere.
        let pool = Arc::new(ThreadPool::new(2));
        let mut handles = Vec::new();
        for t in 0..4 {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let count = AtomicUsize::new(0);
                parallel_for_in(&pool, 100 + t, 2, |r, _| {
                    count.fetch_add(r.end - r.start, Ordering::SeqCst);
                });
                count.load(Ordering::SeqCst)
            }));
        }
        for (t, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), 100 + t);
        }
    }

    #[test]
    fn worker_panic_propagates() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("task boom"));
            });
        }));
        assert!(r.is_err());
        // pool survives a panicked task and stays usable
        let count = AtomicUsize::new(0);
        parallel_for_in(&pool, 16, 2, |r, _| {
            count.fetch_add(r.end - r.start, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn run_tasks_executes_every_index_once() {
        let pool = ThreadPool::new(3);
        for n_tasks in [1usize, 2, 3, 7, 32] {
            let hits: Vec<AtomicUsize> =
                (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run_tasks(n_tasks, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "task {i} of {n_tasks}");
            }
        }
    }

    #[test]
    fn run_tasks_completes_with_zero_workers() {
        // lazy pool, nothing spawned: the leader runs every task itself
        let pool = ThreadPool::new_lazy(4);
        let count = AtomicUsize::new(0);
        pool.run_tasks(5, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
        assert_eq!(pool.spawned_workers(), 0, "gang dispatch never grows a lazy pool");
    }

    #[test]
    fn nested_run_tasks_runs_inline() {
        let pool = ThreadPool::new(2);
        let count = AtomicUsize::new(0);
        pool.run_tasks(3, &|_| {
            // nested gang from inside a task: must not deadlock on the
            // occupied broadcast slot
            pool.run_tasks(4, &|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn run_tasks_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks(4, &|i| {
                if i == 2 {
                    panic!("tile boom");
                }
            });
        }));
        assert!(r.is_err());
        let count = AtomicUsize::new(0);
        pool.run_tasks(4, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // timing/volume test, not a memory-safety target
    fn concurrent_run_tasks_serialize_on_the_slot() {
        let pool = Arc::new(ThreadPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let total = Arc::clone(&total);
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    pool.run_tasks(3, &|_| {
                        total.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 25 * 3);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // timing/volume test, not a memory-safety target
    fn run_tasks_coexists_with_scope_jobs() {
        let pool = Arc::new(ThreadPool::new(3));
        let scope_count = Arc::new(AtomicUsize::new(0));
        let gang_count = Arc::new(AtomicUsize::new(0));
        let p2 = Arc::clone(&pool);
        let sc = Arc::clone(&scope_count);
        let bg = std::thread::spawn(move || {
            p2.scope(|s| {
                for _ in 0..16 {
                    let sc = &sc;
                    s.spawn(move || {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        sc.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        });
        for _ in 0..10 {
            pool.run_tasks(4, &|_| {
                gang_count.fetch_add(1, Ordering::SeqCst);
            });
        }
        bg.join().unwrap();
        assert_eq!(scope_count.load(Ordering::SeqCst), 16);
        assert_eq!(gang_count.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn disjoint_mut_writes_land() {
        let pool = ThreadPool::new(2);
        let mut buf = vec![0.0f32; 12];
        let ranges = split_ranges(12, 4);
        let parts = DisjointMut::new(&mut buf);
        pool.run_tasks(ranges.len(), &|ti| {
            let r = ranges[ti].clone();
            // SAFETY: split_ranges yields disjoint ranges.
            let chunk = unsafe { parts.slice(r.start, r.end - r.start) };
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (r.start + j) as f32;
            }
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn scoped_baseline_still_correct() {
        let count = AtomicUsize::new(0);
        scoped_parallel_for(257, 4, |r, _| {
            count.fetch_add(r.end - r.start, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 257);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = global() as *const _;
        let b = global() as *const _;
        assert_eq!(a, b);
    }
}
