//! Small statistics helpers shared by the bench harness, the tuner's
//! measurement loop and the coordinator's metrics.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min over a slice of f64 (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
