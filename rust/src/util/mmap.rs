//! Read-only memory mapping without the `memmap2` crate (not in the
//! offline crate set).
//!
//! On unix targets `std` already links libc, so `mmap`/`munmap` are
//! declared directly and a [`MappedFile`] wraps a `PROT_READ` /
//! `MAP_PRIVATE` mapping of a whole file. Everywhere else — and whenever
//! the syscall fails — [`MappedFile::open`] falls back to reading the file
//! into an anonymous heap buffer, so callers get identical bytes either
//! way and never need to branch on platform. `is_mapped()` reports which
//! path was taken (tests and the registry's `models` listing use it).

use std::path::Path;

use crate::error::{Error, Result};

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;
}

enum Backing {
    /// Live mmap: pointer + length, unmapped on drop.
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
    /// Heap fallback (non-unix, empty file, or mmap refused).
    Heap(Vec<u8>),
}

/// A whole file held read-only in memory — by `mmap` when possible, by a
/// heap copy otherwise. Dereferences to `&[u8]`.
pub struct MappedFile {
    backing: Backing,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE and never remapped or
// written through after creation, so moving the owning handle to another
// thread cannot race with anything; `munmap` runs exactly once, in Drop.
unsafe impl Send for MappedFile {}
// SAFETY: shared access is read-only (`bytes` hands out `&[u8]` into an
// immutable mapping), as safe to share across threads as any `&[u8]`.
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map `path` read-only, falling back to a heap read on any failure.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, true)
    }

    /// Like [`open`](Self::open) but `use_mmap: false` forces the heap
    /// path (the `--no-mmap` serve flag).
    pub fn open_with(path: &Path, use_mmap: bool) -> Result<Self> {
        #[cfg(unix)]
        if use_mmap {
            if let Some(backing) = Self::try_mmap(path) {
                return Ok(Self { backing });
            }
        }
        let _ = use_mmap;
        let bytes = std::fs::read(path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("read {}: {e}", path.display()),
            ))
        })?;
        Ok(Self { backing: Backing::Heap(bytes) })
    }

    #[cfg(unix)]
    fn try_mmap(path: &Path) -> Option<Backing> {
        use std::os::unix::io::AsRawFd;
        if cfg!(miri) {
            // miri cannot emulate the mmap FFI call; the heap fallback
            // keeps every caller (and this module's tests) checkable.
            return None;
        }
        let file = std::fs::File::open(path).ok()?;
        let len = file.metadata().ok()?.len() as usize;
        if len == 0 {
            // zero-length mmap is EINVAL; the heap path handles it.
            return None;
        }
        // SAFETY: plain FFI call with valid arguments — null addr lets the
        // kernel pick the placement, `len > 0` was checked above, `fd` is
        // an open file held for the duration of the call (MAP_PRIVATE
        // keeps the mapping valid after the fd closes), offset 0. The
        // result is checked against MAP_FAILED before use.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return None;
        }
        Some(Backing::Mapped { ptr: ptr as *const u8, len })
    }

    /// Whether the bytes come from a live mapping (vs the heap fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { .. } => true,
            Backing::Heap(_) => false,
        }
    }

    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(unix)]
            // SAFETY: `ptr` is the non-MAP_FAILED result of a successful
            // `mmap` of exactly `len` bytes, readable (PROT_READ), never
            // written, and unmapped only in Drop — so for `&self`'s
            // lifetime it is valid, initialized memory; `u8` has no
            // alignment or validity requirements.
            Backing::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            Backing::Heap(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.backing {
            // SAFETY: `(ptr, len)` is exactly what `mmap` returned, and
            // Drop runs at most once, so the region is live here and no
            // `&[u8]` into it can outlive `self` (they borrow from it).
            unsafe {
                sys::munmap(ptr as *mut std::ffi::c_void, len);
            }
        }
    }
}

impl std::ops::Deref for MappedFile {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

impl std::fmt::Debug for MappedFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedFile")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_file(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!("pfp_mmap_{}_{name}", std::process::id()));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn mapped_bytes_match_file() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let path = tmp_file("match", &data);
        let m = MappedFile::open(&path).unwrap();
        assert_eq!(&*m, &data[..]);
        // under miri the mmap syscall is unavailable and open() falls
        // back to the heap, so only assert the mapping on a real OS
        #[cfg(all(unix, not(miri)))]
        assert!(m.is_mapped());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn heap_fallback_matches() {
        let data = b"heap path bytes".to_vec();
        let path = tmp_file("heap", &data);
        let m = MappedFile::open_with(&path, false).unwrap();
        assert!(!m.is_mapped());
        assert_eq!(&*m, &data[..]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_uses_heap() {
        let path = tmp_file("empty", b"");
        let m = MappedFile::open(&path).unwrap();
        assert!(!m.is_mapped());
        assert!(m.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(MappedFile::open(Path::new("/nonexistent/pfp_mmap")).is_err());
    }
}
