//! Hand-rolled property-test harness (proptest is not in the offline crate
//! set). Seeded random case generation with failure reporting that names
//! the case seed, so failures reproduce exactly.
//!
//! Used by the operator and coordinator invariant tests:
//! `check(cases, |g| { ... })` draws sizes/values from `g` and asserts
//! inside the closure.

use super::rng::SplitMix64;

/// Random case generator handed to property bodies.
pub struct Gen {
    rng: SplitMix64,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), case_seed: seed }
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.randint((hi - lo + 1) as u64) as usize
    }

    /// f32 uniform in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform() as f32
    }

    /// standard normal f32 scaled.
    pub fn normal(&mut self, scale: f32) -> f32 {
        scale * self.rng.normal() as f32
    }

    /// vec of normals.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal(scale)).collect()
    }

    /// vec of non-negative values (abs of normals), for variances.
    pub fn var_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal(scale).abs() + 1e-6).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.randint(2) == 0
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Random ISA knob (the SIMD dispatch dimension).
    pub fn isa(&mut self) -> crate::ops::simd::Isa {
        use crate::ops::simd::Isa;
        if self.bool() {
            Isa::Native
        } else {
            Isa::Scalar
        }
    }

    /// Random storage precision (the mixed-precision knob), covering all
    /// three formats.
    pub fn precision(&mut self) -> crate::util::half::Precision {
        use crate::util::half::Precision;
        *self.pick(&[Precision::F32, Precision::F16, Precision::Bf16])
    }

    /// Random dense workload shape `(m, k, n)` within the given caps
    /// (inclusive, each at least 1).
    pub fn dense_shape(&mut self, m_max: usize, k_max: usize, n_max: usize) -> (usize, usize, usize) {
        (
            self.usize_in(1, m_max),
            self.usize_in(1, k_max),
            self.usize_in(1, n_max),
        )
    }

    /// Random schedule across every knob — loop order, tiles, unroll,
    /// vectorize hints, ISA — with `threads` pinned to 1 (differential
    /// tests drive parallelism through explicit tile partitions instead).
    pub fn schedule(&mut self) -> crate::ops::Schedule {
        use crate::ops::{LoopOrder, Schedule};
        let tiled = self.usize_in(0, 3) == 0;
        let (tile_n, tile_k) = if tiled {
            (*self.pick(&[8usize, 16, 32]), *self.pick(&[32usize, 64, 128]))
        } else {
            (0, 0)
        };
        Schedule {
            loop_order: if self.bool() { LoopOrder::Mnk } else { LoopOrder::Mkn },
            tile_n,
            tile_k,
            unroll: *self.pick(&[1usize, 2, 3, 4, 8]),
            vectorize: self.bool(),
            threads: 1,
            isa: self.isa(),
            // the kernels take the fused epilogue as an explicit argument,
            // so differential tests drive fusion directly rather than
            // through this eligibility knob
            fuse: false,
            // likewise, the packed kernels take the weight precision as
            // explicit PackedSlice operands; the plan-level tests that
            // exercise this knob set it deliberately
            precision: crate::util::half::Precision::F32,
        }
    }
}

/// Run `body` for `cases` seeded cases. Panics (with the case seed) on the
/// first failing case. Base seed can be overridden with `PFP_PROP_SEED`
/// to reproduce a failure.
pub fn check<F: FnMut(&mut Gen)>(cases: usize, mut body: F) {
    let base = std::env::var("PFP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00u64);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {i} (PFP_PROP_SEED={base}, case seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges_hold() {
        check(50, |g| {
            let n = g.usize_in(1, 10);
            assert!((1..=10).contains(&n));
            let x = g.f32_in(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
            let v = g.var_vec(n, 1.0);
            assert!(v.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(10, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 90, "drew {n}");
        });
    }
}
