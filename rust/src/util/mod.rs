//! Offline substrate: the crates this repo would normally pull from
//! crates.io (rand, serde_json, rayon, proptest, criterion) are not in the
//! offline crate set, so the minimal pieces we need are implemented here
//! and unit-tested in place.

pub mod bench;
pub mod half;
pub mod json;
pub mod mmap;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
