//! Minimal JSON parser/serializer (serde is not in the offline crate set).
//!
//! Supports the full JSON value model with the subset of ergonomics this
//! crate needs: manifest parsing, coordinator wire protocol, tuning
//! records, and bench-result emission.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(key)` as &str or an error naming the key.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Json(format!("missing string field '{key}'")))
    }

    pub fn num_field(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Json(format!("missing number field '{key}'")))
    }

    pub fn arr_field(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Json(format!("missing array field '{key}'")))
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| Error::Json("expected number".into()))
            })
            .collect()
    }

    pub fn to_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Json("expected integer".into()))
            })
            .collect()
    }

    // ---- serialization ---------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("invalid literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("bad \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| Error::Json("invalid utf-8".into()))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| Error::Json("bad number".into()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{s}' at byte {start}")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => return Err(Error::Json(format!("expected ',' or ']', got '{}'", c as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(Error::Json(format!("expected ',' or '}}', got '{}'", c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2000.0));
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        let rows = v.as_arr().unwrap();
        assert_eq!(rows[1].to_f32_vec().unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let d = v.dump();
        assert_eq!(Json::parse(&d).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn integers_serialized_without_dot() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn field_helpers() {
        let v = Json::parse(r#"{"s":"x","n":3,"a":[1]}"#).unwrap();
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert_eq!(v.num_field("n").unwrap(), 3.0);
        assert_eq!(v.arr_field("a").unwrap().len(), 1);
        assert!(v.str_field("missing").is_err());
    }
}
