//! Dependency-free half-precision storage formats for the mixed-precision
//! PFP path: IEEE 754 binary16 (`f16`) and bfloat16 (`bf16`).
//!
//! These are *storage* formats only. Every kernel widens packed operands
//! to f32 registers and accumulates in f32; the only rounding happens on
//! the narrow-on-store edge. The scalar conversions here are the bitwise
//! reference the vectorized paths in `ops::simd` must match exactly:
//!
//! * narrowing uses round-to-nearest-even (the same mode x86 `F16C`
//!   hardware uses for `vcvtps2ph` with rounding control 0), including
//!   for values that land in the f16 subnormal range;
//! * widening is exact (every f16/bf16 value is representable in f32);
//! * NaNs narrow to quiet NaNs with the top mantissa payload bits kept
//!   (f16) or the quiet bit forced (bf16), matching hardware behaviour;
//!   signalling NaNs therefore do not round-trip bit-exactly, by design.

/// Storage precision for posterior moments and inter-layer activations.
///
/// `F32` is the default everywhere and keeps the pre-existing kernels
/// byte-for-byte untouched; `F16`/`Bf16` store tensors as packed `u16`
/// and widen to f32 inside the kernels (f32 accumulation contract).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    #[default]
    F32,
    F16,
    Bf16,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Bf16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "f16" => Some(Precision::F16),
            "bf16" => Some(Precision::Bf16),
            _ => None,
        }
    }

    /// Bytes per stored element.
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F16 | Precision::Bf16 => 2,
        }
    }

    pub fn is_f32(self) -> bool {
        self == Precision::F32
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Narrow an f32 to IEEE binary16 bits with round-to-nearest-even,
/// matching x86 `vcvtps2ph` (rounding control 0) bit-for-bit: gradual
/// underflow to subnormals, overflow to infinity, NaN payload truncated
/// to the top 10 mantissa bits with the quiet bit forced.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN. Keep the top payload bits, force the quiet bit so
        // a NaN never collapses to the infinity encoding.
        return if man == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7e00 | ((man >> 13) as u16 & 0x01ff)
        };
    }

    // Unbiased exponent of the f32 value (normals; f32 subnormals are
    // far below the f16 subnormal range and flush to zero through the
    // shift path below).
    let unbiased = exp - 127;
    if unbiased >= 16 {
        // Too large for f16 (max finite is 65504, exponent 15): RNE on
        // the boundary already rounds 65520+ to infinity, and anything
        // with unbiased >= 16 is past that.
        return sign | 0x7c00;
    }
    if unbiased >= -14 {
        // Normal f16 range. 13 dropped mantissa bits; round half to even.
        let man16 = (man >> 13) as u16;
        let rest = man & 0x1fff;
        let half = 0x1000;
        let mut out = sign | (((unbiased + 15) as u16) << 10) | man16;
        if rest > half || (rest == half && (man16 & 1) == 1) {
            // Mantissa carry naturally increments the exponent, and a
            // carry out of exponent 30 lands exactly on the infinity
            // encoding — both correct under RNE.
            out += 1;
        }
        return out;
    }
    if unbiased >= -25 {
        // Subnormal f16 range: make the implicit bit explicit, then
        // shift right by the underflow amount with RNE on what falls off.
        // value = 1.man * 2^unbiased; the f16 subnormal unit is 2^-24, so
        // the 24-bit significand moves right (−14 − unbiased) places past
        // the normal 13-bit drop.
        let full = man | 0x0080_0000; // 24-bit significand
        let total = (13 + (-14 - unbiased)) as u32; // 14..=24
        let man16 = (full >> total) as u16;
        let rest = full & ((1u32 << total) - 1);
        let half = 1u32 << (total - 1);
        let mut out = sign | man16;
        if rest > half || (rest == half && (man16 & 1) == 1) {
            out += 1; // carry into the smallest normal is again correct
        }
        return out;
    }
    // Below half the smallest subnormal: signed zero.
    sign
}

/// Widen IEEE binary16 bits to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // signed zero
        }
        // Subnormal: value = man * 2^-24. Exact in f32.
        let mag = (man as f32) * f32::from_bits(0x3380_0000); // 2^-24
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 0x1f {
        // Inf / NaN: widen payload into the top f32 mantissa bits.
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Narrow an f32 to bfloat16 bits with round-to-nearest-even. bf16 keeps
/// the f32 exponent, so there is no overflow/underflow handling beyond
/// the rounding itself; NaNs get the quiet bit forced so the payload
/// truncation can never produce an infinity.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE: add 0x7fff plus the LSB of the kept part (round half to even).
    (((bits).wrapping_add(0x7fff + ((bits >> 16) & 1))) >> 16) as u16
}

/// Widen bfloat16 bits to f32 (exact: bf16 is a truncated f32).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Narrow one f32 to the given storage precision's bit pattern. For
/// `F32` this is a plain transmute of the low half — callers never store
/// f32 through this path, but keeping the arm total keeps match sites
/// simple; debug builds assert it is unreachable in kernels.
pub fn narrow(prec: Precision, x: f32) -> u16 {
    match prec {
        Precision::F32 => {
            debug_assert!(false, "narrow(F32) has no packed representation");
            0
        }
        Precision::F16 => f32_to_f16_bits(x),
        Precision::Bf16 => f32_to_bf16_bits(x),
    }
}

/// Widen one packed bit pattern of the given precision to f32.
pub fn widen(prec: Precision, h: u16) -> f32 {
    match prec {
        Precision::F32 => {
            debug_assert!(false, "widen(F32) has no packed representation");
            0.0
        }
        Precision::F16 => f16_bits_to_f32(h),
        Precision::Bf16 => bf16_bits_to_f32(h),
    }
}

/// Quantize an f32 value through a storage precision and back: the exact
/// value a kernel sees after a narrow-on-store / widen-on-load round
/// trip. Identity for `F32`.
pub fn quantize(prec: Precision, x: f32) -> f32 {
    match prec {
        Precision::F32 => x,
        Precision::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
        Precision::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F32, Precision::F16, Precision::Bf16] {
            assert_eq!(Precision::parse(p.as_str()), Some(p));
        }
        assert_eq!(Precision::parse("f64"), None);
        assert_eq!(Precision::default(), Precision::F32);
        assert_eq!(Precision::F16.bytes(), 2);
        assert_eq!(Precision::F32.bytes(), 4);
    }

    #[test]
    fn f16_widen_narrow_is_identity_for_all_65536_patterns() {
        // Every f16 bit pattern widens exactly and must narrow back to
        // itself — except signalling NaNs, which quieten (hardware
        // semantics). Exhaustive: 65536 cases.
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(x);
            let exp = (h >> 10) & 0x1f;
            let man = h & 0x3ff;
            if exp == 0x1f && man != 0 {
                // NaN: must stay NaN with the sign and payload top bits;
                // the quiet bit is forced.
                assert!(x.is_nan());
                assert_eq!(back & 0x8000, h & 0x8000, "sign lost for {h:#06x}");
                assert_eq!(back & 0x7c00, 0x7c00, "NaN collapsed for {h:#06x}");
                assert_ne!(back & 0x03ff, 0, "NaN became inf for {h:#06x}");
            } else {
                assert_eq!(back, h, "round-trip failed for {h:#06x}");
            }
        }
    }

    #[test]
    fn bf16_widen_narrow_is_identity_for_all_65536_patterns() {
        for h in 0..=u16::MAX {
            let x = bf16_bits_to_f32(h);
            let back = f32_to_bf16_bits(x);
            let exp = (h >> 7) & 0xff;
            let man = h & 0x7f;
            if exp == 0xff && man != 0 {
                assert!(x.is_nan());
                assert_eq!(back & 0x8000, h & 0x8000);
                assert_eq!(back & 0x7f80, 0x7f80);
                assert_ne!(back & 0x007f, 0);
            } else {
                assert_eq!(back, h, "round-trip failed for {h:#06x}");
            }
        }
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // overflow → inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        // Smallest f16 normal and subnormal.
        assert_eq!(f32_to_f16_bits(6.103_515_6e-5), 0x0400); // 2^-14
        assert_eq!(f32_to_f16_bits(5.960_464_5e-8), 0x0001); // 2^-24
        let q = f32_to_f16_bits(f32::NAN);
        assert_eq!(q & 0x7c00, 0x7c00);
        assert_ne!(q & 0x03ff, 0);
    }

    #[test]
    fn f16_rne_ties_round_to_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 (even mantissa) and
        // 1 + 2^-10 (odd): must round down to the even one.
        let tie_down = f32::from_bits(0x3f80_0000 | (1 << 12));
        assert_eq!(f32_to_f16_bits(tie_down), 0x3c00);
        // (1 + 2^-10) + 2^-11 is halfway between odd 0x3c01 and even
        // 0x3c02: must round up to the even one.
        let tie_up = f32::from_bits(0x3f80_0000 | (1 << 13) | (1 << 12));
        assert_eq!(f32_to_f16_bits(tie_up), 0x3c02);
        // Just below / above the tie break the obvious way.
        let below = f32::from_bits(0x3f80_0000 | ((1 << 12) - 1));
        assert_eq!(f32_to_f16_bits(below), 0x3c00);
        let above = f32::from_bits(0x3f80_0000 | ((1 << 12) + 1));
        assert_eq!(f32_to_f16_bits(above), 0x3c01);
    }

    #[test]
    fn f16_subnormal_rounding_and_flush() {
        // Halfway between 0 and the smallest subnormal flushes to zero
        // (even side), just above rounds to the subnormal.
        let half_min = 2.0f32.powi(-25);
        assert_eq!(f32_to_f16_bits(half_min), 0x0000);
        assert_eq!(f32_to_f16_bits(half_min * 1.0001), 0x0001);
        assert_eq!(f32_to_f16_bits(-half_min), 0x8000);
        // 1.5 * 2^-24 is halfway between subnormals 1 and 2: rounds to 2.
        assert_eq!(f32_to_f16_bits(1.5 * 2.0f32.powi(-24)), 0x0002);
        // 2.5 * 2^-24 is halfway between 2 and 3: rounds to even 2.
        assert_eq!(f32_to_f16_bits(2.5 * 2.0f32.powi(-24)), 0x0002);
        // Largest subnormal rounds up into the smallest normal when the
        // dropped bits say so: (1023.75) * 2^-24 → 0x0400.
        assert_eq!(f32_to_f16_bits(1023.75 * 2.0f32.powi(-24)), 0x0400);
        // Below half the smallest subnormal: zero.
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000);
    }

    #[test]
    fn bf16_known_values_and_ties() {
        assert_eq!(f32_to_bf16_bits(0.0), 0x0000);
        assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        // Tie at 1 + 2^-8: halfway between 0x3f80 (even) and 0x3f81 —
        // rounds to even (down).
        let tie_down = f32::from_bits(0x3f80_0000 | (1 << 15));
        assert_eq!(f32_to_bf16_bits(tie_down), 0x3f80);
        // Tie one ulp higher lands between odd 0x3f81 and even 0x3f82.
        let tie_up = f32::from_bits(0x3f80_0000 | (1 << 16) | (1 << 15));
        assert_eq!(f32_to_bf16_bits(tie_up), 0x3f82);
        // Overflow via rounding: largest f32 < inf rounds to bf16 inf.
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7f80);
        let q = f32_to_bf16_bits(f32::NAN);
        assert_eq!(q & 0x7f80, 0x7f80);
        assert_ne!(q & 0x007f, 0);
    }

    #[test]
    fn quantize_error_is_bounded_for_random_values() {
        // Relative quantization error is ≤ 2^-11 for f16 normals and
        // ≤ 2^-8 for bf16 — the per-element bounds the differential
        // harness builds on. Property-tested with replayable seeds.
        check(200, |g| {
            let x = g.f32_in(-1000.0, 1000.0);
            if x.abs() > 6.2e-5 {
                let rel16 = ((quantize(Precision::F16, x) - x) / x).abs();
                assert!(rel16 <= 4.9e-4, "f16 rel err {rel16} for {x}");
            }
            if x != 0.0 {
                let relb = ((quantize(Precision::Bf16, x) - x) / x).abs();
                assert!(relb <= 4.0e-3, "bf16 rel err {relb} for {x}");
            }
            assert_eq!(quantize(Precision::F32, x), x);
        });
    }

    #[test]
    fn narrow_widen_dispatch_matches_direct_calls() {
        check(100, |g| {
            let x = g.f32_in(-100.0, 100.0);
            assert_eq!(narrow(Precision::F16, x), f32_to_f16_bits(x));
            assert_eq!(narrow(Precision::Bf16, x), f32_to_bf16_bits(x));
            let h = narrow(Precision::F16, x);
            assert_eq!(widen(Precision::F16, h).to_bits(), f16_bits_to_f32(h).to_bits());
            let b = narrow(Precision::Bf16, x);
            assert_eq!(widen(Precision::Bf16, b).to_bits(), bf16_bits_to_f32(b).to_bits());
        });
    }
}
