//! Hand-rolled bench harness (criterion is not in the offline crate set).
//!
//! Used by every `rust/benches/*.rs` target: warms up, runs timed
//! iterations until a wall-clock budget or iteration cap is reached, and
//! reports median/mean/p95 latency. Emits both a human table and JSON
//! lines (for EXPERIMENTS.md extraction).

use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("median_ms", Json::Num(self.median_s * 1e3)),
            ("mean_ms", Json::Num(self.mean_s * 1e3)),
            ("p95_ms", Json::Num(self.p95_s * 1e3)),
            ("min_ms", Json::Num(self.min_s * 1e3)),
        ])
    }
}

/// Bench configuration: bounded by both iterations and wall clock.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub min_iters: usize,
    pub budget: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            max_iters: 200,
            min_iters: 5,
            budget: Duration::from_millis(1500),
        }
    }
}

impl BenchOpts {
    /// Scale budgets down when `PFP_BENCH_FAST=1` (CI smoke runs).
    pub fn from_env() -> Self {
        let mut o = Self::default();
        if std::env::var("PFP_BENCH_FAST").as_deref() == Ok("1") {
            o.warmup_iters = 1;
            o.max_iters = 20;
            o.min_iters = 2;
            o.budget = Duration::from_millis(300);
        }
        o
    }
}

/// Time `f` repeatedly; returns robust latency statistics.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < opts.max_iters
        && (samples.len() < opts.min_iters || start.elapsed() < opts.budget)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        p95_s: stats::percentile(&samples, 95.0),
        min_s: stats::min(&samples),
    }
}

/// Pretty-print a results table with a title, plus JSON lines.
pub fn report(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<52} {:>10} {:>10} {:>10} {:>7}",
        "case", "median", "mean", "p95", "iters"
    );
    for r in results {
        println!(
            "{:<52} {:>8.3}ms {:>8.3}ms {:>8.3}ms {:>7}",
            r.name,
            r.median_s * 1e3,
            r.mean_s * 1e3,
            r.p95_s * 1e3,
            r.iters
        );
    }
    for r in results {
        println!("JSON {}", r.to_json().dump());
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let opts = BenchOpts {
            warmup_iters: 1,
            max_iters: 10,
            min_iters: 3,
            budget: Duration::from_millis(50),
        };
        let mut n = 0usize;
        let r = bench("noop", opts, || n += 1);
        assert!(r.iters >= 3 && r.iters <= 10);
        assert_eq!(n, r.iters + 1); // + warmup
        assert!(r.median_s >= 0.0);
    }
}
