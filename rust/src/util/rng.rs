//! SplitMix64 PRNG — mirrored draw-for-draw with
//! `python/compile/data.py::SplitMix64` so the synthetic dataset generator
//! produces the same streams in both languages.
//!
//! Also provides Box-Muller Gaussian sampling (cosine branch only, keeping
//! the draw count deterministic — two uniforms per normal) used by the SVI
//! weight sampler and the Eq. 11 logit sampler.

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;

#[inline(always)]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(MIX1);
    z = (z ^ (z >> 27)).wrapping_mul(MIX2);
    z ^ (z >> 31)
}

/// SplitMix64 PRNG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        mix(self.state)
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa (f32-exact; identical
    /// to the Python generator).
    #[inline(always)]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 40) as f64 / (1u64 << 24) as f64
    }

    /// Uniform integer in `[0, n)` (modulo; bias negligible for small n —
    /// and identical to the Python side, which is what matters here).
    #[inline(always)]
    pub fn randint(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Standard normal via Box-Muller, cosine branch (2 uniform draws).
    #[inline(always)]
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.uniform();
        let u2 = self.uniform();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with `mu + sigma * z`, `z ~ N(0,1)`.
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        for v in out.iter_mut() {
            *v = mu + sigma * self.normal() as f32;
        }
    }
}

/// Per-sample seed derivation — mirrors `data.derive_seed`.
pub fn derive_seed(base: u64, stream: u64, index: u64) -> u64 {
    let mixed = base
        ^ stream.wrapping_mul(0x9E37_79B1)
        ^ index.wrapping_mul(0x85EB_CA77);
    SplitMix64::new(mixed).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values_seed_zero() {
        // Same pinned constants as python/tests/test_data.py.
        let mut rng = SplitMix64::new(0);
        assert_eq!(rng.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(rng.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(rng.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = SplitMix64::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = SplitMix64::new(99);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn derive_seed_streams_differ() {
        let seeds: std::collections::HashSet<u64> =
            (1..6).map(|s| derive_seed(2025, s, 0)).collect();
        assert_eq!(seeds.len(), 5);
    }

    #[test]
    fn deterministic_sequences() {
        let mut a = SplitMix64::new(123);
        let mut b = SplitMix64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
