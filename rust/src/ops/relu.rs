//! PFP ReLU — Gaussian moment matching (Eqs. 8, 9).
//!
//! Consumes (mean, variance); produces (mean, **second raw moment**) — the
//! paper's activation-function representation contract. Elementwise, but
//! erf + exp per element make it a real cost center at runtime (Fig. 6
//! shows LeNet's first ReLU costing more than its first conv).
//!
//! The cdf/pdf sub-terms are computed once and shared between the two
//! outputs (the joint-operator rule applied to an elementwise op).
//!
//! Every entry point takes an [`Isa`]: `Native` runs the vectorized
//! erf/exp kernels from [`ops::simd`](super::simd) (AVX2+FMA / NEON,
//! runtime-detected — this is where the SIMD layer pays off most, the op
//! is pure transcendental math); `Scalar` keeps the historical per-element
//! loop bit for bit. Within one ISA every partition of the element range
//! is bit-identical to the serial pass (elementwise, and the vector kernel
//! is position-independent: tails run through padded lanes of the same
//! code).

use crate::tensor::{ProbTensor, Rep, Tensor};
use crate::util::threadpool::{self, DisjointMut, ThreadPool};

use super::erf::{erf, FRAC_1_SQRT_2, INV_SQRT_2PI};
use super::simd::{self, Backend, Isa};

const EPS: f32 = 1e-12;

/// Scalar moment-matched ReLU: (mu, var) -> (mu', E[x'^2]).
#[inline(always)]
pub fn relu_moments(mu: f32, var: f32) -> (f32, f32) {
    let var = var.max(EPS);
    let std = var.sqrt();
    let cdf = 0.5 * (1.0 + erf(mu / std * FRAC_1_SQRT_2));
    let pdf = std * INV_SQRT_2PI * (-(mu * mu) / (2.0 * var)).exp();
    let m = mu * cdf + pdf;
    let e2 = ((var + mu * mu) * cdf + mu * pdf).max(0.0);
    (m, e2)
}

/// Moment-matched ReLU over a probabilistic activation tensor.
/// Input rep must be `Var` (converted by the caller/executor); output rep
/// is `E2` by construction.
pub fn pfp_relu(input: ProbTensor, threads: usize, isa: Isa) -> ProbTensor {
    pfp_relu_in(threadpool::global(), input, threads, isa)
}

/// Fused elementwise epilogue applied by the dense/conv microkernels on
/// their freshly-computed (mu, var) output tile, while it is still
/// cache-hot — the plan's fusion lowering (PR 8) collapses a
/// `compute → pfp_relu (→ Convert)` chain into a single step carrying one
/// of these.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Epilogue {
    /// Plain compute step — no fused elementwise chain.
    #[default]
    None,
    /// Moment-matched ReLU (Eqs. 8/9): the tile's aux plane changes
    /// meaning from variance to **E\[x'^2\]**, exactly like a standalone
    /// `pfp_relu` step.
    Relu,
    /// ReLU plus the E2→Var conversion the next consumer (max-pool or the
    /// network output) would otherwise need as a separate `Convert@…`
    /// step: the aux plane stays a **variance**.
    ReluToVar,
}

/// Fixed stack-buffer chunk for the in-place SIMD epilogue.
/// A multiple of every vector width (8 for AVX2, 4 for NEON), so chunking
/// never moves an element between a full lane and the kernel's tail path —
/// per element the fused epilogue is bit-identical to the standalone
/// `pfp_relu_rows_into` pass on the same ISA.
const EPILOGUE_CHUNK: usize = 64;

/// Apply `ep` in place on one output tile: `mu`/`aux` hold the compute
/// step's (mean, variance) planes and are overwritten with the ReLU'd
/// moments (`aux` becomes E\[x'^2\], or stays a variance for
/// [`Epilogue::ReluToVar`]). Allocation-free: the SIMD kernels take
/// separate in/out slices, so the in-place form round-trips through
/// fixed-size stack chunks.
pub fn apply_epilogue(ep: Epilogue, isa: Isa, mu: &mut [f32], aux: &mut [f32]) {
    if ep == Epilogue::None {
        return;
    }
    debug_assert_eq!(mu.len(), aux.len());
    let to_var = ep == Epilogue::ReluToVar;
    let b = simd::resolve(isa);
    if b == Backend::Scalar {
        for (m, a) in mu.iter_mut().zip(aux.iter_mut()) {
            let (rm, re2) = relu_moments(*m, *a);
            *m = rm;
            // E2→Var fold: same arithmetic as `convert_in_place` on the
            // unfused path, so scalar fused == scalar unfused bit for bit
            *a = if to_var { (re2 - rm * rm).max(0.0) } else { re2 };
        }
    } else {
        let mut tm = [0.0f32; EPILOGUE_CHUNK];
        let mut te = [0.0f32; EPILOGUE_CHUNK];
        let n = mu.len();
        let mut i = 0;
        while i < n {
            let end = (i + EPILOGUE_CHUNK).min(n);
            let len = end - i;
            simd::relu_moments_into(b, &mu[i..end], &aux[i..end], &mut tm[..len], &mut te[..len]);
            mu[i..end].copy_from_slice(&tm[..len]);
            if to_var {
                for j in 0..len {
                    aux[i + j] = (te[j] - tm[j] * tm[j]).max(0.0);
                }
            } else {
                aux[i..end].copy_from_slice(&te[..len]);
            }
            i = end;
        }
    }
}

/// One tile of the moment-matched ReLU: elements `r` of the input, into
/// chunk-relative output slices. Elementwise, so any partition is
/// bit-identical to the serial pass (within one ISA). Allocation-free.
pub fn pfp_relu_rows_into(
    isa: Isa,
    mu_in: &[f32],
    var_in: &[f32],
    r: std::ops::Range<usize>,
    mu_out: &mut [f32],
    e2_out: &mut [f32],
) {
    debug_assert_eq!(mu_out.len(), r.end - r.start);
    debug_assert_eq!(e2_out.len(), r.end - r.start);
    let b = simd::resolve(isa);
    if b == Backend::Scalar {
        for (j, i) in r.enumerate() {
            let (m, e2) = relu_moments(mu_in[i], var_in[i]);
            mu_out[j] = m;
            e2_out[j] = e2;
        }
    } else {
        simd::relu_moments_into(b, &mu_in[r.start..r.end], &var_in[r.start..r.end], mu_out, e2_out);
    }
}

/// Planned-tile moment-matched ReLU: the element ranges were
/// pre-partitioned at plan time and are gang-dispatched onto the pool
/// with zero heap allocation ([`ThreadPool::run_tasks`]); with zero or
/// one tile this is the serial pass, and every partition is bit-identical
/// to it (elementwise).
pub fn pfp_relu_tiled_into(
    pool: &ThreadPool,
    isa: Isa,
    mu_in: &[f32],
    var_in: &[f32],
    tiles: &[std::ops::Range<usize>],
    mu_out: &mut [f32],
    e2_out: &mut [f32],
) {
    let n = mu_in.len();
    debug_assert_eq!(var_in.len(), n);
    debug_assert_eq!(mu_out.len(), n);
    debug_assert_eq!(e2_out.len(), n);
    if tiles.len() <= 1 {
        pfp_relu_rows_into(isa, mu_in, var_in, 0..n, mu_out, e2_out);
        return;
    }
    let mu = DisjointMut::new(mu_out);
    let e2 = DisjointMut::new(e2_out);
    pool.run_tasks(tiles.len(), &|ti| {
        let r = tiles[ti].clone();
        let len = r.end - r.start;
        // SAFETY: tiles are disjoint element ranges; run_tasks blocks
        // until every tile completes.
        let (mc, ec) = unsafe { (mu.slice(r.start, len), e2.slice(r.start, len)) };
        pfp_relu_rows_into(isa, mu_in, var_in, r, mc, ec);
    });
}

/// Slice-level moment-matched ReLU: reads (mean, variance), writes
/// (mean, E\[x^2\]) into caller-provided buffers. Allocation-free when
/// `threads <= 1`; `threads > 1` is the boxed scope path used by the
/// Tensor-level API (the compiled plan uses [`pfp_relu_tiled_into`]).
pub fn pfp_relu_into(
    pool: &ThreadPool,
    isa: Isa,
    mu_in: &[f32],
    var_in: &[f32],
    threads: usize,
    mu_out: &mut [f32],
    e2_out: &mut [f32],
) {
    let n = mu_in.len();
    debug_assert_eq!(var_in.len(), n);
    debug_assert_eq!(mu_out.len(), n);
    debug_assert_eq!(e2_out.len(), n);

    if threads <= 1 {
        pfp_relu_rows_into(isa, mu_in, var_in, 0..n, mu_out, e2_out);
    } else {
        // split both output buffers into matching disjoint chunks
        let ranges = crate::util::threadpool::split_ranges(n, threads);
        let mut mu_rest: &mut [f32] = mu_out;
        let mut e2_rest: &mut [f32] = e2_out;
        let mut chunks = Vec::new();
        for r in ranges {
            let take = r.end - r.start;
            let (mh, mt) = mu_rest.split_at_mut(take);
            let (eh, et) = e2_rest.split_at_mut(take);
            chunks.push((r, mh, eh));
            mu_rest = mt;
            e2_rest = et;
        }
        pool.scope(|s| {
            for (r, mc, ec) in chunks {
                s.spawn(move || pfp_relu_rows_into(isa, mu_in, var_in, r, mc, ec));
            }
        });
    }
}

/// [`pfp_relu`] on an explicit pool.
pub fn pfp_relu_in(pool: &ThreadPool, input: ProbTensor, threads: usize, isa: Isa) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let shape = input.mu.shape().to_vec();
    let mu_in = input.mu.into_data();
    let var_in = input.aux.into_data();
    let n = mu_in.len();
    let mut mu_out = vec![0.0f32; n];
    let mut e2_out = vec![0.0f32; n];
    pfp_relu_into(pool, isa, &mu_in, &var_in, threads, &mut mu_out, &mut e2_out);
    ProbTensor::new(
        Tensor::new(shape.clone(), mu_out).unwrap(),
        Tensor::new(shape, e2_out).unwrap(),
        Rep::E2,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    #[test]
    fn deterministic_limit() {
        // var -> 0: (mu, e2) -> (max(mu,0), max(mu,0)^2)
        for mu in [-3.0f32, -0.5, 0.0, 0.5, 3.0] {
            let (m, e2) = relu_moments(mu, 1e-10);
            let want = mu.max(0.0);
            assert!((m - want).abs() < 1e-4, "mu={mu}: {m} vs {want}");
            assert!((e2 - want * want).abs() < 1e-4);
        }
    }

    #[test]
    fn against_monte_carlo() {
        let cases = [(-2.0f32, 0.5f32), (-0.5, 1.0), (0.0, 2.0), (0.7, 0.3), (3.0, 1.5)];
        let mut rng = SplitMix64::new(42);
        for (mu, var) in cases {
            let n = 200_000;
            let std = var.sqrt();
            let (mut s, mut s2) = (0.0f64, 0.0f64);
            for _ in 0..n {
                let z = (mu as f64 + std as f64 * rng.normal()).max(0.0);
                s += z;
                s2 += z * z;
            }
            let (m, e2) = relu_moments(mu, var);
            assert!(
                (m as f64 - s / n as f64).abs() < 2e-2,
                "mean mismatch mu={mu} var={var}: {m} vs {}",
                s / n as f64
            );
            assert!(
                (e2 as f64 - s2 / n as f64).abs() < 6e-2,
                "e2 mismatch mu={mu} var={var}"
            );
        }
    }

    #[test]
    fn jensen_inequality_holds() {
        check(30, |g| {
            let mu = g.normal(3.0);
            let var = g.normal(2.0).abs() + 1e-6;
            let (m, e2) = relu_moments(mu, var);
            assert!(e2 - m * m >= -1e-4, "E[x^2] < E[x]^2 at mu={mu} var={var}");
            assert!(m >= 0.0, "ReLU mean must be non-negative");
        });
    }

    #[test]
    fn mean_bounded_below_by_relu_of_mean() {
        // E[max(0,X)] >= max(0, E[X]) by Jensen (max is convex).
        check(30, |g| {
            let mu = g.normal(2.0);
            let var = g.normal(1.0).abs() + 1e-6;
            let (m, _) = relu_moments(mu, var);
            assert!(m >= mu.max(0.0) - 1e-5);
        });
    }

    #[test]
    fn tiled_relu_bit_identical_to_serial_per_isa() {
        use crate::util::threadpool::{split_ranges, ThreadPool};
        let pool = ThreadPool::new(3);
        let mut g = crate::util::prop::Gen::new(17);
        let n = 501;
        let mu: Vec<f32> = g.normal_vec(n, 2.0);
        let var: Vec<f32> = g.var_vec(n, 1.0);
        for isa in [Isa::Scalar, Isa::Native] {
            let mut want_mu = vec![0.0f32; n];
            let mut want_e2 = vec![0.0f32; n];
            pfp_relu_rows_into(isa, &mu, &var, 0..n, &mut want_mu, &mut want_e2);
            for tasks in [2usize, 3, 8] {
                let tiles = split_ranges(n, tasks);
                let mut got_mu = vec![0.0f32; n];
                let mut got_e2 = vec![0.0f32; n];
                pfp_relu_tiled_into(&pool, isa, &mu, &var, &tiles, &mut got_mu, &mut got_e2);
                assert_eq!(got_mu, want_mu, "{isa:?} tasks={tasks}");
                assert_eq!(got_e2, want_e2, "{isa:?} tasks={tasks}");
            }
        }
    }

    #[test]
    fn native_isa_matches_scalar_closely() {
        // cross-ISA contract on the op level: <= 1e-4 relative
        let mut g = crate::util::prop::Gen::new(23);
        let n = 777;
        let mu: Vec<f32> = g.normal_vec(n, 2.0);
        let var: Vec<f32> = g.var_vec(n, 1.0);
        let mut s_mu = vec![0.0f32; n];
        let mut s_e2 = vec![0.0f32; n];
        let mut n_mu = vec![0.0f32; n];
        let mut n_e2 = vec![0.0f32; n];
        pfp_relu_rows_into(Isa::Scalar, &mu, &var, 0..n, &mut s_mu, &mut s_e2);
        pfp_relu_rows_into(Isa::Native, &mu, &var, 0..n, &mut n_mu, &mut n_e2);
        for i in 0..n {
            assert!(
                (s_mu[i] - n_mu[i]).abs() <= 1e-5 + 1e-4 * s_mu[i].abs(),
                "mu[{i}]: {} vs {}",
                n_mu[i],
                s_mu[i]
            );
            assert!(
                (s_e2[i] - n_e2[i]).abs() <= 1e-5 + 1e-4 * s_e2[i].abs(),
                "e2[{i}]: {} vs {}",
                n_e2[i],
                s_e2[i]
            );
        }
    }

    #[test]
    fn epilogue_matches_standalone_relu_then_convert_per_isa() {
        // the fused in-place epilogue must reproduce the unfused
        // relu(+convert) chain exactly, per ISA: the 64-element chunking
        // is lane-aligned so no element changes code path (odd length
        // exercises the final partial chunk)
        let mut g = crate::util::prop::Gen::new(31);
        let n = 501;
        let mu: Vec<f32> = g.normal_vec(n, 2.0);
        let var: Vec<f32> = g.var_vec(n, 1.0);
        for isa in [Isa::Scalar, Isa::Native] {
            let mut want_mu = vec![0.0f32; n];
            let mut want_e2 = vec![0.0f32; n];
            pfp_relu_rows_into(isa, &mu, &var, 0..n, &mut want_mu, &mut want_e2);
            let mut got_mu = mu.clone();
            let mut got_e2 = var.clone();
            apply_epilogue(Epilogue::Relu, isa, &mut got_mu, &mut got_e2);
            assert_eq!(got_mu, want_mu, "{isa:?} fused relu mu");
            assert_eq!(got_e2, want_e2, "{isa:?} fused relu e2");

            // ReluToVar additionally folds the E2→Var conversion the
            // executor's convert step would apply on the relu'd moments
            let want_var: Vec<f32> = want_e2
                .iter()
                .zip(&want_mu)
                .map(|(&e2, &m)| (e2 - m * m).max(0.0))
                .collect();
            let mut got_mu = mu.clone();
            let mut got_var = var.clone();
            apply_epilogue(Epilogue::ReluToVar, isa, &mut got_mu, &mut got_var);
            assert_eq!(got_mu, want_mu, "{isa:?} fused relu+convert mu");
            assert_eq!(got_var, want_var, "{isa:?} fused relu+convert var");
        }
    }

    #[test]
    fn none_epilogue_is_identity() {
        let mut mu = vec![1.0f32, -2.0, 3.0];
        let mut aux = vec![0.5f32, 0.25, 4.0];
        apply_epilogue(Epilogue::None, Isa::Native, &mut mu, &mut aux);
        assert_eq!(mu, vec![1.0, -2.0, 3.0]);
        assert_eq!(aux, vec![0.5, 0.25, 4.0]);
    }

    #[test]
    fn tensor_op_parallel_matches_serial() {
        let mut g = crate::util::prop::Gen::new(7);
        let n = 1000;
        let mu = Tensor::from_vec(g.normal_vec(n, 2.0));
        let var = Tensor::from_vec(g.var_vec(n, 1.0));
        for isa in [Isa::Scalar, Isa::Native] {
            let a = pfp_relu(ProbTensor::new(mu.clone(), var.clone(), Rep::Var), 1, isa);
            let b = pfp_relu(ProbTensor::new(mu.clone(), var.clone(), Rep::Var), 4, isa);
            assert!(a.mu.allclose(&b.mu, 1e-7, 1e-7));
            assert!(a.aux.allclose(&b.aux, 1e-7, 1e-7));
            assert_eq!(a.rep, Rep::E2);
        }
    }
}
