//! PFP conv2d — Eq. 12's moment algebra over image patches.
//!
//! Lowered to the *same* scheduled joint dense kernel as the fully
//! connected layer via im2col (exactly like the Pallas kernel in
//! `python/compile/kernels/conv.py`), so conv inherits every schedule knob
//! — the explicit-SIMD `isa` knob included: a `Native` schedule runs the
//! fused im2col+dense phase's per-patch reductions on the AVX2/NEON
//! microkernels of [`ops::simd`](super::simd), with the im2col gather and
//! col2im scatter staying `copy_from_slice` memory moves. A direct
//! (no-im2col) implementation is kept for the ablation bench.
//!
//! Layout: activations NCHW, weights OIHW, padding VALID, stride 1 (all
//! the paper's LeNet-5 needs).

use crate::tensor::{ProbTensor, Rep, Tensor};
use crate::util::threadpool::{self, DisjointMut, ThreadPool};

use super::dense::{
    dense_kernel_into, dense_rows_into, dense_rows_packed_into, Accum, DenseSlices, FirstLayer,
    JointEq12, PackedDenseSlices,
};
use super::relu::Epilogue;
use super::schedule::Schedule;
use super::simd::PackedSlice;

/// Static conv workload description (NCHW input, OIHW weights, VALID
/// padding, stride 1). The compiled plan resolves one of these per conv
/// step at plan time so execution never re-derives shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    /// batch
    pub n: usize,
    /// input channels
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// output channels
    pub o: usize,
    pub kh: usize,
    pub kw: usize,
}

impl ConvShape {
    pub fn oh(&self) -> usize {
        self.h - self.kh + 1
    }

    pub fn ow(&self) -> usize {
        self.w - self.kw + 1
    }

    /// im2col patch rows: `N * OH * OW`.
    pub fn rows(&self) -> usize {
        self.n * self.oh() * self.ow()
    }

    /// im2col patch width (the dense reduction length): `C * kh * kw`.
    pub fn kk(&self) -> usize {
        self.c * self.kh * self.kw
    }

    pub fn in_len(&self) -> usize {
        self.n * self.c * self.h * self.w
    }

    pub fn out_len(&self) -> usize {
        self.n * self.o * self.oh() * self.ow()
    }

    /// Scratch floats [`conv_kernel_into`] needs: one or two im2col patch
    /// matrices (`shared_aux` = the Eq. 13 first layer, whose aux operand
    /// is ignored and aliases the mean patches) plus the two pre-scatter
    /// dense outputs.
    pub fn scratch_len(&self, shared_aux: bool) -> usize {
        let patches = self.rows() * self.kk();
        let outs = self.rows() * self.o;
        patches * if shared_aux { 1 } else { 2 } + 2 * outs
    }
}

/// im2col for patch rows `rows` only, into a caller-provided
/// `[rows.len(), C*kh*kw]` chunk (chunk-relative row indexing) — one
/// planned conv tile's gather phase. Patch rows are independent, so any
/// row partition writes exactly the bytes the full [`im2col_into`] would.
pub fn im2col_rows_into(
    d: &[f32],
    sh: &ConvShape,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let (c, h, w, kh, kw) = (sh.c, sh.h, sh.w, sh.kh, sh.kw);
    let (oh, ow) = (sh.oh(), sh.ow());
    let kk = sh.kk();
    debug_assert_eq!(d.len(), sh.in_len());
    debug_assert_eq!(out.len(), (rows.end - rows.start) * kk);
    for (local, prow) in rows.enumerate() {
        let img = prow / (oh * ow);
        let rem = prow % (oh * ow);
        let (oy, ox) = (rem / ow, rem % ow);
        let row = local * kk;
        let mut col = 0;
        for ch in 0..c {
            let plane = (img * c + ch) * h * w;
            for dy in 0..kh {
                let src = plane + (oy + dy) * w + ox;
                out[row + col..row + col + kw].copy_from_slice(&d[src..src + kw]);
                col += kw;
            }
        }
    }
}

/// im2col into a caller-provided `[N*OH*OW, C*kh*kw]` buffer.
pub fn im2col_into(d: &[f32], sh: &ConvShape, out: &mut [f32]) {
    im2col_rows_into(d, sh, 0..sh.rows(), out);
}

/// im2col: `[N, C, H, W]` -> (`[N*OH*OW, C*kh*kw]`, (n, oh, ow)).
pub fn im2col(x: &Tensor, kh: usize, kw: usize) -> (Tensor, (usize, usize, usize)) {
    let s = x.shape();
    let sh = ConvShape {
        n: s[0],
        c: s[1],
        h: s[2],
        w: s[3],
        o: 0,
        kh,
        kw,
    };
    let kk = sh.kk();
    let mut out = vec![0.0f32; sh.rows() * kk];
    im2col_into(x.data(), &sh, &mut out);
    (
        Tensor::new(vec![sh.rows(), kk], out).unwrap(),
        (sh.n, sh.oh(), sh.ow()),
    )
}

/// Scatter the output planes `planes` (plane `p` = image `p / O`, channel
/// `p % O`) of a `[N*OH*OW, O]` matrix back to NCHW, into a
/// caller-provided chunk covering exactly those planes — one planned conv
/// tile's scatter phase. Planes are contiguous in the NCHW output, so a
/// plane partition maps to disjoint contiguous output chunks.
pub fn col2im_planes_into(
    d: &[f32],
    oh: usize,
    ow: usize,
    o: usize,
    planes: std::ops::Range<usize>,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (planes.end - planes.start) * oh * ow);
    for (local, p) in planes.enumerate() {
        let (img, ch) = (p / o, p % o);
        let obase = local * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                out[obase + oy * ow + ox] = d[((img * oh + oy) * ow + ox) * o + ch];
            }
        }
    }
}

/// Scatter `[N*OH*OW, O]` back to NCHW `[N, O, OH, OW]`, into a
/// caller-provided buffer.
fn col2im_into(d: &[f32], n: usize, oh: usize, ow: usize, o: usize, out: &mut [f32]) {
    debug_assert_eq!(d.len(), n * oh * ow * o);
    col2im_planes_into(d, oh, ow, o, 0..n * o, out);
}

/// Slice-level conv kernel: im2col -> scheduled joint dense -> col2im,
/// entirely within caller-provided scratch/output buffers (the plan's
/// zero-allocation conv step). `x_aux = None` is the Eq. 13 first layer:
/// its aux operand is ignored by the [`FirstLayer`] accumulator, so the
/// mean patches are passed for both operands and the interpreter's
/// explicit `squared()` pass is folded away. Weight matrices are the
/// OIHW tensors viewed flat as `[O, C*kh*kw]` (identical memory layout).
#[allow(clippy::too_many_arguments)]
pub fn conv_kernel_into<A: Accum>(
    pool: &ThreadPool,
    sh: &ConvShape,
    x_mu: &[f32],
    x_aux: Option<&[f32]>,
    w_mu: &[f32],
    w_aux: &[f32],
    b_mu: Option<&[f32]>,
    b_var: Option<&[f32]>,
    sched: &Schedule,
    scratch: &mut [f32],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let rows = sh.rows();
    let kk = sh.kk();
    debug_assert!(scratch.len() >= sh.scratch_len(x_aux.is_none()));
    let (pm, rest) = scratch.split_at_mut(rows * kk);
    im2col_into(x_mu, sh, pm);
    let (pa, rest) = match x_aux {
        Some(aux) => {
            let (pa, rest) = rest.split_at_mut(rows * kk);
            im2col_into(aux, sh, pa);
            (&*pa, rest)
        }
        None => (&*pm, rest),
    };
    let (cm, rest) = rest.split_at_mut(rows * sh.o);
    let (cv, _) = rest.split_at_mut(rows * sh.o);
    dense_kernel_into::<A>(
        pool,
        &DenseSlices {
            m: rows,
            k: kk,
            n: sh.o,
            x_mu: pm,
            x_aux: pa,
            w_mu,
            w_aux,
            b_mu,
            b_var,
        },
        sched,
        cm,
        cv,
    );
    col2im_into(cm, sh.n, sh.oh(), sh.ow(), sh.o, out_mu);
    col2im_into(cv, sh.n, sh.oh(), sh.ow(), sh.o, out_var);
}

/// Planned-tile conv kernel: the compiled plan's parallel conv step.
///
/// Two gang dispatches over the plan's pre-bound partitions, with zero
/// heap allocation end to end:
///
/// 1. **patch-row tiles** (`tiles`): each tile im2cols its own patch rows
///    into its disjoint chunk of the scratch patch matrices and runs the
///    serial dense kernel over exactly those rows — the tile only ever
///    reads patches it wrote itself, so the phase needs no barrier inside;
/// 2. **output-plane tiles** (`scatter_tiles`): each tile scatters a range
///    of NCHW output planes (contiguous in the output) from the shared
///    pre-scatter matrices.
///
/// Row/plane partitioning never touches the per-patch reduction order, so
/// the result is bit-identical to the serial [`conv_kernel_into`] with a
/// `threads = 1` schedule at any tile count. `x_aux = None` is the Eq. 13
/// first layer (aux patches alias the mean patches), as in
/// [`conv_kernel_into`].
///
/// A fused epilogue (`ep`, PR 8) is applied by [`dense_rows_into`] on
/// each tile's pre-scatter `[len, O]` chunk while it is cache-hot:
/// moment-matched ReLU(+convert) is elementwise, so it commutes with the
/// col2im plane permutation of phase 2.
#[allow(clippy::too_many_arguments)]
pub fn conv_kernel_tiled_into<A: Accum>(
    pool: &ThreadPool,
    sh: &ConvShape,
    x_mu: &[f32],
    x_aux: Option<&[f32]>,
    w_mu: &[f32],
    w_aux: &[f32],
    b_mu: Option<&[f32]>,
    b_var: Option<&[f32]>,
    sched: &Schedule,
    ep: Epilogue,
    tiles: &[std::ops::Range<usize>],
    scatter_tiles: &[std::ops::Range<usize>],
    scratch: &mut [f32],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let rows = sh.rows();
    let kk = sh.kk();
    let o = sh.o;
    let (oh, ow) = (sh.oh(), sh.ow());
    let serial = sched.with_threads(1);
    debug_assert!(scratch.len() >= sh.scratch_len(x_aux.is_none()));
    let (pm, rest) = scratch.split_at_mut(rows * kk);
    let (pa, rest) = match x_aux {
        Some(_) => {
            let (pa, rest) = rest.split_at_mut(rows * kk);
            (Some(pa), rest)
        }
        None => (None, rest),
    };
    let (cm, rest) = rest.split_at_mut(rows * o);
    let (cv, _) = rest.split_at_mut(rows * o);

    // phase 1: gather + reduce, partitioned by patch row
    let pm_parts = DisjointMut::new(pm);
    let pa_parts = pa.map(DisjointMut::new);
    let cm_parts = DisjointMut::new(cm);
    let cv_parts = DisjointMut::new(cv);
    let run_tile = |r: std::ops::Range<usize>| {
        let len = r.end - r.start;
        // SAFETY: patch-row tiles are disjoint, so every chunk below is
        // touched by exactly one tile; run_tasks blocks until all finish.
        let pm_chunk = unsafe { pm_parts.slice(r.start * kk, len * kk) };
        im2col_rows_into(x_mu, sh, r.clone(), pm_chunk);
        let pm_chunk: &[f32] = pm_chunk;
        let pa_chunk: &[f32] = match (x_aux, &pa_parts) {
            (Some(aux), Some(p)) => {
                // SAFETY: same disjoint patch-row tiles as `pm_chunk`.
                let chunk = unsafe { p.slice(r.start * kk, len * kk) };
                im2col_rows_into(aux, sh, r.clone(), chunk);
                chunk
            }
            // ignored-aux formulations (Eq. 13 / mean-only) alias the
            // mean patches instead of gathering twice
            _ => pm_chunk,
        };
        // SAFETY: per-tile output rows are disjoint (same tiles as above).
        let cm_chunk = unsafe { cm_parts.slice(r.start * o, len * o) };
        // SAFETY: per-tile output rows are disjoint (same tiles as above).
        let cv_chunk = unsafe { cv_parts.slice(r.start * o, len * o) };
        let args = DenseSlices {
            m: len,
            k: kk,
            n: o,
            x_mu: pm_chunk,
            x_aux: pa_chunk,
            w_mu,
            w_aux,
            b_mu,
            b_var,
        };
        dense_rows_into::<A>(&args, &serial, ep, 0..len, cm_chunk, cv_chunk);
    };
    if tiles.len() <= 1 {
        run_tile(0..rows);
    } else {
        pool.run_tasks(tiles.len(), &|ti| run_tile(tiles[ti].clone()));
    }

    // phase 2: scatter back to NCHW, partitioned by output plane
    if scatter_tiles.len() <= 1 {
        col2im_planes_into(cm, oh, ow, o, 0..sh.n * o, out_mu);
        col2im_planes_into(cv, oh, ow, o, 0..sh.n * o, out_var);
    } else {
        let plane_out = oh * ow;
        let mu_parts = DisjointMut::new(out_mu);
        let var_parts = DisjointMut::new(out_var);
        let cm_ref: &[f32] = cm;
        let cv_ref: &[f32] = cv;
        pool.run_tasks(scatter_tiles.len(), &|ti| {
            let p = scatter_tiles[ti].clone();
            let len = (p.end - p.start) * plane_out;
            // SAFETY: plane tiles are disjoint contiguous output chunks.
            let (mu_chunk, var_chunk) = unsafe {
                (
                    mu_parts.slice(p.start * plane_out, len),
                    var_parts.slice(p.start * plane_out, len),
                )
            };
            col2im_planes_into(cm_ref, oh, ow, o, p.clone(), mu_chunk);
            col2im_planes_into(cv_ref, oh, ow, o, p, var_chunk);
        });
    }
}

/// [`conv_kernel_tiled_into`] with packed weight operands — the compiled
/// plan's mixed-precision conv step. Only the per-patch reductions of
/// phase 1 touch the weights, so the packed twin swaps
/// [`dense_rows_into`] for [`dense_rows_packed_into`] and leaves the
/// im2col gather and col2im scatter (pure f32 memory moves) untouched.
/// The packed dense kernel is bitwise its f32 twin on pre-widened weight
/// copies, so this whole lowering inherits that contract.
#[allow(clippy::too_many_arguments)]
pub fn conv_kernel_packed_tiled_into<A: Accum>(
    pool: &ThreadPool,
    sh: &ConvShape,
    x_mu: &[f32],
    x_aux: Option<&[f32]>,
    w_mu: PackedSlice<'_>,
    w_aux: PackedSlice<'_>,
    b_mu: Option<&[f32]>,
    b_var: Option<&[f32]>,
    sched: &Schedule,
    ep: Epilogue,
    tiles: &[std::ops::Range<usize>],
    scatter_tiles: &[std::ops::Range<usize>],
    scratch: &mut [f32],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let rows = sh.rows();
    let kk = sh.kk();
    let o = sh.o;
    let (oh, ow) = (sh.oh(), sh.ow());
    let serial = sched.with_threads(1);
    debug_assert!(scratch.len() >= sh.scratch_len(x_aux.is_none()));
    let (pm, rest) = scratch.split_at_mut(rows * kk);
    let (pa, rest) = match x_aux {
        Some(_) => {
            let (pa, rest) = rest.split_at_mut(rows * kk);
            (Some(pa), rest)
        }
        None => (None, rest),
    };
    let (cm, rest) = rest.split_at_mut(rows * o);
    let (cv, _) = rest.split_at_mut(rows * o);

    // phase 1: gather + packed reduce, partitioned by patch row
    let pm_parts = DisjointMut::new(pm);
    let pa_parts = pa.map(DisjointMut::new);
    let cm_parts = DisjointMut::new(cm);
    let cv_parts = DisjointMut::new(cv);
    let run_tile = |r: std::ops::Range<usize>| {
        let len = r.end - r.start;
        // SAFETY: patch-row tiles are disjoint, so every chunk below is
        // touched by exactly one tile; run_tasks blocks until all finish.
        let pm_chunk = unsafe { pm_parts.slice(r.start * kk, len * kk) };
        im2col_rows_into(x_mu, sh, r.clone(), pm_chunk);
        let pm_chunk: &[f32] = pm_chunk;
        let pa_chunk: &[f32] = match (x_aux, &pa_parts) {
            (Some(aux), Some(p)) => {
                // SAFETY: same disjoint patch-row tiles as `pm_chunk`.
                let chunk = unsafe { p.slice(r.start * kk, len * kk) };
                im2col_rows_into(aux, sh, r.clone(), chunk);
                chunk
            }
            _ => pm_chunk,
        };
        // SAFETY: per-tile output rows are disjoint (same tiles as above).
        let cm_chunk = unsafe { cm_parts.slice(r.start * o, len * o) };
        // SAFETY: per-tile output rows are disjoint (same tiles as above).
        let cv_chunk = unsafe { cv_parts.slice(r.start * o, len * o) };
        let args = PackedDenseSlices {
            m: len,
            k: kk,
            n: o,
            x_mu: pm_chunk,
            x_aux: pa_chunk,
            w_mu,
            w_aux,
            b_mu,
            b_var,
        };
        dense_rows_packed_into::<A>(&args, &serial, ep, 0..len, cm_chunk, cv_chunk);
    };
    if tiles.len() <= 1 {
        run_tile(0..rows);
    } else {
        pool.run_tasks(tiles.len(), &|ti| run_tile(tiles[ti].clone()));
    }

    // phase 2: scatter back to NCHW, partitioned by output plane
    if scatter_tiles.len() <= 1 {
        col2im_planes_into(cm, oh, ow, o, 0..sh.n * o, out_mu);
        col2im_planes_into(cv, oh, ow, o, 0..sh.n * o, out_var);
    } else {
        let plane_out = oh * ow;
        let mu_parts = DisjointMut::new(out_mu);
        let var_parts = DisjointMut::new(out_var);
        let cm_ref: &[f32] = cm;
        let cv_ref: &[f32] = cv;
        pool.run_tasks(scatter_tiles.len(), &|ti| {
            let p = scatter_tiles[ti].clone();
            let len = (p.end - p.start) * plane_out;
            // SAFETY: plane tiles are disjoint contiguous output chunks.
            let (mu_chunk, var_chunk) = unsafe {
                (
                    mu_parts.slice(p.start * plane_out, len),
                    var_parts.slice(p.start * plane_out, len),
                )
            };
            col2im_planes_into(cm_ref, oh, ow, o, p.clone(), mu_chunk);
            col2im_planes_into(cv_ref, oh, ow, o, p, var_chunk);
        });
    }
}

/// Conv arguments: weights OIHW; aux follows the kernel's formulation
/// (E[w^2] for Eq. 12, weight variance for Eq. 13).
pub struct ConvArgs<'a> {
    pub w_mu: &'a Tensor,
    pub w_aux: &'a Tensor,
    pub b_mu: Option<&'a [f32]>,
    pub b_var: Option<&'a [f32]>,
}

fn conv_via_dense<A: Accum>(
    pool: &ThreadPool,
    x_mu: &Tensor,
    x_aux: Option<&Tensor>,
    args: &ConvArgs<'_>,
    sched: &Schedule,
) -> (Tensor, Tensor) {
    let xs = x_mu.shape();
    let ws = args.w_mu.shape();
    let sh = ConvShape {
        n: xs[0],
        c: xs[1],
        h: xs[2],
        w: xs[3],
        o: ws[0],
        kh: ws[2],
        kw: ws[3],
    };
    debug_assert_eq!(sh.c, ws[1]);
    let mut scratch = vec![0.0f32; sh.scratch_len(x_aux.is_none())];
    let mut out_mu = vec![0.0f32; sh.out_len()];
    let mut out_var = vec![0.0f32; sh.out_len()];
    conv_kernel_into::<A>(
        pool,
        &sh,
        x_mu.data(),
        x_aux.map(|t| t.data()),
        args.w_mu.data(),
        args.w_aux.data(),
        args.b_mu,
        args.b_var,
        sched,
        &mut scratch,
        &mut out_mu,
        &mut out_var,
    );
    let shape = vec![sh.n, sh.o, sh.oh(), sh.ow()];
    (
        Tensor::new(shape.clone(), out_mu).unwrap(),
        Tensor::new(shape, out_var).unwrap(),
    )
}

/// Joint PFP conv2d (Eq. 12): activation aux = E[x^2], weight aux = E[w^2].
/// Input rep `E2` -> output rep `Var`.
pub fn pfp_conv2d_joint(
    x: &ProbTensor,
    args: &ConvArgs<'_>,
    sched: &Schedule,
) -> ProbTensor {
    pfp_conv2d_joint_in(threadpool::global(), x, args, sched)
}

/// [`pfp_conv2d_joint`] on an explicit pool.
pub fn pfp_conv2d_joint_in(
    pool: &ThreadPool,
    x: &ProbTensor,
    args: &ConvArgs<'_>,
    sched: &Schedule,
) -> ProbTensor {
    debug_assert_eq!(x.rep, Rep::E2);
    let (mu, var) = conv_via_dense::<JointEq12>(pool, &x.mu, Some(&x.aux), args, sched);
    ProbTensor::new(mu, var, Rep::Var)
}

/// First-layer PFP conv2d (Eq. 13): deterministic input, weight aux =
/// weight variance.
pub fn pfp_conv2d_first(x: &Tensor, args: &ConvArgs<'_>, sched: &Schedule) -> ProbTensor {
    pfp_conv2d_first_in(threadpool::global(), x, args, sched)
}

/// [`pfp_conv2d_first`] on an explicit pool. The Eq. 13 accumulator
/// ignores its activation-aux operand, so no `squared()` pass is run —
/// the mean patches serve as both operands.
pub fn pfp_conv2d_first_in(
    pool: &ThreadPool,
    x: &Tensor,
    args: &ConvArgs<'_>,
    sched: &Schedule,
) -> ProbTensor {
    let (mu, var) = conv_via_dense::<FirstLayer>(pool, x, None, args, sched);
    ProbTensor::new(mu, var, Rep::Var)
}

/// Direct (no-im2col) joint conv — ablation reference for the im2col
/// lowering decision (DESIGN.md §ablations).
pub fn pfp_conv2d_direct(x: &ProbTensor, args: &ConvArgs<'_>) -> ProbTensor {
    debug_assert_eq!(x.rep, Rep::E2);
    let xs = x.shape();
    let (n, c, h, w) = (xs[0], xs[1], xs[2], xs[3]);
    let ws = args.w_mu.shape();
    let (o, _, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    let xm = x.mu.data();
    let xe = x.aux.data();
    let wm = args.w_mu.data();
    let we = args.w_aux.data();
    let mut out_mu = vec![0.0f32; n * o * oh * ow];
    let mut out_var = vec![0.0f32; n * o * oh * ow];
    for img in 0..n {
        for oc in 0..o {
            for oy in 0..oh {
                for ox in 0..ow {
                    let (mut mu, mut e2, mut cross) = (0.0f32, 0.0f32, 0.0f32);
                    for ic in 0..c {
                        let plane = (img * c + ic) * h * w;
                        let wplane = (oc * c + ic) * kh * kw;
                        for dy in 0..kh {
                            for dx in 0..kw {
                                let xi = plane + (oy + dy) * w + (ox + dx);
                                let wi = wplane + dy * kw + dx;
                                let t = xm[xi] * wm[wi];
                                mu += t;
                                cross += t * t;
                                e2 += xe[xi] * we[wi];
                            }
                        }
                    }
                    let oi = ((img * o + oc) * oh + oy) * ow + ox;
                    let b_mu = args.b_mu.map_or(0.0, |b| b[oc]);
                    let b_var = args.b_var.map_or(0.0, |b| b[oc]);
                    out_mu[oi] = mu + b_mu;
                    out_var[oi] = (e2 - cross + b_var).max(0.0);
                }
            }
        }
    }
    ProbTensor::new(
        Tensor::new(vec![n, o, oh, ow], out_mu).unwrap(),
        Tensor::new(vec![n, o, oh, ow], out_var).unwrap(),
        Rep::Var,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn rand_conv_case(
        g: &mut Gen,
    ) -> (ProbTensor, Tensor, Tensor, usize, usize, usize, usize, usize) {
        let n = g.usize_in(1, 3);
        let c = g.usize_in(1, 4);
        let o = g.usize_in(1, 6);
        let k = *g.pick(&[3usize, 5]);
        let hw = g.usize_in(k + 1, 14);
        let x_mu = Tensor::new(vec![n, c, hw, hw], g.normal_vec(n * c * hw * hw, 1.0)).unwrap();
        let x_var = Tensor::new(vec![n, c, hw, hw], g.var_vec(n * c * hw * hw, 0.5)).unwrap();
        let x_e2 = x_mu.zip(&x_var, |m, v| m * m + v).unwrap();
        let x = ProbTensor::new(x_mu, x_e2, Rep::E2);
        let w_mu = Tensor::new(vec![o, c, k, k], g.normal_vec(o * c * k * k, 0.2)).unwrap();
        let w_var = Tensor::new(vec![o, c, k, k], g.var_vec(o * c * k * k, 0.02)).unwrap();
        (x, w_mu, w_var, n, c, o, k, hw)
    }

    #[test]
    fn im2col_shapes_and_values() {
        // 1 image, 1 channel, 3x3, k=2 -> 4 patches of 4
        let x = Tensor::new(
            vec![1, 1, 3, 3],
            vec![1., 2., 3., 4., 5., 6., 7., 8., 9.],
        )
        .unwrap();
        let (p, (n, oh, ow)) = im2col(&x, 2, 2);
        assert_eq!((n, oh, ow), (1, 2, 2));
        assert_eq!(p.shape(), &[4, 4]);
        assert_eq!(p.row(0), &[1., 2., 4., 5.]);
        assert_eq!(p.row(3), &[5., 6., 8., 9.]);
    }

    #[test]
    fn im2col_dense_matches_direct() {
        check(8, |g| {
            let (x, w_mu, w_var, ..) = rand_conv_case(g);
            let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
            let args = ConvArgs {
                w_mu: &w_mu,
                w_aux: &w_e2,
                b_mu: None,
                b_var: None,
            };
            let a = pfp_conv2d_joint(&x, &args, &Schedule::tuned(1));
            let b = pfp_conv2d_direct(&x, &args);
            assert!(a.mu.allclose(&b.mu, 1e-4, 1e-4), "conv mu mismatch");
            assert!(a.aux.allclose(&b.aux, 1e-3, 1e-3), "conv var mismatch");
        });
    }

    #[test]
    fn conv_first_layer_behaves_like_eq13() {
        let mut g = Gen::new(4);
        let x = Tensor::new(vec![1, 1, 8, 8], g.normal_vec(64, 1.0)).unwrap();
        let w_mu = Tensor::new(vec![2, 1, 3, 3], g.normal_vec(18, 0.3)).unwrap();
        let w_var = Tensor::new(vec![2, 1, 3, 3], g.var_vec(18, 0.05)).unwrap();
        let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
        let first = pfp_conv2d_first(
            &x,
            &ConvArgs { w_mu: &w_mu, w_aux: &w_var, b_mu: None, b_var: None },
            &Schedule::tuned(1),
        );
        // generic kernel with x_e2 = x^2 and w_e2 must agree (cancellation)
        let x_prob = ProbTensor::new(x.clone(), x.squared(), Rep::E2);
        let generic = pfp_conv2d_joint(
            &x_prob,
            &ConvArgs { w_mu: &w_mu, w_aux: &w_e2, b_mu: None, b_var: None },
            &Schedule::tuned(1),
        );
        assert!(first.mu.allclose(&generic.mu, 1e-4, 1e-4));
        assert!(first.aux.allclose(&generic.aux, 2e-3, 2e-3));
    }

    #[test]
    fn tiled_conv_bit_identical_to_serial() {
        // planned patch-row + plane partitions vs the serial kernel: the
        // lowering must change where work runs, never a single bit
        use crate::util::threadpool::{split_ranges, ThreadPool};
        let pool = ThreadPool::new(3);
        check(6, |g| {
            let (x, w_mu, w_var, n, _c, o, _k, _hw) = rand_conv_case(g);
            let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
            let xs = x.shape();
            let ws = w_mu.shape();
            let sh = ConvShape {
                n: xs[0],
                c: xs[1],
                h: xs[2],
                w: xs[3],
                o: ws[0],
                kh: ws[2],
                kw: ws[3],
            };
            let sched = Schedule::tuned(1);
            let mut scratch = vec![0.0f32; sh.scratch_len(false)];
            let mut want_mu = vec![0.0f32; sh.out_len()];
            let mut want_var = vec![0.0f32; sh.out_len()];
            conv_kernel_into::<JointEq12>(
                &pool,
                &sh,
                x.mu.data(),
                Some(x.aux.data()),
                w_mu.data(),
                w_e2.data(),
                None,
                None,
                &sched,
                &mut scratch,
                &mut want_mu,
                &mut want_var,
            );
            for tasks in [2usize, 3, 7] {
                let tiles = split_ranges(sh.rows(), tasks);
                let scatter = split_ranges(n * o, tasks);
                let mut mu = vec![0.0f32; sh.out_len()];
                let mut var = vec![0.0f32; sh.out_len()];
                let mut scratch2 = vec![0.0f32; sh.scratch_len(false)];
                conv_kernel_tiled_into::<JointEq12>(
                    &pool,
                    &sh,
                    x.mu.data(),
                    Some(x.aux.data()),
                    w_mu.data(),
                    w_e2.data(),
                    None,
                    None,
                    &sched,
                    Epilogue::None,
                    &tiles,
                    &scatter,
                    &mut scratch2,
                    &mut mu,
                    &mut var,
                );
                assert_eq!(mu, want_mu, "tasks={tasks} mu");
                assert_eq!(var, want_var, "tasks={tasks} var");
            }
        });
    }

    #[test]
    fn packed_conv_is_bitwise_widen_then_f32() {
        // mixed-precision conv inherits the dense bit-parity contract:
        // packed weights must reproduce exactly the bits of the f32 tiled
        // kernel run on pre-widened weight copies, at any tile count and
        // with the fused epilogue on
        use crate::util::half::{narrow, quantize, Precision};
        use crate::util::threadpool::{split_ranges, ThreadPool};
        let pool = ThreadPool::new(3);
        check(4, |g| {
            let (x, w_mu, w_var, n, _c, o, _k, _hw) = rand_conv_case(g);
            let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
            let xs = x.shape();
            let ws = w_mu.shape();
            let sh = ConvShape {
                n: xs[0],
                c: xs[1],
                h: xs[2],
                w: xs[3],
                o: ws[0],
                kh: ws[2],
                kw: ws[3],
            };
            let sched = Schedule::tuned(1);
            for (pm, pa) in [
                (Precision::F16, Precision::F16),
                (Precision::Bf16, Precision::F32),
                (Precision::F32, Precision::Bf16),
            ] {
                let wm_q: Vec<f32> = w_mu.data().iter().map(|&v| quantize(pm, v)).collect();
                let wa_q: Vec<f32> = w_e2.data().iter().map(|&v| quantize(pa, v)).collect();
                let wm_bits: Vec<u16> = w_mu.data().iter().map(|&v| narrow(pm, v)).collect();
                let wa_bits: Vec<u16> = w_e2.data().iter().map(|&v| narrow(pa, v)).collect();
                let wm_packed = if pm.is_f32() {
                    PackedSlice::F32(&wm_q)
                } else {
                    PackedSlice::U16(pm, &wm_bits)
                };
                let wa_packed = if pa.is_f32() {
                    PackedSlice::F32(&wa_q)
                } else {
                    PackedSlice::U16(pa, &wa_bits)
                };
                for tasks in [1usize, 3] {
                    let tiles = split_ranges(sh.rows(), tasks);
                    let scatter = split_ranges(n * o, tasks);
                    for ep in [Epilogue::None, Epilogue::Relu] {
                        let mut scratch = vec![0.0f32; sh.scratch_len(false)];
                        let mut want_mu = vec![0.0f32; sh.out_len()];
                        let mut want_var = vec![0.0f32; sh.out_len()];
                        conv_kernel_tiled_into::<JointEq12>(
                            &pool,
                            &sh,
                            x.mu.data(),
                            Some(x.aux.data()),
                            &wm_q,
                            &wa_q,
                            None,
                            None,
                            &sched,
                            ep,
                            &tiles,
                            &scatter,
                            &mut scratch,
                            &mut want_mu,
                            &mut want_var,
                        );
                        let mut scratch2 = vec![0.0f32; sh.scratch_len(false)];
                        let mut mu = vec![0.0f32; sh.out_len()];
                        let mut var = vec![0.0f32; sh.out_len()];
                        conv_kernel_packed_tiled_into::<JointEq12>(
                            &pool,
                            &sh,
                            x.mu.data(),
                            Some(x.aux.data()),
                            wm_packed,
                            wa_packed,
                            None,
                            None,
                            &sched,
                            ep,
                            &tiles,
                            &scatter,
                            &mut scratch2,
                            &mut mu,
                            &mut var,
                        );
                        assert_eq!(mu, want_mu, "{pm:?}/{pa:?} tasks={tasks} {ep:?} mu");
                        assert_eq!(var, want_var, "{pm:?}/{pa:?} tasks={tasks} {ep:?} var");
                    }
                }
            }
        });
    }

    #[test]
    fn fused_relu_epilogue_commutes_with_scatter() {
        // fused conv+relu applies the epilogue on the pre-scatter [rows, O]
        // chunks; the unfused reference applies it on the NCHW output. The
        // elementwise kernels are position-independent (tails run through
        // padded lanes of the same code), so the two orderings must agree
        // bit for bit — per ISA, at any tile count.
        use crate::ops::relu::pfp_relu_rows_into;
        use crate::ops::simd::Isa;
        use crate::util::threadpool::{split_ranges, ThreadPool};
        let pool = ThreadPool::new(3);
        check(4, |g| {
            let (x, w_mu, w_var, n, _c, o, _k, _hw) = rand_conv_case(g);
            let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
            let xs = x.shape();
            let ws = w_mu.shape();
            let sh = ConvShape {
                n: xs[0],
                c: xs[1],
                h: xs[2],
                w: xs[3],
                o: ws[0],
                kh: ws[2],
                kw: ws[3],
            };
            for isa in [Isa::Scalar, Isa::Native] {
                let sched = Schedule::tuned(1).with_isa(isa);
                let out_len = sh.out_len();
                let mut scratch = vec![0.0f32; sh.scratch_len(false)];
                let mut conv_mu = vec![0.0f32; out_len];
                let mut conv_var = vec![0.0f32; out_len];
                conv_kernel_into::<JointEq12>(
                    &pool,
                    &sh,
                    x.mu.data(),
                    Some(x.aux.data()),
                    w_mu.data(),
                    w_e2.data(),
                    None,
                    None,
                    &sched,
                    &mut scratch,
                    &mut conv_mu,
                    &mut conv_var,
                );
                let mut want_mu = vec![0.0f32; out_len];
                let mut want_e2 = vec![0.0f32; out_len];
                pfp_relu_rows_into(isa, &conv_mu, &conv_var, 0..out_len, &mut want_mu, &mut want_e2);
                for tasks in [1usize, 3, 7] {
                    let tiles = split_ranges(sh.rows(), tasks);
                    let scatter = split_ranges(n * o, tasks);
                    let mut mu = vec![0.0f32; out_len];
                    let mut e2 = vec![0.0f32; out_len];
                    let mut scratch2 = vec![0.0f32; sh.scratch_len(false)];
                    conv_kernel_tiled_into::<JointEq12>(
                        &pool,
                        &sh,
                        x.mu.data(),
                        Some(x.aux.data()),
                        w_mu.data(),
                        w_e2.data(),
                        None,
                        None,
                        &sched,
                        Epilogue::Relu,
                        &tiles,
                        &scatter,
                        &mut scratch2,
                        &mut mu,
                        &mut e2,
                    );
                    assert_eq!(mu, want_mu, "{isa:?} tasks={tasks} fused mu");
                    assert_eq!(e2, want_e2, "{isa:?} tasks={tasks} fused e2");
                }
            }
        });
    }

    #[test]
    fn bias_broadcast_per_channel() {
        let mut g = Gen::new(6);
        let x_mu = Tensor::new(vec![1, 1, 4, 4], g.normal_vec(16, 1.0)).unwrap();
        let x = ProbTensor::new(x_mu.clone(), x_mu.squared(), Rep::E2);
        let w_mu = Tensor::new(vec![2, 1, 3, 3], vec![0.0; 18]).unwrap();
        let w_e2 = Tensor::new(vec![2, 1, 3, 3], vec![0.0; 18]).unwrap();
        let b_mu = [1.0f32, 2.0];
        let b_var = [0.1f32, 0.2];
        let out = pfp_conv2d_joint(
            &x,
            &ConvArgs {
                w_mu: &w_mu, w_aux: &w_e2,
                b_mu: Some(&b_mu), b_var: Some(&b_var),
            },
            &Schedule::tuned(1),
        );
        // zero weights: output = bias per channel
        assert!(out.mu.data()[..4].iter().all(|&v| (v - 1.0).abs() < 1e-6));
        assert!(out.mu.data()[4..].iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert!(out.aux.data()[..4].iter().all(|&v| (v - 0.1).abs() < 1e-6));
    }

    #[test]
    fn output_shape_valid_padding() {
        let mut g = Gen::new(8);
        let x_mu = Tensor::new(vec![2, 1, 28, 28], g.normal_vec(2 * 784, 1.0)).unwrap();
        let x = ProbTensor::new(x_mu.clone(), x_mu.squared(), Rep::E2);
        let w_mu = Tensor::new(vec![6, 1, 5, 5], g.normal_vec(150, 0.2)).unwrap();
        let w_e2 = w_mu.squared();
        let out = pfp_conv2d_joint(
            &x,
            &ConvArgs { w_mu: &w_mu, w_aux: &w_e2, b_mu: None, b_var: None },
            &Schedule::tuned(1),
        );
        assert_eq!(out.shape(), &[2, 6, 24, 24]);
    }
}
