//! Native PFP operator library — the paper's TVM operator library analog.
//!
//! Every operator of the Probabilistic Forward Pass is implemented here
//! with explicit, tunable *schedules* (tiling / loop order / unrolling /
//! vectorization / parallelization — the paper's Table 2 knobs), plus the
//! deterministic and SVI-sampled counterparts used as baselines in
//! Table 5 / Fig. 7.
//!
//! Numerical contracts (checked against `python/compile/kernels/ref.py`
//! goldens by the integration tests):
//!
//! * dense/conv: Eq. 4 mean, Eq. 12 variance (raw-moment form), Eq. 7
//!   (variance form), Eq. 5 (original form) and Eq. 13 (first layer);
//! * ReLU: Eqs. 8/9 moment matching (erf-based);
//! * max-pool: pairwise moment-matched Gaussian max (generic reduction
//!   and vectorized k=2 tree — Table 3's two implementations).

pub mod activations;
pub mod conv;
pub mod dense;
pub mod det;
pub mod erf;
pub mod maxpool;
pub mod relu;
pub mod schedule;
pub mod simd;
pub mod svi;

pub use relu::Epilogue;
pub use schedule::{LoopOrder, Schedule};
pub use simd::Isa;
// storage-precision knob lives in util::half; re-exported here because it
// is a Schedule dimension like `Isa`
pub use crate::util::half::Precision;
