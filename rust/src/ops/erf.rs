//! Fast error-function / Gaussian helpers for the moment-matching
//! operators (PFP ReLU, Gaussian max-pool).
//!
//! `erf` uses the Abramowitz & Stegun 7.1.26 rational approximation
//! (|err| <= 1.5e-7 in f64; ~1e-6 in this f32 evaluation) — accurate
//! enough that the whole network stays within 1e-3 of the JAX goldens,
//! and far cheaper than a libm-quality implementation on the hot path.
//!
//! The SIMD backends ([`ops::simd`](super::simd)) evaluate the *same*
//! A&S polynomial (constants shared below) with a Cephes-style polynomial
//! `exp` instead of libm, so scalar and vectorized `erf` agree to ~1e-6
//! absolute — the bound is pinned by the reference-table tests in this
//! file, which check both against a high-precision f64 table over
//! `[-6, 6]` and bound the scalar↔SIMD ULP distance.

pub const INV_SQRT_2PI: f32 = 0.398_942_28;
pub const FRAC_1_SQRT_2: f32 = std::f32::consts::FRAC_1_SQRT_2;

/// A&S 7.1.26 constants, shared with the vectorized evaluation in
/// [`ops::simd`](super::simd) so both render one polynomial.
pub(crate) const ERF_P: f32 = 0.327_591_1;
pub(crate) const ERF_A1: f32 = 0.254_829_592;
pub(crate) const ERF_A2: f32 = -0.284_496_736;
pub(crate) const ERF_A3: f32 = 1.421_413_741;
pub(crate) const ERF_A4: f32 = -1.453_152_027;
pub(crate) const ERF_A5: f32 = 1.061_405_429;

/// erf(x), Abramowitz & Stegun 7.1.26.
#[inline(always)]
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + ERF_P * x);
    let poly =
        ((((ERF_A5 * t + ERF_A4) * t + ERF_A3) * t + ERF_A2) * t + ERF_A1) * t;
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF Phi(x).
#[inline(always)]
pub fn norm_cdf(x: f32) -> f32 {
    0.5 * (1.0 + erf(x * FRAC_1_SQRT_2))
}

/// Standard normal PDF phi(x).
#[inline(always)]
pub fn norm_pdf(x: f32) -> f32 {
    INV_SQRT_2PI * (-0.5 * x * x).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::simd;

    /// High-precision f64 reference over [-6, 6], step 0.5:
    /// `(x, erf(x), Phi(x), phi(x))` computed with `math.erf`/`exp` in
    /// double precision.
    const REF: &[(f32, f64, f64, f64)] = &[
        (-6.0, -1.0, 9.865876449133282e-10, 6.075882849823286e-09),
        (-5.5, -0.9999999999999927, 1.8989562478033406e-08, 1.0769760042543276e-07),
        (-5.0, -0.9999999999984626, 2.8665157186802404e-07, 1.4867195147342979e-06),
        (-4.5, -0.9999999998033839, 3.3976731247387093e-06, 1.5983741106905478e-05),
        (-4.0, -0.9999999845827421, 3.167124183311998e-05, 0.00013383022576488537),
        (-3.5, -0.9999992569016276, 0.0002326290790355401, 0.0008726826950457602),
        (-3.0, -0.9999779095030014, 0.0013498980316301035, 0.0044318484119380075),
        (-2.5, -0.999593047982555, 0.006209665325776159, 0.01752830049356854),
        (-2.0, -0.9953222650189527, 0.02275013194817921, 0.05399096651318806),
        (-1.5, -0.9661051464753108, 0.06680720126885809, 0.12951759566589174),
        (-1.0, -0.8427007929497149, 0.15865525393145707, 0.24197072451914337),
        (-0.5, -0.5204998778130465, 0.3085375387259869, 0.3520653267642995),
        (0.0, 0.0, 0.5, 0.3989422804014327),
        (0.5, 0.5204998778130465, 0.6914624612740131, 0.3520653267642995),
        (1.0, 0.8427007929497149, 0.8413447460685429, 0.24197072451914337),
        (1.5, 0.9661051464753108, 0.9331927987311419, 0.12951759566589174),
        (2.0, 0.9953222650189527, 0.9772498680518208, 0.05399096651318806),
        (2.5, 0.999593047982555, 0.9937903346742238, 0.01752830049356854),
        (3.0, 0.9999779095030014, 0.9986501019683699, 0.0044318484119380075),
        (3.5, 0.9999992569016276, 0.9997673709209645, 0.0008726826950457602),
        (4.0, 0.9999999845827421, 0.9999683287581669, 0.00013383022576488537),
        (4.5, 0.9999999998033839, 0.9999966023268753, 1.5983741106905478e-05),
        (5.0, 0.9999999999984626, 0.9999997133484282, 1.4867195147342979e-06),
        (5.5, 0.9999999999999927, 0.9999999810104375, 1.0769760042543276e-07),
        (6.0, 1.0, 0.9999999990134123, 6.075882849823286e-09),
    ];

    /// The documented accuracy contract, absolute over [-6, 6].
    const ERF_BOUND: f64 = 1.5e-6;

    /// Distance in representable f32 values (ULPs), sign-aware.
    fn ulp_dist(a: f32, b: f32) -> u64 {
        fn key(x: f32) -> i64 {
            let i = x.to_bits() as i32;
            if i >= 0 { i as i64 } else { i64::from(i32::MIN) - i as i64 }
        }
        key(a).abs_diff(key(b))
    }

    #[test]
    fn scalar_erf_cdf_pdf_within_bound_of_f64_reference() {
        for &(x, e, c, p) in REF {
            assert!(
                (erf(x) as f64 - e).abs() < ERF_BOUND,
                "erf({x}) = {} vs f64 reference {e}",
                erf(x)
            );
            assert!(
                (norm_cdf(x) as f64 - c).abs() < ERF_BOUND,
                "norm_cdf({x}) = {} vs {c}",
                norm_cdf(x)
            );
            assert!(
                (norm_pdf(x) as f64 - p).abs() < ERF_BOUND,
                "norm_pdf({x}) = {} vs {p}",
                norm_pdf(x)
            );
        }
    }

    #[test]
    fn vectorized_erf_cdf_pdf_within_bound_of_f64_reference() {
        // the detected backend (scalar under PFP_FORCE_SCALAR=1 — the CI
        // matrix runs both) must honor the same absolute bound
        let b = simd::detect();
        let xs: Vec<f32> = REF.iter().map(|r| r.0).collect();
        let mut erf_v = vec![0.0f32; xs.len()];
        let mut cdf_v = vec![0.0f32; xs.len()];
        let mut pdf_v = vec![0.0f32; xs.len()];
        simd::erf_into(b, &xs, &mut erf_v);
        simd::norm_cdf_into(b, &xs, &mut cdf_v);
        simd::norm_pdf_into(b, &xs, &mut pdf_v);
        for (i, &(x, e, c, p)) in REF.iter().enumerate() {
            assert!(
                (erf_v[i] as f64 - e).abs() < ERF_BOUND,
                "{} erf({x}) = {} vs {e}",
                b.name(),
                erf_v[i]
            );
            assert!(
                (cdf_v[i] as f64 - c).abs() < ERF_BOUND,
                "{} norm_cdf({x}) = {} vs {c}",
                b.name(),
                cdf_v[i]
            );
            assert!(
                (pdf_v[i] as f64 - p).abs() < ERF_BOUND,
                "{} norm_pdf({x}) = {} vs {p}",
                b.name(),
                pdf_v[i]
            );
        }
    }

    #[test]
    fn scalar_vs_simd_erf_ulp_distance_bounded() {
        // dense grid over [-6, 6]: the two renderings of the one A&S
        // polynomial differ only by FMA contraction and the polynomial
        // exp. The absolute cap (1e-6) polices accuracy everywhere; the
        // ULP cap is only meaningful away from x = 0, where erf's output
        // is not yet tiny — near zero the result is the cancellation
        // residual 1 - poly*exp(-x^2) of two ~1.0 values, so a ~1e-7
        // absolute difference can legitimately span thousands of (tiny)
        // ULPs of the output without any accuracy loss.
        let b = simd::detect();
        let xs: Vec<f32> = (-600..=600).map(|i| i as f32 * 0.01).collect();
        let mut got = vec![0.0f32; xs.len()];
        simd::erf_into(b, &xs, &mut got);
        let mut worst_ulp = 0u64;
        let mut worst_abs = 0.0f32;
        for (i, &x) in xs.iter().enumerate() {
            let s = erf(x);
            if x.abs() >= 0.25 {
                worst_ulp = worst_ulp.max(ulp_dist(s, got[i]));
            }
            worst_abs = worst_abs.max((s - got[i]).abs());
        }
        assert!(
            worst_ulp <= 512,
            "scalar vs {} erf (|x| >= 0.25): {worst_ulp} ULPs",
            b.name()
        );
        assert!(worst_abs <= 1e-6, "scalar vs {} erf: |diff| {worst_abs}", b.name());
    }

    #[test]
    fn erf_reference_points() {
        // reference values from the mathematical erf
        let cases = [
            (0.0f32, 0.0f32),
            (0.5, 0.5204999),
            (1.0, 0.8427008),
            (2.0, 0.9953223),
            (-1.0, -0.8427008),
            (3.5, 0.9999993),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-6, "erf({x}) = {} != {want}", erf(x));
        }
    }

    #[test]
    fn erf_is_odd_and_bounded() {
        for i in -40..=40 {
            let x = i as f32 * 0.25;
            assert!((erf(x) + erf(-x)).abs() < 1e-6);
            assert!(erf(x).abs() <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn cdf_pdf_sanity() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-6);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_pdf(0.0) - 0.3989423).abs() < 1e-6);
        assert!(norm_pdf(5.0) < 1e-5);
    }

    #[test]
    fn cdf_monotone() {
        let mut prev = 0.0;
        for i in -30..=30 {
            let c = norm_cdf(i as f32 * 0.2);
            assert!(c >= prev - 1e-6);
            prev = c;
        }
    }
}
