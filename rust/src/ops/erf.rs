//! Fast error-function / Gaussian helpers for the moment-matching
//! operators (PFP ReLU, Gaussian max-pool).
//!
//! `erf` uses the Abramowitz & Stegun 7.1.26 rational approximation
//! (|err| <= 1.5e-7 in f64; ~1e-6 in this f32 evaluation) — accurate
//! enough that the whole network stays within 1e-3 of the JAX goldens,
//! and far cheaper than a libm-quality implementation on the hot path.

pub const INV_SQRT_2PI: f32 = 0.398_942_28;
pub const FRAC_1_SQRT_2: f32 = std::f32::consts::FRAC_1_SQRT_2;

/// erf(x), Abramowitz & Stegun 7.1.26.
#[inline(always)]
pub fn erf(x: f32) -> f32 {
    const P: f32 = 0.327_591_1;
    const A1: f32 = 0.254_829_592;
    const A2: f32 = -0.284_496_736;
    const A3: f32 = 1.421_413_741;
    const A4: f32 = -1.453_152_027;
    const A5: f32 = 1.061_405_429;
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + P * x);
    let poly = ((((A5 * t + A4) * t + A3) * t + A2) * t + A1) * t;
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF Phi(x).
#[inline(always)]
pub fn norm_cdf(x: f32) -> f32 {
    0.5 * (1.0 + erf(x * FRAC_1_SQRT_2))
}

/// Standard normal PDF phi(x).
#[inline(always)]
pub fn norm_pdf(x: f32) -> f32 {
    INV_SQRT_2PI * (-0.5 * x * x).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_points() {
        // reference values from the mathematical erf
        let cases = [
            (0.0f32, 0.0f32),
            (0.5, 0.5204999),
            (1.0, 0.8427008),
            (2.0, 0.9953223),
            (-1.0, -0.8427008),
            (3.5, 0.9999993),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-6, "erf({x}) = {} != {want}", erf(x));
        }
    }

    #[test]
    fn erf_is_odd_and_bounded() {
        for i in -40..=40 {
            let x = i as f32 * 0.25;
            assert!((erf(x) + erf(-x)).abs() < 1e-6);
            assert!(erf(x).abs() <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn cdf_pdf_sanity() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-6);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_pdf(0.0) - 0.3989423).abs() < 1e-6);
        assert!(norm_pdf(5.0) < 1e-5);
    }

    #[test]
    fn cdf_monotone() {
        let mut prev = 0.0;
        for i in -30..=30 {
            let c = norm_cdf(i as f32 * 0.2);
            assert!(c >= prev - 1e-6);
            prev = c;
        }
    }
}
