//! Operator schedules — the paper's Table 2 optimization knobs, made
//! explicit so the tuner (Meta-Scheduler analog) can search over them.
//!
//! | paper knob       | here |
//! |------------------|------|
//! | Loop Reordering  | [`LoopOrder`]: `Mkn` (naive baseline) vs `Mnk` (dot-product order) |
//! | Tiling           | `tile_n`/`tile_k` output/reduction blocking (0 = off) |
//! | Loop Unrolling   | `unroll` ∈ {1,2,4,8}: independent accumulators in the k-loop |
//! | Vectorization    | `vectorize`: SIMD-friendly fixed-width lanes in the inner loop |
//! | Parallelization  | `threads`: row-parallel execution |
//!
//! `threads` has two realizations: the Tensor-level operator API splits
//! rows over boxed scope jobs at call time, while the compiled plan reads
//! it at **plan time** to pre-partition each compute step into disjoint
//! row tiles that are gang-dispatched allocation-free
//! (`ThreadPool::run_tasks`) — rows are never split along the reduction,
//! so planned-parallel output is bit-identical to planned-serial. Tiled
//! schedules run with fixed-size accumulator blocks
//! (`ops::dense::MAX_TILE_N`) and are admitted into plan lowering like
//! any other schedule.
//!
//! The paper's footnote "tiling does not support stochastic tuning" is
//! mirrored in `tuner::space`: enabling tiles freezes the stochastic
//! mutation of the other knobs.
//!
//! Since PR 5 a schedule also carries an [`Isa`] knob — the explicit-SIMD
//! dimension. `vectorize` keeps its historical meaning (fixed-width lane
//! *hints* the compiler may or may not vectorize); `isa: Native` swaps the
//! `Mnk` inner reduction for the hand-written AVX2+FMA / NEON microkernels
//! in [`ops::simd`](super::simd), resolved by one-time runtime feature
//! detection (scalar fallback always compiled, `PFP_FORCE_SCALAR=1`
//! honored). The tuner explores the knob; `CompiledPlan::compile` binds it
//! per step like every other knob.

use super::simd::Isa;
use crate::util::half::Precision;

/// Loop nest order for the dense/conv matmul core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopOrder {
    /// m → k → n: the naive TE-lowering order. The inner n-loop walks the
    /// weight matrix with stride K — the slow baseline, and the order in
    /// which "vectorization alone" *hurts* (Table 2's 0.42x row).
    Mkn,
    /// m → n → k: dot-product order; both operand rows are contiguous.
    Mnk,
}

/// A concrete schedule for a PFP compute operator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Schedule {
    pub loop_order: LoopOrder,
    /// Output-feature tile (0 = no tiling).
    pub tile_n: usize,
    /// Reduction tile (0 = no tiling).
    pub tile_k: usize,
    /// k-loop unroll factor (1 = off; 2/4/8 use that many accumulators).
    pub unroll: usize,
    /// SIMD-friendly fixed-width inner lanes.
    pub vectorize: bool,
    /// Worker threads for row-parallel execution (1 = off).
    pub threads: usize,
    /// Explicit-SIMD microkernel selection: `Scalar` keeps the portable
    /// lane machinery; `Native` dispatches the `Mnk` inner reduction (and
    /// the elementwise moment-matching ops bound with this schedule) to
    /// the runtime-detected ISA backend.
    pub isa: Isa,
    /// Fused-epilogue eligibility (PR 8): when plan lowering runs under
    /// `FusePolicy::Auto`, a compute step whose bound schedule carries
    /// `fuse: true` absorbs a directly-following moment-matched ReLU (and
    /// an absorbable `Convert`) into its kernel epilogue, skipping the
    /// intermediate ping-pong buffer round trips. The knob only marks
    /// *eligibility* — which epilogue actually applies (ReLU vs
    /// ReLU+E2→Var vs none on a last layer) is decided by the plan's
    /// pattern matcher.
    pub fuse: bool,
    /// Storage precision for this step's posterior weights and its
    /// output activations (mixed-precision PR): `F32` is the stock
    /// format; `F16`/`Bf16` store weight matrices packed as u16 bits and
    /// narrow the step's output through the workspace's packed buffer,
    /// with **all accumulation staying in f32**. Mean vs variance
    /// precision can additionally be split model-wide via the executor's
    /// `var_precision` override; this knob is the per-step default for
    /// both operand roles.
    pub precision: Precision,
}

impl Default for Schedule {
    fn default() -> Self {
        Self::baseline()
    }
}

impl Schedule {
    /// Untuned baseline: naive loop order, nothing enabled (Table 2 row 1).
    pub fn baseline() -> Self {
        Self {
            loop_order: LoopOrder::Mkn,
            tile_n: 0,
            tile_k: 0,
            unroll: 1,
            vectorize: false,
            threads: 1,
            isa: Isa::Scalar,
            fuse: false,
            precision: Precision::F32,
        }
    }

    /// The hand-tuned schedule that Table 2's "All Optimizations (no
    /// tiling) + stochastic tuning" row converges to — explicit SIMD
    /// included (runtime-detected, scalar where unsupported). `fuse`
    /// stays off: the bitwise plan==interpreter contract is anchored on
    /// this schedule, and fusion is an opt-in policy (see
    /// `model::FusePolicy`).
    pub fn tuned(threads: usize) -> Self {
        Self {
            loop_order: LoopOrder::Mnk,
            tile_n: 0,
            tile_k: 0,
            unroll: 8,
            vectorize: true,
            threads,
            isa: Isa::Native,
            fuse: false,
            precision: Precision::F32,
        }
    }

    /// Tiling-only schedule (Table 2's "Tiling, other opts OFF" row).
    pub fn tiled(tile_n: usize, tile_k: usize) -> Self {
        Self {
            loop_order: LoopOrder::Mnk,
            tile_n,
            tile_k,
            unroll: 1,
            vectorize: false,
            threads: 1,
            isa: Isa::Scalar,
            fuse: false,
            precision: Precision::F32,
        }
    }

    pub fn with_order(mut self, o: LoopOrder) -> Self {
        self.loop_order = o;
        self
    }

    pub fn with_unroll(mut self, u: usize) -> Self {
        self.unroll = u;
        self
    }

    pub fn with_vectorize(mut self, v: bool) -> Self {
        self.vectorize = v;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    pub fn with_tiles(mut self, n: usize, k: usize) -> Self {
        self.tile_n = n;
        self.tile_k = k;
        self
    }

    pub fn with_isa(mut self, isa: Isa) -> Self {
        self.isa = isa;
        self
    }

    pub fn with_fuse(mut self, fuse: bool) -> Self {
        self.fuse = fuse;
        self
    }

    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// Short human tag, used in bench output and tuning records.
    pub fn tag(&self) -> String {
        format!(
            "{:?}{}{}{}{}{}{}{}",
            self.loop_order,
            if self.tile_n > 0 || self.tile_k > 0 {
                format!("+tile{}x{}", self.tile_n, self.tile_k)
            } else {
                String::new()
            },
            if self.unroll > 1 { format!("+u{}", self.unroll) } else { String::new() },
            if self.vectorize { "+vec" } else { "" },
            if self.isa == Isa::Native { "+simd" } else { "" },
            if self.fuse { "+fuse" } else { "" },
            if self.precision.is_f32() {
                String::new()
            } else {
                format!("+{}", self.precision.as_str())
            },
            if self.threads > 1 { format!("+t{}", self.threads) } else { String::new() },
        )
    }

    /// Serialize for tuning records.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "loop_order",
                Json::Str(format!("{:?}", self.loop_order)),
            ),
            ("tile_n", Json::Num(self.tile_n as f64)),
            ("tile_k", Json::Num(self.tile_k as f64)),
            ("unroll", Json::Num(self.unroll as f64)),
            ("vectorize", Json::Bool(self.vectorize)),
            ("threads", Json::Num(self.threads as f64)),
            ("isa", Json::Str(self.isa.as_str().to_string())),
            ("fuse", Json::Bool(self.fuse)),
            (
                "precision",
                Json::Str(self.precision.as_str().to_string()),
            ),
        ])
    }

    pub fn from_json(v: &crate::util::json::Json) -> crate::error::Result<Self> {
        use crate::error::Error;
        let order = match v.str_field("loop_order")? {
            "Mkn" => LoopOrder::Mkn,
            "Mnk" => LoopOrder::Mnk,
            o => return Err(Error::Json(format!("unknown loop order {o}"))),
        };
        Ok(Self {
            loop_order: order,
            tile_n: v.num_field("tile_n")? as usize,
            tile_k: v.num_field("tile_k")? as usize,
            unroll: (v.num_field("unroll")? as usize).max(1),
            vectorize: v.get("vectorize").and_then(|b| b.as_bool()).unwrap_or(false),
            threads: (v.num_field("threads")? as usize).max(1),
            // absent in pre-SIMD records: those schedules were measured on
            // the scalar kernels, so that is what they keep describing
            isa: v
                .get("isa")
                .and_then(|s| s.as_str())
                .and_then(Isa::parse)
                .unwrap_or(Isa::Scalar),
            // absent in pre-fusion records: those schedules were measured
            // on the unfused kernels, so they keep describing them (the
            // records-file version gate in `tuner::records` warns and
            // drops whole pre-v4 files before this fallback is ever hit)
            fuse: v.get("fuse").and_then(|b| b.as_bool()).unwrap_or(false),
            // absent in pre-mixed-precision records (schema v4 and
            // earlier): those schedules were measured on f32 storage
            precision: v
                .get("precision")
                .and_then(|s| s.as_str())
                .and_then(Precision::parse)
                .unwrap_or(Precision::F32),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let s = Schedule::tuned(4).with_tiles(16, 64).with_fuse(true);
        let j = s.to_json();
        let back = Schedule::from_json(&j).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn tags_are_distinct() {
        assert_ne!(Schedule::baseline().tag(), Schedule::tuned(1).tag());
        assert_ne!(Schedule::tuned(1).tag(), Schedule::tuned(4).tag());
        // the ISA knob is visible in the tag (tuned carries Native)
        assert_ne!(
            Schedule::tuned(1).tag(),
            Schedule::tuned(1).with_isa(Isa::Scalar).tag()
        );
        // so is the fuse knob
        assert_ne!(
            Schedule::tuned(1).tag(),
            Schedule::tuned(1).with_fuse(true).tag()
        );
        // and the precision knob (f32 is the unmarked default)
        let f16 = Schedule::tuned(1).with_precision(Precision::F16).tag();
        let bf16 = Schedule::tuned(1).with_precision(Precision::Bf16).tag();
        assert_ne!(Schedule::tuned(1).tag(), f16);
        assert_ne!(f16, bf16);
        assert!(f16.contains("+f16"), "{f16}");
        assert!(bf16.contains("+bf16"), "{bf16}");
    }

    #[test]
    fn precision_json_roundtrip_and_back_compat() {
        // the knob serializes with the record and round-trips
        let s = Schedule::tuned(2).with_precision(Precision::Bf16);
        let back = Schedule::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // pre-mixed-precision (schema ≤ v4) schedule JSON: those
        // schedules were measured on f32 storage, so that is what they
        // must keep describing
        let mut j = Schedule::tuned(2).with_precision(Precision::F16).to_json();
        if let crate::util::json::Json::Obj(obj) = &mut j {
            obj.remove("precision");
        }
        let back = Schedule::from_json(&j).unwrap();
        assert_eq!(back.precision, Precision::F32);
    }

    #[test]
    fn missing_isa_field_parses_as_scalar() {
        // pre-SIMD-era schedule JSON: those schedules were measured on the
        // scalar kernels, so they must keep binding the scalar backend
        let mut j = Schedule::tuned(2).to_json();
        if let crate::util::json::Json::Obj(obj) = &mut j {
            obj.remove("isa");
        }
        let back = Schedule::from_json(&j).unwrap();
        assert_eq!(back.isa, Isa::Scalar);
        assert_eq!(back.unroll, 8);
    }

    #[test]
    fn missing_fuse_field_parses_as_off() {
        // pre-fusion-era schedule JSON (schema v3 and earlier): those
        // schedules were measured on the unfused kernels, so they must
        // keep describing the unfused path
        let mut j = Schedule::tuned(2).with_fuse(true).to_json();
        if let crate::util::json::Json::Obj(obj) = &mut j {
            obj.remove("fuse");
        }
        let back = Schedule::from_json(&j).unwrap();
        assert!(!back.fuse);
        assert_eq!(back.isa, Isa::Native);
    }
}
