//! SVI baseline support: posterior weight sampling.
//!
//! The paper's SVI baseline draws a full weight set from the mean-field
//! posterior and runs a standard forward pass, N times per prediction
//! (N = 30 in the evaluation). The sampling itself is part of the
//! measured cost — `sample_into` is the reparameterisation
//! `w = mu + sigma * z`, `z ~ N(0,1)` via Box-Muller on SplitMix64.

use crate::tensor::Tensor;
use crate::util::rng::SplitMix64;

/// Sample `w = mu + sigma * z` elementwise into a reusable buffer.
pub fn sample_into(out: &mut Vec<f32>, mu: &Tensor, sigma: &Tensor, rng: &mut SplitMix64) {
    let n = mu.len();
    out.clear();
    out.reserve(n);
    let mu_d = mu.data();
    let sg_d = sigma.data();
    for i in 0..n {
        out.push(mu_d[i] + sg_d[i] * rng.normal() as f32);
    }
}

/// Sample a full weight tensor (allocating).
pub fn sample_tensor(mu: &Tensor, sigma: &Tensor, rng: &mut SplitMix64) -> Tensor {
    let mut buf = Vec::new();
    sample_into(&mut buf, mu, sigma, rng);
    Tensor::new(mu.shape().to_vec(), buf).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_moments_match_posterior() {
        let n = 20_000;
        let mu = Tensor::full(vec![n], 0.5);
        let sigma = Tensor::full(vec![n], 0.2);
        let mut rng = SplitMix64::new(11);
        let s = sample_tensor(&mu, &sigma, &mut rng);
        let mean: f32 = s.data().iter().sum::<f32>() / n as f32;
        let var: f32 =
            s.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 0.04).abs() < 0.005, "var {var}");
    }

    #[test]
    fn zero_sigma_is_deterministic() {
        let mu = Tensor::from_vec(vec![1.0, -2.0, 3.0]);
        let sigma = Tensor::from_vec(vec![0.0, 0.0, 0.0]);
        let mut rng = SplitMix64::new(1);
        let s = sample_tensor(&mu, &sigma, &mut rng);
        assert_eq!(s.data(), mu.data());
    }

    #[test]
    fn different_seeds_differ() {
        let mu = Tensor::zeros(vec![16]);
        let sigma = Tensor::full(vec![16], 1.0);
        let a = sample_tensor(&mu, &sigma, &mut SplitMix64::new(1));
        let b = sample_tensor(&mu, &sigma, &mut SplitMix64::new(2));
        assert!(a.max_abs_diff(&b) > 1e-3);
    }
}
