//! Scheduled PFP dense operators — the paper's hottest kernel (Table 2).
//!
//! All formulations share one generic, monomorphized loop nest
//! parameterized by an [`Accum`] (the per-k update), so every variant
//! benefits from the same schedule knobs and Fig. 5's comparison is
//! apples-to-apples:
//!
//! * [`JointEq12`] — joint mean+variance, second-raw-moment form (Eq. 12):
//!   `t = mu_x*mu_w; mu += t; var += E[x^2]*E[w^2] - t*t` — the mean-path
//!   product is *reused* by the variance path (the paper's joint-operator
//!   data reuse), two accumulators per lane.
//! * [`JointEq5`] — joint, original form (Eq. 5): recomputes
//!   `mu_w^2 (E[x^2] - mu_x^2)` with no reuse; more arithmetic per k.
//! * [`VarForm`] — Eq. 7, for producers that hand variances directly.
//! * [`FirstLayer`] — Eq. 13 (deterministic input).
//! * [`MeanOnly`] / [`VarOnlyEq12`] / [`VarOnlyEq5`] — the "separate
//!   operators" split (one operator = one compute rule) for Fig. 5.
//!
//! Layout: activations `[M, K]`, weights `[N, K]` row-major, so the `Mnk`
//! order walks two contiguous rows (dot-product form) while `Mkn` (the
//! untuned baseline) strides the weight matrix by K in its inner loop.
//!
//! Schedules with `isa: Native` route the `Mnk` inner reduction through
//! the explicit SIMD microkernels in [`ops::simd`](super::simd)
//! (AVX2+FMA / NEON, runtime-detected) via [`Accum::reduce_simd`] — the
//! production formulations (`JointEq12`, `FirstLayer`, `MeanOnly`) have
//! vector kernels; everything else, the scalar ISA, and the deliberately
//! naive `Mkn` baseline keep the portable lane machinery unchanged.

use crate::tensor::Tensor;
use crate::util::threadpool::{self, split_ranges, DisjointMut, ThreadPool};

use super::relu::{apply_epilogue, Epilogue};
use super::schedule::{LoopOrder, Schedule};
use super::simd::{self, Backend, PackedSlice};

/// Upper bound on the `tile_n` accumulator block: the cache-blocked loop
/// body keeps its per-block accumulators in a fixed-size stack array so it
/// allocates nothing (the compiled plan's zero-steady-state-allocation
/// guarantee covers tiled schedules too). Larger requested tiles are
/// processed in `MAX_TILE_N`-wide sub-blocks — numerically identical,
/// since `tile_n` only groups *independent* outputs; only `tile_k` blocks
/// the reduction itself.
pub const MAX_TILE_N: usize = 64;

/// Per-k accumulator contract. `step` must be `#[inline(always)]`-cheap;
/// the schedule machinery instantiates 1..=16 independent copies for
/// unroll/vectorize lanes and merges them at the end.
pub trait Accum: Copy + Default {
    /// Consume one reduction element. `xa`/`wa` are the auxiliary operands
    /// (E[x^2] / variance, depending on the formulation).
    fn step(&mut self, xm: f32, xa: f32, wm: f32, wa: f32);
    /// Merge a lane into self.
    fn merge(&mut self, other: Self);
    /// (mean contribution, raw variance contribution).
    fn finish(self) -> (f32, f32);

    /// Whole-(sub)row reduction on an explicit SIMD backend, when this
    /// formulation has a microkernel ([`ops::simd`](super::simd)). `None`
    /// (the default, and always for [`Backend::Scalar`]) falls back to the
    /// portable lane machinery — so forcing scalar reproduces the
    /// historical outputs bit for bit. Implemented for the three
    /// formulations the compiled plan executes ([`JointEq12`],
    /// [`FirstLayer`], [`MeanOnly`]).
    #[inline(always)]
    fn reduce_simd(
        _b: Backend,
        _xm: &[f32],
        _xa: &[f32],
        _wm: &[f32],
        _wa: &[f32],
    ) -> Option<Self> {
        None
    }

    /// Packed-weight twin of [`Accum::reduce_simd`]: the weight operands
    /// are [`PackedSlice`]s (f16/bf16 bits, or plain f32 — each moment
    /// path carries its own precision) widened to f32 registers inside
    /// the microkernel, with f32 accumulation throughout. Implemented for
    /// the same three planned formulations; `None` falls back to the
    /// packed lane machinery, which widens per element with the scalar
    /// reference — bitwise the same contract either way: a packed
    /// reduction equals the f32 reduction over pre-widened weights.
    #[inline(always)]
    fn reduce_simd_packed(
        _b: Backend,
        _xm: &[f32],
        _xa: &[f32],
        _wm: PackedSlice<'_>,
        _wa: PackedSlice<'_>,
    ) -> Option<Self> {
        None
    }
}

/// Eq. 12 joint kernel (raw-moment form, shared mean product).
///
/// Maximal-reuse formulation: the mean-path product `t = mu_x*mu_w` feeds
/// both the mean accumulator and the variance accumulator
/// (`var += E[x^2]E[w^2] - t^2`), and the subtraction is folded into the
/// k-loop so the kernel carries only **two** accumulators per lane — the
/// measured-fastest variant on this host (see EXPERIMENTS.md §Perf; the
/// three-accumulator version pays ~75% more at wide lane counts from
/// register pressure).
#[derive(Clone, Copy, Default)]
pub struct JointEq12 {
    mu: f32,
    var: f32,
}

impl Accum for JointEq12 {
    #[inline(always)]
    fn step(&mut self, xm: f32, xa: f32, wm: f32, wa: f32) {
        let t = xm * wm;
        self.mu += t;
        self.var += xa * wa - t * t;
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.mu += o.mu;
        self.var += o.var;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (self.mu, self.var)
    }

    #[inline(always)]
    fn reduce_simd(b: Backend, xm: &[f32], xa: &[f32], wm: &[f32], wa: &[f32]) -> Option<Self> {
        if b == Backend::Scalar {
            return None;
        }
        let (mu, var) = simd::dot_joint_eq12(b, xm, xa, wm, wa);
        Some(Self { mu, var })
    }

    #[inline(always)]
    fn reduce_simd_packed(
        b: Backend,
        xm: &[f32],
        xa: &[f32],
        wm: PackedSlice<'_>,
        wa: PackedSlice<'_>,
    ) -> Option<Self> {
        if b == Backend::Scalar {
            return None;
        }
        let (mu, var) = simd::dot_joint_eq12_packed(b, xm, xa, wm, wa);
        Some(Self { mu, var })
    }
}

/// Eq. 5 joint kernel (original form): aux operands are E[x^2] and the
/// weight *variance*; the mean product is not reused.
#[derive(Clone, Copy, Default)]
pub struct JointEq5 {
    mu: f32,
    var: f32,
}

impl Accum for JointEq5 {
    #[inline(always)]
    fn step(&mut self, xm: f32, xa: f32, wm: f32, wa: f32) {
        self.mu += xm * wm;
        // sigma_w^2 * E[x^2] + mu_w^2 * (E[x^2] - mu_x^2)
        self.var += wa * xa + wm * wm * (xa - xm * xm);
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.mu += o.mu;
        self.var += o.var;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (self.mu, self.var)
    }
}

/// Eq. 7 joint kernel (variance form): aux operands are activation and
/// weight variances.
#[derive(Clone, Copy, Default)]
pub struct VarForm {
    mu: f32,
    var: f32,
}

impl Accum for VarForm {
    #[inline(always)]
    fn step(&mut self, xm: f32, xa: f32, wm: f32, wa: f32) {
        self.mu += xm * wm;
        // sigma_w^2 * E[x^2] + mu_w^2 * sigma_x^2
        self.var += (xm * xm + xa) * wa + xa * wm * wm;
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.mu += o.mu;
        self.var += o.var;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (self.mu, self.var)
    }
}

/// Eq. 13 first-layer kernel (deterministic input): aux weight operand is
/// the weight variance; activation aux is ignored.
#[derive(Clone, Copy, Default)]
pub struct FirstLayer {
    mu: f32,
    var: f32,
}

impl Accum for FirstLayer {
    #[inline(always)]
    fn step(&mut self, xm: f32, _xa: f32, wm: f32, wa: f32) {
        self.mu += xm * wm;
        self.var += xm * xm * wa;
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.mu += o.mu;
        self.var += o.var;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (self.mu, self.var)
    }

    #[inline(always)]
    fn reduce_simd(b: Backend, xm: &[f32], _xa: &[f32], wm: &[f32], wa: &[f32]) -> Option<Self> {
        if b == Backend::Scalar {
            return None;
        }
        let (mu, var) = simd::dot_first_layer(b, xm, wm, wa);
        Some(Self { mu, var })
    }

    #[inline(always)]
    fn reduce_simd_packed(
        b: Backend,
        xm: &[f32],
        _xa: &[f32],
        wm: PackedSlice<'_>,
        wa: PackedSlice<'_>,
    ) -> Option<Self> {
        if b == Backend::Scalar {
            return None;
        }
        let (mu, var) = simd::dot_first_layer_packed(b, xm, wm, wa);
        Some(Self { mu, var })
    }
}

/// Mean-only pass (the "separate operators" split, Fig. 5).
#[derive(Clone, Copy, Default)]
pub struct MeanOnly {
    mu: f32,
}

impl Accum for MeanOnly {
    #[inline(always)]
    fn step(&mut self, xm: f32, _xa: f32, wm: f32, _wa: f32) {
        self.mu += xm * wm;
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.mu += o.mu;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (self.mu, 0.0)
    }

    #[inline(always)]
    fn reduce_simd(b: Backend, xm: &[f32], _xa: &[f32], wm: &[f32], _wa: &[f32]) -> Option<Self> {
        if b == Backend::Scalar {
            return None;
        }
        Some(Self { mu: simd::dot_mean(b, xm, wm) })
    }

    #[inline(always)]
    fn reduce_simd_packed(
        b: Backend,
        xm: &[f32],
        _xa: &[f32],
        wm: PackedSlice<'_>,
        _wa: PackedSlice<'_>,
    ) -> Option<Self> {
        if b == Backend::Scalar {
            return None;
        }
        Some(Self { mu: simd::dot_mean_packed(b, xm, wm) })
    }
}

/// Variance-only pass, Eq. 12 form (recomputes the mean product — that is
/// the point of the separate-operator baseline).
#[derive(Clone, Copy, Default)]
pub struct VarOnlyEq12 {
    e2: f32,
    cross: f32,
}

impl Accum for VarOnlyEq12 {
    #[inline(always)]
    fn step(&mut self, xm: f32, xa: f32, wm: f32, wa: f32) {
        let t = xm * wm;
        self.cross += t * t;
        self.e2 += xa * wa;
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.e2 += o.e2;
        self.cross += o.cross;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (0.0, self.e2 - self.cross)
    }
}

/// Variance-only pass, Eq. 5 form.
#[derive(Clone, Copy, Default)]
pub struct VarOnlyEq5 {
    var: f32,
}

impl Accum for VarOnlyEq5 {
    #[inline(always)]
    fn step(&mut self, xm: f32, xa: f32, wm: f32, wa: f32) {
        self.var += wa * xa + wm * wm * (xa - xm * xm);
    }

    #[inline(always)]
    fn merge(&mut self, o: Self) {
        self.var += o.var;
    }

    #[inline(always)]
    fn finish(self) -> (f32, f32) {
        (0.0, self.var)
    }
}

// ---------------------------------------------------------------------------
// inner reduction with schedule knobs
// ---------------------------------------------------------------------------

/// Reduce one (m, n) pair over k with `LANES` independent accumulators
/// (the unroll/vectorize machinery; LANES is a compile-time constant so
/// LLVM sees a fixed-width pattern it can vectorize).
#[inline(always)]
fn reduce_lanes<A: Accum, const LANES: usize>(
    xm: &[f32],
    xa: &[f32],
    wm: &[f32],
    wa: &[f32],
) -> A {
    let k = xm.len();
    let mut lanes = [A::default(); LANES];
    let chunks = k / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let i = base + l;
            lanes[l].step(xm[i], xa[i], wm[i], wa[i]);
        }
    }
    let mut acc = lanes[0];
    for lane in lanes.iter().skip(1) {
        acc.merge(*lane);
    }
    for i in chunks * LANES..k {
        acc.step(xm[i], xa[i], wm[i], wa[i]);
    }
    acc
}

#[inline(always)]
fn reduce<A: Accum>(sched: &Schedule, xm: &[f32], xa: &[f32], wm: &[f32], wa: &[f32]) -> A {
    let mut lanes = if sched.vectorize { 8 } else { 1 } * sched.unroll.max(1);
    // The dispatch below only has power-of-two kernels: round a non-pow2
    // lane count (e.g. unroll=3 with vectorize -> 24) *down* to one, so
    // it never falls through to the widest 64-lane kernel and pays its
    // init/merge cost for a tiny K.
    if !lanes.is_power_of_two() {
        lanes = lanes.next_power_of_two() / 2;
    }
    // Never use more lanes than reduction elements: a short K (e.g. a 5x5
    // single-channel conv's K=25) would otherwise pay full lane-array
    // init + merge while every element lands in the scalar remainder.
    while lanes > 1 && lanes > xm.len() {
        lanes /= 2;
    }
    match lanes {
        1 => reduce_lanes::<A, 1>(xm, xa, wm, wa),
        2 => reduce_lanes::<A, 2>(xm, xa, wm, wa),
        4 => reduce_lanes::<A, 4>(xm, xa, wm, wa),
        8 => reduce_lanes::<A, 8>(xm, xa, wm, wa),
        16 => reduce_lanes::<A, 16>(xm, xa, wm, wa),
        32 => reduce_lanes::<A, 32>(xm, xa, wm, wa),
        _ => reduce_lanes::<A, 64>(xm, xa, wm, wa),
    }
}

/// Inputs to a dense kernel: mean + aux matrices for activations `[M, K]`
/// and weights `[N, K]`, with optional (mu, var) bias vectors `[N]`.
pub struct DenseArgs<'a> {
    pub x_mu: &'a Tensor,
    pub x_aux: &'a Tensor,
    pub w_mu: &'a Tensor,
    pub w_aux: &'a Tensor,
    pub b_mu: Option<&'a [f32]>,
    pub b_var: Option<&'a [f32]>,
}

impl<'a> DenseArgs<'a> {
    fn dims(&self) -> (usize, usize, usize) {
        let m = self.x_mu.rows();
        let k = self.x_mu.cols();
        let n = self.w_mu.rows();
        debug_assert_eq!(self.w_mu.cols(), k);
        debug_assert_eq!(self.x_aux.shape(), self.x_mu.shape());
        debug_assert_eq!(self.w_aux.shape(), self.w_mu.shape());
        (m, k, n)
    }

    fn as_slices(&self) -> DenseSlices<'a> {
        let (m, k, n) = self.dims();
        DenseSlices {
            m,
            k,
            n,
            x_mu: self.x_mu.data(),
            x_aux: self.x_aux.data(),
            w_mu: self.w_mu.data(),
            w_aux: self.w_aux.data(),
            b_mu: self.b_mu,
            b_var: self.b_var,
        }
    }
}

/// Raw-slice dense kernel inputs with explicit dims. The compiled plan
/// executes directly on workspace slices through this form; the Tensor
/// API ([`DenseArgs`]) lowers onto it.
#[derive(Clone, Copy)]
pub struct DenseSlices<'a> {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// `[M, K]` row-major activation means.
    pub x_mu: &'a [f32],
    /// `[M, K]` activation aux (E\[x^2\] / variance per the formulation).
    pub x_aux: &'a [f32],
    /// `[N, K]` row-major weight means.
    pub w_mu: &'a [f32],
    /// `[N, K]` weight aux.
    pub w_aux: &'a [f32],
    pub b_mu: Option<&'a [f32]>,
    pub b_var: Option<&'a [f32]>,
}

/// Run kernel `A` over rows `rows`, writing `[len(rows), N]` chunks.
fn run_rows<A: Accum>(
    args: &DenseSlices<'_>,
    sched: &Schedule,
    rows: std::ops::Range<usize>,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let (k, n) = (args.k, args.n);
    let xm_all = args.x_mu;
    let xa_all = args.x_aux;
    let wm_all = args.w_mu;
    let wa_all = args.w_aux;
    // The schedule's ISA knob, resolved once per row-range call (a cached
    // atomic load). `Mnk` reductions go through the explicit microkernel
    // when the formulation has one; the scalar backend (and the `Mkn`
    // baseline below) keeps the portable lane machinery bit for bit.
    let be = simd::resolve(sched.isa);

    match sched.loop_order {
        LoopOrder::Mnk if sched.tile_n == 0 && sched.tile_k == 0 => {
            for (local, m) in rows.enumerate() {
                let xm = &xm_all[m * k..(m + 1) * k];
                let xa = &xa_all[m * k..(m + 1) * k];
                for nn in 0..n {
                    let wm = &wm_all[nn * k..(nn + 1) * k];
                    let wa = &wa_all[nn * k..(nn + 1) * k];
                    let acc: A = match A::reduce_simd(be, xm, xa, wm, wa) {
                        Some(acc) => acc,
                        None => reduce(sched, xm, xa, wm, wa),
                    };
                    let (mu, var) = acc.finish();
                    out_mu[local * n + nn] = mu;
                    out_var[local * n + nn] = var;
                }
            }
        }
        LoopOrder::Mnk => {
            // tiled: block the n and k loops. The accumulator block is a
            // fixed-size stack array (no per-row heap allocation); tile_n
            // requests beyond MAX_TILE_N run as MAX_TILE_N-wide sub-blocks,
            // which groups the same independent outputs differently but
            // never touches the per-(m, n) reduction order.
            let tn = (if sched.tile_n == 0 { n } else { sched.tile_n })
                .max(1)
                .min(MAX_TILE_N);
            let tk = (if sched.tile_k == 0 { k } else { sched.tile_k }).max(1);
            for (local, m) in rows.enumerate() {
                let xm = &xm_all[m * k..(m + 1) * k];
                let xa = &xa_all[m * k..(m + 1) * k];
                let mut n0 = 0;
                while n0 < n {
                    let n1 = (n0 + tn).min(n);
                    let mut accs = [A::default(); MAX_TILE_N];
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + tk).min(k);
                        for (ai, nn) in (n0..n1).enumerate() {
                            let wm = &wm_all[nn * k + k0..nn * k + k1];
                            let wa = &wa_all[nn * k + k0..nn * k + k1];
                            let mut part: A =
                                match A::reduce_simd(be, &xm[k0..k1], &xa[k0..k1], wm, wa) {
                                    Some(acc) => acc,
                                    None => reduce(sched, &xm[k0..k1], &xa[k0..k1], wm, wa),
                                };
                            part.merge(accs[ai]);
                            accs[ai] = part;
                        }
                        k0 = k1;
                    }
                    for (ai, nn) in (n0..n1).enumerate() {
                        let (mu, var) = accs[ai].finish();
                        out_mu[local * n + nn] = mu;
                        out_var[local * n + nn] = var;
                    }
                    n0 = n1;
                }
            }
        }
        LoopOrder::Mkn => {
            // naive baseline: inner loop strides the weight matrix by k.
            for (local, m) in rows.enumerate() {
                let mut accs: Vec<A> = vec![A::default(); n];
                for kk in 0..k {
                    let xm = xm_all[m * k + kk];
                    let xa = xa_all[m * k + kk];
                    if sched.vectorize {
                        // "vectorization without reordering": gather strided
                        // lanes into fixed-width temporaries — extra traffic,
                        // no contiguous loads; reproduces Table 2's slowdown.
                        let mut nn = 0;
                        while nn + 8 <= n {
                            let mut wm_l = [0.0f32; 8];
                            let mut wa_l = [0.0f32; 8];
                            for l in 0..8 {
                                wm_l[l] = wm_all[(nn + l) * k + kk];
                                wa_l[l] = wa_all[(nn + l) * k + kk];
                            }
                            for l in 0..8 {
                                accs[nn + l].step(xm, xa, wm_l[l], wa_l[l]);
                            }
                            nn += 8;
                        }
                        for nn2 in nn..n {
                            accs[nn2].step(xm, xa, wm_all[nn2 * k + kk], wa_all[nn2 * k + kk]);
                        }
                    } else {
                        for (nn, acc) in accs.iter_mut().enumerate() {
                            acc.step(xm, xa, wm_all[nn * k + kk], wa_all[nn * k + kk]);
                        }
                    }
                }
                for (nn, acc) in accs.into_iter().enumerate() {
                    let (mu, var) = acc.finish();
                    out_mu[local * n + nn] = mu;
                    out_var[local * n + nn] = var;
                }
            }
        }
    }
}

/// Run kernel `A` serially over output rows `rows` of the full workload
/// described by `args`, writing the `[rows.len(), N]` chunk
/// (chunk-relative row indexing) including the bias/clamp epilogue for
/// those rows — and, when `ep` is not [`Epilogue::None`], the fused
/// moment-matched ReLU(+convert) epilogue on the same cache-hot chunk
/// (the PR 8 fusion hook: the chunk is never written back and re-read by
/// a standalone relu/convert step). This is one planned *tile*: the
/// compiled plan partitions rows over the pool and gang-dispatches this
/// per tile. Partitioning over rows never touches the per-row reduction
/// order, and both epilogues are elementwise — so **any** row partition
/// is bit-identical to the serial whole-matrix pass. Allocation-free for
/// `Mnk` schedules (tiled or not); the deliberately naive `Mkn` baseline
/// allocates its per-row accumulator vector.
pub fn dense_rows_into<A: Accum>(
    args: &DenseSlices<'_>,
    sched: &Schedule,
    ep: Epilogue,
    rows: std::ops::Range<usize>,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let n = args.n;
    debug_assert_eq!(out_mu.len(), (rows.end - rows.start) * n);
    debug_assert_eq!(out_var.len(), (rows.end - rows.start) * n);
    run_rows::<A>(args, sched, rows, out_mu, out_var);
    // bias + clamp epilogue for this tile's rows
    if let Some(b) = args.b_mu {
        for row in out_mu.chunks_mut(n) {
            for (o, bv) in row.iter_mut().zip(b) {
                *o += bv;
            }
        }
    }
    match args.b_var {
        Some(b) => {
            for row in out_var.chunks_mut(n) {
                for (o, bv) in row.iter_mut().zip(b) {
                    *o = (*o + bv).max(0.0);
                }
            }
        }
        None => {
            for o in out_var.iter_mut() {
                *o = o.max(0.0);
            }
        }
    }
    // fused elementwise chain (relu / relu+convert) on the hot chunk
    apply_epilogue(ep, sched.isa, out_mu, out_var);
}

/// Execute kernel `A` with schedule `sched` on `pool`, writing the
/// `[M, N]` moment outputs into caller-provided slices. `threads > 1`
/// splits rows over boxed scope jobs (the interpreted/Tensor-level path);
/// the compiled plan instead pre-partitions rows and calls
/// [`dense_kernel_tiled_into`], whose gang dispatch allocates nothing.
pub fn dense_kernel_into<A: Accum>(
    pool: &ThreadPool,
    args: &DenseSlices<'_>,
    sched: &Schedule,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let (m, n) = (args.m, args.n);
    debug_assert_eq!(out_mu.len(), m * n);
    debug_assert_eq!(out_var.len(), m * n);
    debug_assert_eq!(args.x_mu.len(), m * args.k);
    debug_assert_eq!(args.x_aux.len(), m * args.k);
    debug_assert_eq!(args.w_mu.len(), n * args.k);
    debug_assert_eq!(args.w_aux.len(), n * args.k);

    let threads = sched.threads.max(1).min(m.max(1));
    if threads <= 1 {
        dense_rows_into::<A>(args, sched, Epilogue::None, 0..m, out_mu, out_var);
        return;
    }
    let ranges = split_ranges(m, threads);
    // split both output buffers into matching disjoint row chunks
    let mut mu_rest: &mut [f32] = &mut *out_mu;
    let mut var_rest: &mut [f32] = &mut *out_var;
    let mut chunks = Vec::new();
    for r in ranges {
        let take = (r.end - r.start) * n;
        let (mu_head, mu_tail) = mu_rest.split_at_mut(take);
        let (var_head, var_tail) = var_rest.split_at_mut(take);
        chunks.push((r, mu_head, var_head));
        mu_rest = mu_tail;
        var_rest = var_tail;
    }
    pool.scope(|s| {
        for (r, mu_chunk, var_chunk) in chunks {
            s.spawn(move || {
                dense_rows_into::<A>(args, sched, Epilogue::None, r, mu_chunk, var_chunk)
            });
        }
    });
}

/// Execute kernel `A` the way [`CompiledPlan`](crate::plan::CompiledPlan)
/// does: the output rows are pre-partitioned into `tiles` (see
/// `plan::tile_ranges`), each tile runs the serial kernel over its own
/// disjoint output chunk, and the tiles are gang-dispatched onto `pool`
/// with **zero heap allocation** ([`ThreadPool::run_tasks`]). With zero
/// or one tile this is exactly the serial path. The schedule's own
/// `threads` knob is ignored here — the plan-level tile partition *is*
/// the parallelization — and row partitioning keeps the result
/// bit-identical to the serial pass.
pub fn dense_kernel_tiled_into<A: Accum>(
    pool: &ThreadPool,
    args: &DenseSlices<'_>,
    sched: &Schedule,
    ep: Epilogue,
    tiles: &[std::ops::Range<usize>],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let serial = sched.with_threads(1);
    if tiles.len() <= 1 {
        dense_rows_into::<A>(args, &serial, ep, 0..args.m, out_mu, out_var);
        return;
    }
    let n = args.n;
    let mu = DisjointMut::new(out_mu);
    let var = DisjointMut::new(out_var);
    pool.run_tasks(tiles.len(), &|ti| {
        let r = tiles[ti].clone();
        let len = (r.end - r.start) * n;
        let (mu_chunk, var_chunk) =
            // SAFETY: tiles are disjoint row ranges, so the chunks never
            // overlap, and run_tasks blocks until every tile completes.
            unsafe { (mu.slice(r.start * n, len), var.slice(r.start * n, len)) };
        dense_rows_into::<A>(args, &serial, ep, r, mu_chunk, var_chunk);
    });
}

// ---------------------------------------------------------------------------
// mixed-precision (packed-weight) twins of the loop nest
// ---------------------------------------------------------------------------
//
// Same schedule machinery, same bias/clamp/fused-epilogue tail, but the
// weight operands are [`PackedSlice`]s: f16/bf16 bits widened to f32
// registers inside the reduction (or plain f32 — mean and variance
// precision are independent), with **all accumulation in f32**. Every
// path mirrors its f32 twin's loop/lane structure exactly, so a packed
// kernel is bitwise the f32 kernel run on pre-widened weight copies —
// the invariant the differential harness pins per backend.

/// [`reduce_lanes`] with packed weight operands (per-element widen via
/// the scalar reference — exact, so lane structure decides the bits).
#[inline(always)]
fn reduce_lanes_packed<A: Accum, const LANES: usize>(
    xm: &[f32],
    xa: &[f32],
    wm: PackedSlice<'_>,
    wa: PackedSlice<'_>,
) -> A {
    let k = xm.len();
    let mut lanes = [A::default(); LANES];
    let chunks = k / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let i = base + l;
            lanes[l].step(xm[i], xa[i], wm.get(i), wa.get(i));
        }
    }
    let mut acc = lanes[0];
    for lane in lanes.iter().skip(1) {
        acc.merge(*lane);
    }
    for i in chunks * LANES..k {
        acc.step(xm[i], xa[i], wm.get(i), wa.get(i));
    }
    acc
}

/// [`reduce`] with packed weight operands: identical lane-count
/// legalization, so the packed scalar path matches widen-then-f32 at any
/// unroll/vectorize setting.
#[inline(always)]
fn reduce_packed<A: Accum>(
    sched: &Schedule,
    xm: &[f32],
    xa: &[f32],
    wm: PackedSlice<'_>,
    wa: PackedSlice<'_>,
) -> A {
    let mut lanes = if sched.vectorize { 8 } else { 1 } * sched.unroll.max(1);
    if !lanes.is_power_of_two() {
        lanes = lanes.next_power_of_two() / 2;
    }
    while lanes > 1 && lanes > xm.len() {
        lanes /= 2;
    }
    match lanes {
        1 => reduce_lanes_packed::<A, 1>(xm, xa, wm, wa),
        2 => reduce_lanes_packed::<A, 2>(xm, xa, wm, wa),
        4 => reduce_lanes_packed::<A, 4>(xm, xa, wm, wa),
        8 => reduce_lanes_packed::<A, 8>(xm, xa, wm, wa),
        16 => reduce_lanes_packed::<A, 16>(xm, xa, wm, wa),
        32 => reduce_lanes_packed::<A, 32>(xm, xa, wm, wa),
        _ => reduce_lanes_packed::<A, 64>(xm, xa, wm, wa),
    }
}

/// [`DenseSlices`] with packed weight operands. Activations stay f32 —
/// reduced-precision *activation* storage happens between steps (the
/// plan narrows a step's output through the workspace's packed buffer),
/// so the kernel always streams f32 activation rows.
#[derive(Clone, Copy)]
pub struct PackedDenseSlices<'a> {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// `[M, K]` row-major activation means.
    pub x_mu: &'a [f32],
    /// `[M, K]` activation aux (E\[x^2\] / variance per the formulation).
    pub x_aux: &'a [f32],
    /// `[N, K]` row-major weight means, possibly packed.
    pub w_mu: PackedSlice<'a>,
    /// `[N, K]` weight aux, possibly packed (independent precision).
    pub w_aux: PackedSlice<'a>,
    pub b_mu: Option<&'a [f32]>,
    pub b_var: Option<&'a [f32]>,
}

/// [`run_rows`] with packed weight operands — all three loop orders, so
/// the packed/f32 bit-parity holds across the whole schedule space.
fn run_rows_packed<A: Accum>(
    args: &PackedDenseSlices<'_>,
    sched: &Schedule,
    rows: std::ops::Range<usize>,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let (k, n) = (args.k, args.n);
    let xm_all = args.x_mu;
    let xa_all = args.x_aux;
    let wm_all = args.w_mu;
    let wa_all = args.w_aux;
    let be = simd::resolve(sched.isa);

    match sched.loop_order {
        LoopOrder::Mnk if sched.tile_n == 0 && sched.tile_k == 0 => {
            for (local, m) in rows.enumerate() {
                let xm = &xm_all[m * k..(m + 1) * k];
                let xa = &xa_all[m * k..(m + 1) * k];
                for nn in 0..n {
                    let wm = wm_all.slice(nn * k..(nn + 1) * k);
                    let wa = wa_all.slice(nn * k..(nn + 1) * k);
                    let acc: A = match A::reduce_simd_packed(be, xm, xa, wm, wa) {
                        Some(acc) => acc,
                        None => reduce_packed(sched, xm, xa, wm, wa),
                    };
                    let (mu, var) = acc.finish();
                    out_mu[local * n + nn] = mu;
                    out_var[local * n + nn] = var;
                }
            }
        }
        LoopOrder::Mnk => {
            let tn = (if sched.tile_n == 0 { n } else { sched.tile_n })
                .max(1)
                .min(MAX_TILE_N);
            let tk = (if sched.tile_k == 0 { k } else { sched.tile_k }).max(1);
            for (local, m) in rows.enumerate() {
                let xm = &xm_all[m * k..(m + 1) * k];
                let xa = &xa_all[m * k..(m + 1) * k];
                let mut n0 = 0;
                while n0 < n {
                    let n1 = (n0 + tn).min(n);
                    let mut accs = [A::default(); MAX_TILE_N];
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + tk).min(k);
                        for (ai, nn) in (n0..n1).enumerate() {
                            let wm = wm_all.slice(nn * k + k0..nn * k + k1);
                            let wa = wa_all.slice(nn * k + k0..nn * k + k1);
                            let mut part: A = match A::reduce_simd_packed(
                                be,
                                &xm[k0..k1],
                                &xa[k0..k1],
                                wm,
                                wa,
                            ) {
                                Some(acc) => acc,
                                None => {
                                    reduce_packed(sched, &xm[k0..k1], &xa[k0..k1], wm, wa)
                                }
                            };
                            part.merge(accs[ai]);
                            accs[ai] = part;
                        }
                        k0 = k1;
                    }
                    for (ai, nn) in (n0..n1).enumerate() {
                        let (mu, var) = accs[ai].finish();
                        out_mu[local * n + nn] = mu;
                        out_var[local * n + nn] = var;
                    }
                    n0 = n1;
                }
            }
        }
        LoopOrder::Mkn => {
            // naive baseline, packed: per-element widen in the strided
            // inner loop (never planned for hot serving, kept for the
            // schedule-space parity contract).
            for (local, m) in rows.enumerate() {
                let mut accs: Vec<A> = vec![A::default(); n];
                for kk in 0..k {
                    let xm = xm_all[m * k + kk];
                    let xa = xa_all[m * k + kk];
                    if sched.vectorize {
                        let mut nn = 0;
                        while nn + 8 <= n {
                            let mut wm_l = [0.0f32; 8];
                            let mut wa_l = [0.0f32; 8];
                            for l in 0..8 {
                                wm_l[l] = wm_all.get((nn + l) * k + kk);
                                wa_l[l] = wa_all.get((nn + l) * k + kk);
                            }
                            for l in 0..8 {
                                accs[nn + l].step(xm, xa, wm_l[l], wa_l[l]);
                            }
                            nn += 8;
                        }
                        for nn2 in nn..n {
                            accs[nn2].step(
                                xm,
                                xa,
                                wm_all.get(nn2 * k + kk),
                                wa_all.get(nn2 * k + kk),
                            );
                        }
                    } else {
                        for (nn, acc) in accs.iter_mut().enumerate() {
                            acc.step(xm, xa, wm_all.get(nn * k + kk), wa_all.get(nn * k + kk));
                        }
                    }
                }
                for (nn, acc) in accs.into_iter().enumerate() {
                    let (mu, var) = acc.finish();
                    out_mu[local * n + nn] = mu;
                    out_var[local * n + nn] = var;
                }
            }
        }
    }
}

/// [`dense_rows_into`] with packed weight operands: same bias/clamp tail
/// and fused epilogue on the cache-hot chunk. Allocation-free for `Mnk`
/// schedules — the widen/narrow helpers use registers and stack buffers
/// only (policed by pfp-lint's hot-path allocation ban).
pub fn dense_rows_packed_into<A: Accum>(
    args: &PackedDenseSlices<'_>,
    sched: &Schedule,
    ep: Epilogue,
    rows: std::ops::Range<usize>,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let n = args.n;
    debug_assert_eq!(out_mu.len(), (rows.end - rows.start) * n);
    debug_assert_eq!(out_var.len(), (rows.end - rows.start) * n);
    run_rows_packed::<A>(args, sched, rows, out_mu, out_var);
    if let Some(b) = args.b_mu {
        for row in out_mu.chunks_mut(n) {
            for (o, bv) in row.iter_mut().zip(b) {
                *o += bv;
            }
        }
    }
    match args.b_var {
        Some(b) => {
            for row in out_var.chunks_mut(n) {
                for (o, bv) in row.iter_mut().zip(b) {
                    *o = (*o + bv).max(0.0);
                }
            }
        }
        None => {
            for o in out_var.iter_mut() {
                *o = o.max(0.0);
            }
        }
    }
    apply_epilogue(ep, sched.isa, out_mu, out_var);
}

/// [`dense_kernel_tiled_into`] with packed weight operands: the compiled
/// plan's packed dense step — pre-partitioned tiles, gang dispatch, zero
/// heap allocation, bit-identical at any tile count.
pub fn dense_kernel_packed_tiled_into<A: Accum>(
    pool: &ThreadPool,
    args: &PackedDenseSlices<'_>,
    sched: &Schedule,
    ep: Epilogue,
    tiles: &[std::ops::Range<usize>],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let serial = sched.with_threads(1);
    if tiles.len() <= 1 {
        dense_rows_packed_into::<A>(args, &serial, ep, 0..args.m, out_mu, out_var);
        return;
    }
    let n = args.n;
    let mu = DisjointMut::new(out_mu);
    let var = DisjointMut::new(out_var);
    pool.run_tasks(tiles.len(), &|ti| {
        let r = tiles[ti].clone();
        let len = (r.end - r.start) * n;
        let (mu_chunk, var_chunk) =
            // SAFETY: tiles are disjoint row ranges, so the chunks never
            // overlap, and run_tasks blocks until every tile completes.
            unsafe { (mu.slice(r.start * n, len), var.slice(r.start * n, len)) };
        dense_rows_packed_into::<A>(args, &serial, ep, r, mu_chunk, var_chunk);
    });
}

/// Execute kernel `A` with schedule `sched` on `pool`
/// -> (mu `[M,N]`, var `[M,N]`).
pub fn dense_kernel_in<A: Accum>(
    pool: &ThreadPool,
    args: &DenseArgs<'_>,
    sched: &Schedule,
) -> (Tensor, Tensor) {
    let (m, _, n) = args.dims();
    let mut out_mu = vec![0.0f32; m * n];
    let mut out_var = vec![0.0f32; m * n];
    dense_kernel_into::<A>(pool, &args.as_slices(), sched, &mut out_mu, &mut out_var);
    (
        Tensor::new(vec![m, n], out_mu).unwrap(),
        Tensor::new(vec![m, n], out_var).unwrap(),
    )
}

/// [`dense_kernel_in`] on the process-wide global pool.
pub fn dense_kernel<A: Accum>(args: &DenseArgs<'_>, sched: &Schedule) -> (Tensor, Tensor) {
    dense_kernel_in::<A>(threadpool::global(), args, sched)
}

// ---------------------------------------------------------------------------
// public operator entry points
// ---------------------------------------------------------------------------
//
// Each operator has an `_in` form taking an explicit pool handle (the
// executor threads `Schedules::pool` through these) and a convenience
// form on the process-wide global pool.

/// Joint PFP dense, Eq. 12 (the production operator).
/// aux inputs: activation E[x^2], weight E[w^2].
pub fn pfp_dense_joint(args: &DenseArgs<'_>, sched: &Schedule) -> (Tensor, Tensor) {
    dense_kernel::<JointEq12>(args, sched)
}

/// [`pfp_dense_joint`] on an explicit pool.
pub fn pfp_dense_joint_in(
    pool: &ThreadPool,
    args: &DenseArgs<'_>,
    sched: &Schedule,
) -> (Tensor, Tensor) {
    dense_kernel_in::<JointEq12>(pool, args, sched)
}

/// Joint PFP dense, original Eq. 5 form.
/// aux inputs: activation E[x^2], weight *variance*.
pub fn pfp_dense_joint_eq5(args: &DenseArgs<'_>, sched: &Schedule) -> (Tensor, Tensor) {
    dense_kernel::<JointEq5>(args, sched)
}

/// Variance-form PFP dense, Eq. 7.
/// aux inputs: activation variance, weight variance.
pub fn pfp_dense_varform(args: &DenseArgs<'_>, sched: &Schedule) -> (Tensor, Tensor) {
    dense_kernel::<VarForm>(args, sched)
}

/// First-layer PFP dense, Eq. 13 (deterministic input).
/// aux inputs: ignored activation aux, weight *variance*.
pub fn pfp_dense_first(args: &DenseArgs<'_>, sched: &Schedule) -> (Tensor, Tensor) {
    dense_kernel::<FirstLayer>(args, sched)
}

/// [`pfp_dense_first`] on an explicit pool.
pub fn pfp_dense_first_in(
    pool: &ThreadPool,
    args: &DenseArgs<'_>,
    sched: &Schedule,
) -> (Tensor, Tensor) {
    dense_kernel_in::<FirstLayer>(pool, args, sched)
}

/// Separate-operator PFP dense (Fig. 5 baseline): two full passes over the
/// data — a mean pass and a variance pass with no term sharing.
/// `eq5 = true` uses the Eq. 5 variance form (weight variance aux),
/// otherwise Eq. 12 (weight E[w^2] aux).
pub fn pfp_dense_separate(
    args: &DenseArgs<'_>,
    sched: &Schedule,
    eq5: bool,
) -> (Tensor, Tensor) {
    let (mu, _) = dense_kernel::<MeanOnly>(
        &DenseArgs { b_var: None, ..*args },
        sched,
    );
    let (_, var) = if eq5 {
        dense_kernel::<VarOnlyEq5>(&DenseArgs { b_mu: None, ..*args }, sched)
    } else {
        dense_kernel::<VarOnlyEq12>(&DenseArgs { b_mu: None, ..*args }, sched)
    };
    (mu, var)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn rand_dense(g: &mut Gen, m: usize, k: usize, n: usize) -> (Tensor, Tensor, Tensor, Tensor) {
        let x_mu = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
        let x_var = Tensor::new(vec![m, k], g.var_vec(m * k, 1.0)).unwrap();
        let w_mu = Tensor::new(vec![n, k], g.normal_vec(n * k, 0.2)).unwrap();
        let w_var = Tensor::new(vec![n, k], g.var_vec(n * k, 0.02)).unwrap();
        (x_mu, x_var, w_mu, w_var)
    }

    fn e2_of(mu: &Tensor, var: &Tensor) -> Tensor {
        mu.zip(var, |m, v| m * m + v).unwrap()
    }

    /// Straightforward O(mnk) Eq. 12 reference, no schedule machinery.
    fn naive_eq12(
        x_mu: &Tensor,
        x_e2: &Tensor,
        w_mu: &Tensor,
        w_e2: &Tensor,
    ) -> (Tensor, Tensor) {
        let (m, k, n) = (x_mu.rows(), x_mu.cols(), w_mu.rows());
        let mut mu = vec![0.0f32; m * n];
        let mut var = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let (mut a, mut e, mut c) = (0.0f32, 0.0f32, 0.0f32);
                for kk in 0..k {
                    let xm = x_mu.data()[i * k + kk];
                    let wm = w_mu.data()[j * k + kk];
                    a += xm * wm;
                    c += xm * wm * xm * wm;
                    e += x_e2.data()[i * k + kk] * w_e2.data()[j * k + kk];
                }
                mu[i * n + j] = a;
                var[i * n + j] = (e - c).max(0.0);
            }
        }
        (
            Tensor::new(vec![m, n], mu).unwrap(),
            Tensor::new(vec![m, n], var).unwrap(),
        )
    }

    #[test]
    fn all_schedules_agree_with_naive() {
        let schedules = [
            Schedule::baseline(),
            Schedule::baseline().with_vectorize(true),
            Schedule::tuned(1),
            Schedule::tuned(2),
            Schedule::tiled(8, 32),
            Schedule::tuned(1).with_unroll(4),
            Schedule::tuned(1).with_tiles(16, 64),
        ];
        check(12, |g| {
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 96);
            let n = g.usize_in(1, 40);
            let (x_mu, x_var, w_mu, w_var) = rand_dense(g, m, k, n);
            let x_e2 = e2_of(&x_mu, &x_var);
            let w_e2 = e2_of(&w_mu, &w_var);
            let args = DenseArgs {
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: &w_mu,
                w_aux: &w_e2,
                b_mu: None,
                b_var: None,
            };
            let (want_mu, want_var) = naive_eq12(&x_mu, &x_e2, &w_mu, &w_e2);
            for s in &schedules {
                let (mu, var) = pfp_dense_joint(&args, s);
                assert!(
                    mu.allclose(&want_mu, 1e-4, 1e-4),
                    "mu mismatch {} [{m},{k},{n}]",
                    s.tag()
                );
                assert!(
                    var.allclose(&want_var, 1e-3, 1e-3),
                    "var mismatch {} [{m},{k},{n}]",
                    s.tag()
                );
            }
        });
    }

    #[test]
    fn non_pow2_unroll_matches_naive() {
        // unroll=3 with vectorize gives 24 requested lanes; the dispatcher
        // must round down to a real power-of-two kernel (16), not fall
        // through to the 64-lane one — and stay correct either way.
        let schedules = [
            Schedule::tuned(1).with_unroll(3),
            Schedule::tuned(1).with_unroll(5),
            Schedule::baseline().with_order(LoopOrder::Mnk).with_unroll(3),
        ];
        check(10, |g| {
            let m = g.usize_in(1, 8);
            let k = g.usize_in(1, 96);
            let n = g.usize_in(1, 24);
            let (x_mu, x_var, w_mu, w_var) = rand_dense(g, m, k, n);
            let x_e2 = e2_of(&x_mu, &x_var);
            let w_e2 = e2_of(&w_mu, &w_var);
            let args = DenseArgs {
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: &w_mu,
                w_aux: &w_e2,
                b_mu: None,
                b_var: None,
            };
            let (want_mu, want_var) = naive_eq12(&x_mu, &x_e2, &w_mu, &w_e2);
            for s in &schedules {
                let (mu, var) = pfp_dense_joint(&args, s);
                assert!(
                    mu.allclose(&want_mu, 1e-4, 1e-4),
                    "mu mismatch {} [{m},{k},{n}]",
                    s.tag()
                );
                assert!(
                    var.allclose(&want_var, 1e-3, 1e-3),
                    "var mismatch {} [{m},{k},{n}]",
                    s.tag()
                );
            }
        });
    }

    #[test]
    fn formulations_are_equivalent() {
        // Eq. 5 == Eq. 12 == Eq. 7 == separate, on matching inputs.
        // Pinned to the scalar ISA: the tight separate-vs-joint bound
        // below relies on every formulation running the same scalar
        // arithmetic (the SIMD backends reassociate with FMA and only
        // cover the planned formulations; their cross-ISA contract is
        // policed by `tests/integration_simd_parity.rs`).
        check(12, |g| {
            let m = g.usize_in(1, 8);
            let k = g.usize_in(1, 64);
            let n = g.usize_in(1, 24);
            let (x_mu, x_var, w_mu, w_var) = rand_dense(g, m, k, n);
            let x_e2 = e2_of(&x_mu, &x_var);
            let w_e2 = e2_of(&w_mu, &w_var);
            let s = Schedule::tuned(1).with_isa(crate::ops::simd::Isa::Scalar);

            let eq12 = pfp_dense_joint(
                &DenseArgs {
                    x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
                    b_mu: None, b_var: None,
                },
                &s,
            );
            let eq5 = pfp_dense_joint_eq5(
                &DenseArgs {
                    x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_var,
                    b_mu: None, b_var: None,
                },
                &s,
            );
            let eq7 = pfp_dense_varform(
                &DenseArgs {
                    x_mu: &x_mu, x_aux: &x_var, w_mu: &w_mu, w_aux: &w_var,
                    b_mu: None, b_var: None,
                },
                &s,
            );
            let sep = pfp_dense_separate(
                &DenseArgs {
                    x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
                    b_mu: None, b_var: None,
                },
                &s,
                false,
            );
            assert!(eq5.0.allclose(&eq12.0, 1e-4, 1e-4));
            assert!(eq5.1.allclose(&eq12.1, 2e-3, 2e-3), "eq5 vs eq12 var");
            assert!(eq7.0.allclose(&eq12.0, 1e-4, 1e-4));
            assert!(eq7.1.allclose(&eq12.1, 2e-3, 2e-3), "eq7 vs eq12 var");
            assert!(sep.0.allclose(&eq12.0, 1e-5, 1e-5));
            assert!(sep.1.allclose(&eq12.1, 1e-5, 1e-5));
        });
    }

    #[test]
    fn first_layer_matches_generic_with_det_input() {
        // Eq. 13 == generic Eq. 12 with x_e2 = x^2, w_e2 = mu^2 + var.
        check(10, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 48);
            let n = g.usize_in(1, 16);
            let x = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
            let w_mu = Tensor::new(vec![n, k], g.normal_vec(n * k, 0.2)).unwrap();
            let w_var = Tensor::new(vec![n, k], g.var_vec(n * k, 0.02)).unwrap();
            let w_e2 = e2_of(&w_mu, &w_var);
            let x_sq = x.squared();
            let s = Schedule::tuned(1);
            let first = pfp_dense_first(
                &DenseArgs {
                    x_mu: &x, x_aux: &x_sq, w_mu: &w_mu, w_aux: &w_var,
                    b_mu: None, b_var: None,
                },
                &s,
            );
            let generic = pfp_dense_joint(
                &DenseArgs {
                    x_mu: &x, x_aux: &x_sq, w_mu: &w_mu, w_aux: &w_e2,
                    b_mu: None, b_var: None,
                },
                &s,
            );
            assert!(first.0.allclose(&generic.0, 1e-4, 1e-4));
            assert!(first.1.allclose(&generic.1, 2e-3, 2e-3));
        });
    }

    #[test]
    fn bias_applied() {
        let x_mu = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let x_e2 = x_mu.squared();
        let w_mu = Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap();
        let w_e2 = w_mu.squared();
        let b_mu = [10.0f32];
        let b_var = [0.5f32];
        let (mu, var) = pfp_dense_joint(
            &DenseArgs {
                x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
                b_mu: Some(&b_mu), b_var: Some(&b_var),
            },
            &Schedule::tuned(1),
        );
        assert!((mu.data()[0] - 13.0).abs() < 1e-6);
        assert!((var.data()[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn tiled_gang_dispatch_bit_identical_to_serial() {
        // the planned path's row partition must not change a single bit,
        // at any tile count, for plain and cache-blocked schedules alike
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let mut g = Gen::new(33);
        let (m, k, n) = (13, 96, 24);
        let (x_mu, x_var, w_mu, w_var) = rand_dense(&mut g, m, k, n);
        let x_e2 = e2_of(&x_mu, &x_var);
        let w_e2 = e2_of(&w_mu, &w_var);
        let b_mu: Vec<f32> = g.normal_vec(n, 0.5);
        let b_var: Vec<f32> = g.var_vec(n, 0.1);
        let slices = DenseSlices {
            m,
            k,
            n,
            x_mu: x_mu.data(),
            x_aux: x_e2.data(),
            w_mu: w_mu.data(),
            w_aux: w_e2.data(),
            b_mu: Some(&b_mu),
            b_var: Some(&b_var),
        };
        for sched in [Schedule::tuned(1), Schedule::tiled(16, 32)] {
            // with and without the fused relu epilogue: elementwise, so
            // the row partition stays bit-identical either way
            for ep in [Epilogue::None, Epilogue::Relu, Epilogue::ReluToVar] {
                let mut want_mu = vec![0.0f32; m * n];
                let mut want_var = vec![0.0f32; m * n];
                dense_rows_into::<JointEq12>(&slices, &sched, ep, 0..m, &mut want_mu, &mut want_var);
                for tasks in [2usize, 3, 5, 13] {
                    let tiles = split_ranges(m, tasks);
                    let mut mu = vec![0.0f32; m * n];
                    let mut var = vec![0.0f32; m * n];
                    dense_kernel_tiled_into::<JointEq12>(
                        &pool, &slices, &sched, ep, &tiles, &mut mu, &mut var,
                    );
                    assert_eq!(mu, want_mu, "{} {ep:?} tasks={tasks} mu", sched.tag());
                    assert_eq!(var, want_var, "{} {ep:?} tasks={tasks} var", sched.tag());
                }
            }
        }
    }

    #[test]
    fn packed_dense_is_bitwise_widen_then_f32() {
        // the mixed-precision contract: a packed kernel produces exactly
        // the bits of the f32 kernel run on pre-widened weight copies,
        // for every mean/variance precision pair, schedule shape,
        // epilogue, and tile count (widening is exact, loop structure is
        // mirrored, accumulation is f32 throughout)
        use crate::util::half::{quantize, Precision};
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let precisions = [Precision::F32, Precision::F16, Precision::Bf16];
        let schedules = [
            Schedule::tuned(1),
            Schedule::tiled(16, 32),
            Schedule::baseline().with_vectorize(true),
            Schedule::baseline().with_order(LoopOrder::Mkn).with_vectorize(true),
        ];
        check(6, |g| {
            let m = g.usize_in(1, 9);
            let k = g.usize_in(1, 130);
            let n = g.usize_in(1, 24);
            let (x_mu, x_var, w_mu, w_var) = rand_dense(g, m, k, n);
            let x_e2 = e2_of(&x_mu, &x_var);
            let w_e2 = e2_of(&w_mu, &w_var);
            let b_mu: Vec<f32> = g.normal_vec(n, 0.5);
            let b_var: Vec<f32> = g.var_vec(n, 0.1);
            for &pm in &precisions {
                for &pa in &precisions {
                    // quantize to the storage grid, then build both views
                    // of the same values: widened f32 and packed u16
                    let wm_q: Vec<f32> =
                        w_mu.data().iter().map(|&v| quantize(pm, v)).collect();
                    let wa_q: Vec<f32> =
                        w_e2.data().iter().map(|&v| quantize(pa, v)).collect();
                    let wm_bits: Vec<u16> = w_mu
                        .data()
                        .iter()
                        .map(|&v| crate::util::half::narrow(pm, v))
                        .collect();
                    let wa_bits: Vec<u16> = w_e2
                        .data()
                        .iter()
                        .map(|&v| crate::util::half::narrow(pa, v))
                        .collect();
                    let wm_packed = if pm.is_f32() {
                        PackedSlice::F32(&wm_q)
                    } else {
                        PackedSlice::U16(pm, &wm_bits)
                    };
                    let wa_packed = if pa.is_f32() {
                        PackedSlice::F32(&wa_q)
                    } else {
                        PackedSlice::U16(pa, &wa_bits)
                    };
                    let f32_slices = DenseSlices {
                        m,
                        k,
                        n,
                        x_mu: x_mu.data(),
                        x_aux: x_e2.data(),
                        w_mu: &wm_q,
                        w_aux: &wa_q,
                        b_mu: Some(&b_mu),
                        b_var: Some(&b_var),
                    };
                    let packed_slices = PackedDenseSlices {
                        m,
                        k,
                        n,
                        x_mu: x_mu.data(),
                        x_aux: x_e2.data(),
                        w_mu: wm_packed,
                        w_aux: wa_packed,
                        b_mu: Some(&b_mu),
                        b_var: Some(&b_var),
                    };
                    for sched in &schedules {
                        for ep in [Epilogue::None, Epilogue::Relu] {
                            let mut want_mu = vec![0.0f32; m * n];
                            let mut want_var = vec![0.0f32; m * n];
                            dense_rows_into::<JointEq12>(
                                &f32_slices, sched, ep, 0..m, &mut want_mu, &mut want_var,
                            );
                            let mut mu = vec![0.0f32; m * n];
                            let mut var = vec![0.0f32; m * n];
                            dense_rows_packed_into::<JointEq12>(
                                &packed_slices, sched, ep, 0..m, &mut mu, &mut var,
                            );
                            assert_eq!(
                                mu, want_mu,
                                "{} {ep:?} {pm:?}/{pa:?} mu",
                                sched.tag()
                            );
                            assert_eq!(
                                var, want_var,
                                "{} {ep:?} {pm:?}/{pa:?} var",
                                sched.tag()
                            );
                            // gang dispatch over the packed kernel must
                            // stay bit-identical to its own serial run
                            let tiles = split_ranges(m, 3);
                            let mut tmu = vec![0.0f32; m * n];
                            let mut tvar = vec![0.0f32; m * n];
                            dense_kernel_packed_tiled_into::<JointEq12>(
                                &pool, &packed_slices, sched, ep, &tiles, &mut tmu, &mut tvar,
                            );
                            assert_eq!(tmu, mu, "{} tiled mu", sched.tag());
                            assert_eq!(tvar, var, "{} tiled var", sched.tag());
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn packed_first_and_mean_match_their_f32_twins() {
        // same bit-parity contract for the Eq. 13 first-layer and
        // mean-only formulations the plan actually dispatches packed
        use crate::util::half::{narrow, quantize, Precision};
        check(6, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 96);
            let n = g.usize_in(1, 16);
            let x = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
            let x_sq = x.squared();
            let w_mu = Tensor::new(vec![n, k], g.normal_vec(n * k, 0.2)).unwrap();
            let w_var = Tensor::new(vec![n, k], g.var_vec(n * k, 0.02)).unwrap();
            for prec in [Precision::F16, Precision::Bf16] {
                let wm_q: Vec<f32> = w_mu.data().iter().map(|&v| quantize(prec, v)).collect();
                let wv_q: Vec<f32> = w_var.data().iter().map(|&v| quantize(prec, v)).collect();
                let wm_bits: Vec<u16> = w_mu.data().iter().map(|&v| narrow(prec, v)).collect();
                let wv_bits: Vec<u16> = w_var.data().iter().map(|&v| narrow(prec, v)).collect();
                let sched = Schedule::tuned(1);
                let f32_slices = DenseSlices {
                    m,
                    k,
                    n,
                    x_mu: x.data(),
                    x_aux: x_sq.data(),
                    w_mu: &wm_q,
                    w_aux: &wv_q,
                    b_mu: None,
                    b_var: None,
                };
                let packed_slices = PackedDenseSlices {
                    m,
                    k,
                    n,
                    x_mu: x.data(),
                    x_aux: x_sq.data(),
                    w_mu: PackedSlice::U16(prec, &wm_bits),
                    w_aux: PackedSlice::U16(prec, &wv_bits),
                    b_mu: None,
                    b_var: None,
                };
                let mut want_mu = vec![0.0f32; m * n];
                let mut want_var = vec![0.0f32; m * n];
                let mut mu = vec![0.0f32; m * n];
                let mut var = vec![0.0f32; m * n];
                dense_rows_into::<FirstLayer>(
                    &f32_slices, &sched, Epilogue::None, 0..m, &mut want_mu, &mut want_var,
                );
                dense_rows_packed_into::<FirstLayer>(
                    &packed_slices, &sched, Epilogue::None, 0..m, &mut mu, &mut var,
                );
                assert_eq!(mu, want_mu, "{prec:?} first mu");
                assert_eq!(var, want_var, "{prec:?} first var");
                dense_rows_into::<MeanOnly>(
                    &f32_slices, &sched, Epilogue::None, 0..m, &mut want_mu, &mut want_var,
                );
                dense_rows_packed_into::<MeanOnly>(
                    &packed_slices, &sched, Epilogue::None, 0..m, &mut mu, &mut var,
                );
                assert_eq!(mu, want_mu, "{prec:?} mean mu");
            }
        });
    }

    #[test]
    fn simd_schedule_matches_scalar_schedule_closely() {
        // the explicit-ISA kernels reassociate the reduction (FMA, lane
        // sums) but must stay within the documented 1e-4 relative
        // cross-ISA contract on the production formulations
        use crate::ops::simd::Isa;
        check(10, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 160);
            let n = g.usize_in(1, 32);
            let (x_mu, x_var, w_mu, w_var) = rand_dense(g, m, k, n);
            let x_e2 = e2_of(&x_mu, &x_var);
            let w_e2 = e2_of(&w_mu, &w_var);
            let args = DenseArgs {
                x_mu: &x_mu,
                x_aux: &x_e2,
                w_mu: &w_mu,
                w_aux: &w_e2,
                b_mu: None,
                b_var: None,
            };
            let scalar = Schedule::tuned(1).with_isa(Isa::Scalar);
            let native = Schedule::tuned(1).with_isa(Isa::Native);
            let (mu_s, var_s) = pfp_dense_joint(&args, &scalar);
            let (mu_n, var_n) = pfp_dense_joint(&args, &native);
            assert!(mu_n.allclose(&mu_s, 1e-4, 1e-4), "mu [{m},{k},{n}]");
            assert!(var_n.allclose(&var_s, 1e-3, 1e-3), "var [{m},{k},{n}]");
            // first-layer kernel too (det input)
            let x_sq = x_mu.squared();
            let fargs = DenseArgs {
                x_mu: &x_mu,
                x_aux: &x_sq,
                w_mu: &w_mu,
                w_aux: &w_var,
                b_mu: None,
                b_var: None,
            };
            let (fmu_s, fvar_s) = pfp_dense_first(&fargs, &scalar);
            let (fmu_n, fvar_n) = pfp_dense_first(&fargs, &native);
            assert!(fmu_n.allclose(&fmu_s, 1e-4, 1e-4), "first mu");
            assert!(fvar_n.allclose(&fvar_s, 1e-3, 1e-3), "first var");
        });
    }

    #[test]
    fn variance_never_negative() {
        check(20, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 64);
            let n = g.usize_in(1, 20);
            let (x_mu, x_var, w_mu, w_var) = rand_dense(g, m, k, n);
            let x_e2 = e2_of(&x_mu, &x_var);
            let w_e2 = e2_of(&w_mu, &w_var);
            let (_, var) = pfp_dense_joint(
                &DenseArgs {
                    x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
                    b_mu: None, b_var: None,
                },
                &Schedule::tuned(1),
            );
            assert!(var.data().iter().all(|&v| v >= 0.0));
        });
    }
}
