//! Extension activation operators: moment-matched sigmoid / tanh and the
//! probabilistic average pool.
//!
//! The paper's operator library covers MLPs and CNNs with ReLU + max-pool;
//! these are the natural next operators a PFP user needs (the paper's
//! "enabling new network architectures" direction), implemented with the
//! standard probit approximation (Roth 2021 lineage):
//!
//! * `sigmoid(x) ~ Phi(zeta * x)`, `zeta = sqrt(pi/8)`, so for
//!   `X ~ N(mu, s^2)`:
//!   `E[sigmoid(X)] ~ Phi(zeta mu / sqrt(1 + zeta^2 s^2))`;
//!   the output variance uses the Barber-Bishop-style shrinkage
//!   `Var ~ m(1-m)(1 - 1/sqrt(1 + zeta^2 s^2))`, validated against
//!   Monte-Carlo below (these are *approximations*; tolerances are
//!   documented in the tests).
//! * `tanh(x) = 2 sigmoid(2x) - 1` transfers both moments linearly.
//! * average pooling is linear, so it is *exact* under the mean-field
//!   assumption: means average; variances average with a 1/k^2 factor.

use crate::tensor::{ProbTensor, Rep, Tensor};

use super::erf::norm_cdf;

/// zeta = sqrt(pi / 8), the probit-sigmoid matching constant.
pub const ZETA: f32 = 0.626_657_07;

/// Moment-matched sigmoid: (mu, var) -> (mean, variance).
#[inline(always)]
pub fn sigmoid_moments(mu: f32, var: f32) -> (f32, f32) {
    let denom = (1.0 + ZETA * ZETA * var).sqrt();
    let m = norm_cdf(ZETA * mu / denom);
    let shrink = 1.0 - 1.0 / denom;
    let v = (m * (1.0 - m) * shrink).max(0.0);
    (m, v)
}

/// Moment-matched tanh via `tanh(x) = 2 sigmoid(2x) - 1`.
#[inline(always)]
pub fn tanh_moments(mu: f32, var: f32) -> (f32, f32) {
    let (m, v) = sigmoid_moments(2.0 * mu, 4.0 * var);
    (2.0 * m - 1.0, 4.0 * v)
}

/// PFP sigmoid over a tensor. Input rep Var; output rep E2 (activation
/// contract, like ReLU).
pub fn pfp_sigmoid(input: ProbTensor) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let shape = input.mu.shape().to_vec();
    let mu_in = input.mu.into_data();
    let var_in = input.aux.into_data();
    let mut mu = vec![0.0f32; mu_in.len()];
    let mut e2 = vec![0.0f32; mu_in.len()];
    for i in 0..mu_in.len() {
        let (m, v) = sigmoid_moments(mu_in[i], var_in[i]);
        mu[i] = m;
        e2[i] = v + m * m;
    }
    ProbTensor::new(
        Tensor::new(shape.clone(), mu).unwrap(),
        Tensor::new(shape, e2).unwrap(),
        Rep::E2,
    )
}

/// PFP tanh over a tensor (rep contract as above).
pub fn pfp_tanh(input: ProbTensor) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let shape = input.mu.shape().to_vec();
    let mu_in = input.mu.into_data();
    let var_in = input.aux.into_data();
    let mut mu = vec![0.0f32; mu_in.len()];
    let mut e2 = vec![0.0f32; mu_in.len()];
    for i in 0..mu_in.len() {
        let (m, v) = tanh_moments(mu_in[i], var_in[i]);
        mu[i] = m;
        e2[i] = v + m * m;
    }
    ProbTensor::new(
        Tensor::new(shape.clone(), mu).unwrap(),
        Tensor::new(shape, e2).unwrap(),
        Rep::E2,
    )
}

/// Probabilistic 2x2/stride-2 average pool over NCHW (mean, variance):
/// exact for independent Gaussians — means average, variances get 1/k^2.
pub fn pfp_avgpool2(input: &ProbTensor) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let s = input.mu.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mu = input.mu.data();
    let var = input.aux.data();
    let mut out_mu = vec![0.0f32; n * c * oh * ow];
    let mut out_var = vec![0.0f32; n * c * oh * ow];
    for plane in 0..n * c {
        let base = plane * h * w;
        let obase = plane * oh * ow;
        for oy in 0..oh {
            let r0 = base + 2 * oy * w;
            let r1 = r0 + w;
            for ox in 0..ow {
                let i = 2 * ox;
                out_mu[obase + oy * ow + ox] =
                    0.25 * (mu[r0 + i] + mu[r0 + i + 1] + mu[r1 + i] + mu[r1 + i + 1]);
                out_var[obase + oy * ow + ox] = 0.0625
                    * (var[r0 + i] + var[r0 + i + 1] + var[r1 + i] + var[r1 + i + 1]);
            }
        }
    }
    ProbTensor::new(
        Tensor::new(vec![n, c, oh, ow], out_mu).unwrap(),
        Tensor::new(vec![n, c, oh, ow], out_var).unwrap(),
        Rep::Var,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    fn mc_moments(f: impl Fn(f64) -> f64, mu: f32, var: f32, n: usize) -> (f64, f64) {
        let mut rng = SplitMix64::new(99);
        let std = (var as f64).sqrt();
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let y = f(mu as f64 + std * rng.normal());
            s += y;
            s2 += y * y;
        }
        let m = s / n as f64;
        (m, s2 / n as f64 - m * m)
    }

    #[test]
    fn sigmoid_mean_against_monte_carlo() {
        for (mu, var) in [(-2.0f32, 0.5f32), (0.0, 1.0), (1.5, 2.0), (3.0, 0.2)] {
            let (m, _) = sigmoid_moments(mu, var);
            let (mc_m, _) = mc_moments(|x| 1.0 / (1.0 + (-x).exp()), mu, var, 200_000);
            assert!(
                (m as f64 - mc_m).abs() < 0.02,
                "sigmoid mean mu={mu} var={var}: {m} vs {mc_m}"
            );
        }
    }

    #[test]
    fn sigmoid_variance_against_monte_carlo() {
        // the variance shrinkage is a rougher approximation: 30% rel. tol.
        for (mu, var) in [(0.0f32, 1.0f32), (1.0, 2.0), (-1.0, 0.5)] {
            let (_, v) = sigmoid_moments(mu, var);
            let (_, mc_v) = mc_moments(|x| 1.0 / (1.0 + (-x).exp()), mu, var, 200_000);
            assert!(
                (v as f64 - mc_v).abs() < 0.3 * mc_v.max(0.01),
                "sigmoid var mu={mu} var={var}: {v} vs {mc_v}"
            );
        }
    }

    #[test]
    fn tanh_mean_against_monte_carlo() {
        for (mu, var) in [(-1.0f32, 0.5f32), (0.0, 1.0), (0.8, 0.3)] {
            let (m, _) = tanh_moments(mu, var);
            let (mc_m, _) = mc_moments(|x| x.tanh(), mu, var, 200_000);
            assert!(
                (m as f64 - mc_m).abs() < 0.03,
                "tanh mean mu={mu} var={var}: {m} vs {mc_m}"
            );
        }
    }

    #[test]
    fn sigmoid_bounds_and_monotonicity() {
        check(40, |g| {
            let mu = g.normal(3.0);
            let var = g.normal(2.0).abs() + 1e-6;
            let (m, v) = sigmoid_moments(mu, var);
            assert!((0.0..=1.0).contains(&m));
            assert!(v >= 0.0 && v <= 0.25 + 1e-6); // Var[sigmoid] <= 1/4
            // mean monotone in mu
            let (m2, _) = sigmoid_moments(mu + 0.5, var);
            assert!(m2 >= m - 1e-6);
        });
    }

    #[test]
    fn deterministic_limits() {
        // var -> 0 reduces to the probit approximation of sigmoid itself,
        // whose intrinsic error is ~1e-2 at moderate |x| — that is the
        // tolerance here, not a numerical bug.
        let (m, v) = sigmoid_moments(1.2, 1e-12);
        assert!((m - 1.0 / (1.0 + (-1.2f32).exp())).abs() < 1e-2);
        assert!(v < 1e-6);
        let (mt, vt) = tanh_moments(-0.7, 1e-12);
        assert!((mt - (-0.7f32).tanh()).abs() < 2e-2);
        assert!(vt < 1e-5);
    }

    #[test]
    fn avgpool_exact_linearity() {
        // constant plane: mean preserved, variance shrinks by 4
        let mu = Tensor::full(vec![1, 1, 4, 4], 2.0);
        let var = Tensor::full(vec![1, 1, 4, 4], 1.0);
        let out = pfp_avgpool2(&ProbTensor::new(mu, var, Rep::Var));
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        assert!(out.mu.data().iter().all(|&m| (m - 2.0).abs() < 1e-6));
        assert!(out.aux.data().iter().all(|&v| (v - 0.25).abs() < 1e-6));
    }

    #[test]
    fn avgpool_mc_agreement() {
        // E and Var of the average of 4 independent Gaussians is exact
        let mut g = crate::util::prop::Gen::new(5);
        let mu = Tensor::new(vec![1, 1, 2, 2], g.normal_vec(4, 1.0)).unwrap();
        let var = Tensor::new(vec![1, 1, 2, 2], g.var_vec(4, 0.5)).unwrap();
        let out = pfp_avgpool2(&ProbTensor::new(mu.clone(), var.clone(), Rep::Var));
        let want_m: f32 = mu.data().iter().sum::<f32>() / 4.0;
        let want_v: f32 = var.data().iter().sum::<f32>() / 16.0;
        assert!((out.mu.data()[0] - want_m).abs() < 1e-6);
        assert!((out.aux.data()[0] - want_v).abs() < 1e-6);
    }

    #[test]
    fn activation_tensor_contract() {
        let mut g = crate::util::prop::Gen::new(6);
        let mu = Tensor::from_vec(g.normal_vec(32, 1.0));
        let var = Tensor::from_vec(g.var_vec(32, 0.5));
        let out = pfp_sigmoid(ProbTensor::new(mu, var, Rep::Var));
        assert_eq!(out.rep, Rep::E2);
        // Jensen: E[y^2] >= E[y]^2
        for (m, e2) in out.mu.data().iter().zip(out.aux.data()) {
            assert!(e2 - m * m >= -1e-6);
        }
    }
}
