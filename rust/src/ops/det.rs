//! Deterministic operators — the non-probabilistic baseline of Table 5
//! and the per-sample forward pass of the SVI baseline.
//!
//! The dense core reuses the scheduled reduction machinery via the
//! [`super::dense::MeanOnly`] accumulator so the deterministic network is
//! benchmarked with the same tuning treatment the paper gives its
//! deterministic NN ("not tuned" = baseline schedule, "tuned" = tuned
//! schedule).

use crate::tensor::Tensor;

use super::conv::im2col;
use super::dense::{dense_kernel, DenseArgs, MeanOnly};
use super::schedule::Schedule;

/// Deterministic dense: `x [M,K] @ w.T [N,K] + b`.
pub fn det_dense(x: &Tensor, w: &Tensor, b: Option<&[f32]>, sched: &Schedule) -> Tensor {
    let (mu, _) = dense_kernel::<MeanOnly>(
        &DenseArgs {
            x_mu: x,
            x_aux: x, // unused by MeanOnly
            w_mu: w,
            w_aux: w, // unused by MeanOnly
            b_mu: b,
            b_var: None,
        },
        sched,
    );
    mu
}

/// Deterministic conv2d (NCHW / OIHW / VALID / stride 1) via im2col.
pub fn det_conv2d(x: &Tensor, w: &Tensor, b: Option<&[f32]>, sched: &Schedule) -> Tensor {
    let ws = w.shape();
    let (o, i, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
    debug_assert_eq!(x.shape()[1], i);
    let (patches, (n, oh, ow)) = im2col(x, kh, kw);
    let wm = w.clone().reshape(vec![o, i * kh * kw]).unwrap();
    let flat = det_dense(&patches, &wm, b, sched);
    // scatter [N*OH*OW, O] -> [N, O, OH, OW]
    let d = flat.data();
    let mut out = vec![0.0f32; n * o * oh * ow];
    for img in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((img * oh + oy) * ow + ox) * o;
                for ch in 0..o {
                    out[((img * o + ch) * oh + oy) * ow + ox] = d[row + ch];
                }
            }
        }
    }
    Tensor::new(vec![n, o, oh, ow], out).unwrap()
}

/// Deterministic ReLU.
pub fn det_relu(x: &Tensor) -> Tensor {
    x.map(|v| v.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn dense_matches_naive() {
        check(10, |g| {
            let m = g.usize_in(1, 8);
            let k = g.usize_in(1, 64);
            let n = g.usize_in(1, 24);
            let x = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
            let w = Tensor::new(vec![n, k], g.normal_vec(n * k, 1.0)).unwrap();
            let got = det_dense(&x, &w, None, &Schedule::tuned(1));
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k)
                        .map(|kk| x.data()[i * k + kk] * w.data()[j * k + kk])
                        .sum();
                    let v = got.data()[i * n + j];
                    assert!((v - want).abs() <= 1e-4 + 1e-4 * want.abs());
                }
            }
        });
    }

    #[test]
    fn dense_bias() {
        let x = Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap();
        let w = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = [10.0f32, 20.0];
        let y = det_dense(&x, &w, Some(&b), &Schedule::baseline());
        assert_eq!(y.data(), &[13.0, 27.0]);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1.0 reproduces the input
        let mut g = Gen::new(2);
        let x = Tensor::new(vec![1, 1, 4, 4], g.normal_vec(16, 1.0)).unwrap();
        let w = Tensor::new(vec![1, 1, 1, 1], vec![1.0]).unwrap();
        let y = det_conv2d(&x, &w, None, &Schedule::tuned(1));
        assert!(y.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn conv_shape() {
        let x = Tensor::zeros(vec![2, 3, 10, 10]);
        let w = Tensor::zeros(vec![5, 3, 3, 3]);
        let y = det_conv2d(&x, &w, None, &Schedule::baseline());
        assert_eq!(y.shape(), &[2, 5, 8, 8]);
    }

    #[test]
    fn relu_clamps() {
        let x = Tensor::from_vec(vec![-1.0, 0.0, 2.0]);
        assert_eq!(det_relu(&x).data(), &[0.0, 0.0, 2.0]);
    }
}
