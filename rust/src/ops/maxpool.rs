//! PFP max-pool — moment-matched Gaussian max (Roth 2021), the Table 3
//! operator.
//!
//! Consumes and produces (mean, variance) — the paper's pooling
//! representation contract. Two implementations, mirroring Table 3:
//!
//! * [`pfp_maxpool_generic`] — generic reduction over an arbitrary `k`/
//!   `stride` window: sequential pairwise folds (the slow formulation the
//!   paper inherited from Roth's operator).
//! * [`pfp_maxpool2_vectorized`] — fixed k=2/stride-2: the three pairwise
//!   matches arranged as a balanced tree over four strided views with
//!   contiguous inner loops (the paper's hand-vectorized operator).
//!
//! NOTE: Gaussian moment matching is **not associative**, so the two
//! implementations are *slightly* different approximations (tree vs
//! sequential fold). The vectorized tree matches the Pallas/JAX kernel
//! (`kernels/maxpool.py`) exactly — that is the cross-language contract —
//! and both are validated against Monte-Carlo.
//!
//! The k=2 tree additionally takes an [`Isa`]: `Native` evaluates the
//! three pairwise matches on the explicit SIMD backends of
//! [`ops::simd`](super::simd) — the strided window operands are gathered
//! into fixed 8-lane stack buffers and the expensive erf/exp/div/sqrt
//! math runs vectorized (same association order, so it is the *same*
//! approximation as the scalar tree up to FMA/poly-exp rounding, within
//! the 1e-4 cross-ISA contract); `Scalar` keeps the historical per-pixel
//! loop bit for bit. The generic reduction stays scalar by design (it is
//! the Table-3 slow baseline).

use crate::tensor::{ProbTensor, Rep, Tensor};
use crate::util::threadpool::{split_ranges, DisjointMut, ThreadPool};

use super::erf::{erf, norm_pdf, FRAC_1_SQRT_2};
use super::simd::{self, Backend, Isa};

const EPS: f32 = 1e-12;

/// Moment-matched max of two independent Gaussians -> (mean, variance).
#[inline(always)]
pub fn gaussian_max(mu1: f32, var1: f32, mu2: f32, var2: f32) -> (f32, f32) {
    let theta = (var1 + var2).max(EPS).sqrt();
    let alpha = (mu1 - mu2) / theta;
    let cdf = 0.5 * (1.0 + erf(alpha * FRAC_1_SQRT_2));
    let pdf = norm_pdf(alpha);
    let m = mu1 * cdf + mu2 * (1.0 - cdf) + theta * pdf;
    let e2 = (mu1 * mu1 + var1) * cdf
        + (mu2 * mu2 + var2) * (1.0 - cdf)
        + (mu1 + mu2) * theta * pdf;
    (m, (e2 - m * m).max(0.0))
}

fn out_hw(h: usize, w: usize, k: usize, stride: usize) -> (usize, usize) {
    ((h - k) / stride + 1, (w - k) / stride + 1)
}

/// Slice-level generic-reduction PFP max-pool (see
/// [`pfp_maxpool_generic`]); writes into caller-provided buffers.
#[allow(clippy::too_many_arguments)]
pub fn pfp_maxpool_generic_into(
    mu: &[f32],
    var: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let (oh, ow) = out_hw(h, w, k, stride);
    debug_assert_eq!(mu.len(), n * c * h * w);
    debug_assert_eq!(out_mu.len(), n * c * oh * ow);
    for img in 0..n {
        for ch in 0..c {
            let base = (img * c + ch) * h * w;
            let obase = (img * c + ch) * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc_m = f32::NAN;
                    let mut acc_v = 0.0f32;
                    let mut first = true;
                    for dy in 0..k {
                        for dx in 0..k {
                            let idx = base + (oy * stride + dy) * w + (ox * stride + dx);
                            if first {
                                acc_m = mu[idx];
                                acc_v = var[idx];
                                first = false;
                            } else {
                                let (m, v) = gaussian_max(acc_m, acc_v, mu[idx], var[idx]);
                                acc_m = m;
                                acc_v = v;
                            }
                        }
                    }
                    out_mu[obase + oy * ow + ox] = acc_m;
                    out_var[obase + oy * ow + ox] = acc_v;
                }
            }
        }
    }
}

/// Generic-reduction PFP max-pool over NCHW (mean, variance) tensors:
/// iterated *sequential* pairwise Gaussian max over a k x k window.
pub fn pfp_maxpool_generic(input: &ProbTensor, k: usize, stride: usize) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let s = input.mu.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = out_hw(h, w, k, stride);
    let mut out_mu = vec![0.0f32; n * c * oh * ow];
    let mut out_var = vec![0.0f32; n * c * oh * ow];
    pfp_maxpool_generic_into(
        input.mu.data(),
        input.aux.data(),
        n,
        c,
        h,
        w,
        k,
        stride,
        &mut out_mu,
        &mut out_var,
    );
    ProbTensor::new(
        Tensor::new(vec![n, c, oh, ow], out_mu).unwrap(),
        Tensor::new(vec![n, c, oh, ow], out_var).unwrap(),
        Rep::Var,
    )
}

/// Slice-level vectorized k=2/stride-2 PFP max-pool (see
/// [`pfp_maxpool2_vectorized`]); writes into caller-provided buffers.
/// Allocation-free when `threads <= 1` or the input has a single plane.
/// Bit-identical across thread counts (planes are independent).
#[allow(clippy::too_many_arguments)]
pub fn pfp_maxpool2_vectorized_into(
    pool: &ThreadPool,
    isa: Isa,
    mu: &[f32],
    var: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    threads: usize,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let (oh, ow) = (h / 2, w / 2);
    let planes = n * c;
    let b = simd::resolve(isa);
    debug_assert_eq!(mu.len(), planes * h * w);
    debug_assert_eq!(out_mu.len(), planes * oh * ow);
    if threads <= 1 || planes <= 1 {
        pool2_serial(b, mu, var, n, c, h, w, out_mu, out_var);
        return;
    }
    // split both output buffers into per-plane-range disjoint chunks
    let ranges = split_ranges(planes, threads);
    let plane_out = oh * ow;
    let mut mu_rest: &mut [f32] = out_mu;
    let mut var_rest: &mut [f32] = out_var;
    let mut chunks = Vec::new();
    for r in ranges {
        let take = (r.end - r.start) * plane_out;
        let (mh, mt) = mu_rest.split_at_mut(take);
        let (vh, vt) = var_rest.split_at_mut(take);
        chunks.push((r, mh, vh));
        mu_rest = mt;
        var_rest = vt;
    }
    pool.scope(|sc| {
        for (r, mu_chunk, var_chunk) in chunks {
            sc.spawn(move || {
                for (local, plane) in r.enumerate() {
                    pool2_plane(
                        b,
                        mu,
                        var,
                        plane * h * w,
                        h,
                        w,
                        mu_chunk,
                        var_chunk,
                        local * plane_out,
                    );
                }
            });
        }
    });
}

/// One tile of the vectorized k=2/stride-2 pool: NCHW planes `planes`
/// into chunk-relative output slices. Planes are independent, so any
/// plane partition is bit-identical to the serial pass (within one ISA).
/// Allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn pfp_maxpool2_planes_into(
    isa: Isa,
    mu: &[f32],
    var: &[f32],
    h: usize,
    w: usize,
    planes: std::ops::Range<usize>,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let plane_out = (h / 2) * (w / 2);
    let b = simd::resolve(isa);
    debug_assert_eq!(out_mu.len(), (planes.end - planes.start) * plane_out);
    for (local, plane) in planes.enumerate() {
        pool2_plane(b, mu, var, plane * h * w, h, w, out_mu, out_var, local * plane_out);
    }
}

/// Planned-tile vectorized k=2/stride-2 pool: the NCHW plane ranges were
/// pre-partitioned at plan time and are gang-dispatched onto the pool
/// with zero heap allocation ([`ThreadPool::run_tasks`]); bit-identical
/// to the serial pass at any tile count (planes are independent — only
/// the schedule changes, never the association order).
#[allow(clippy::too_many_arguments)]
pub fn pfp_maxpool2_tiled_into(
    pool: &ThreadPool,
    isa: Isa,
    mu: &[f32],
    var: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    tiles: &[std::ops::Range<usize>],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let planes = n * c;
    debug_assert_eq!(mu.len(), planes * h * w);
    if tiles.len() <= 1 {
        pfp_maxpool2_planes_into(isa, mu, var, h, w, 0..planes, out_mu, out_var);
        return;
    }
    let plane_out = (h / 2) * (w / 2);
    let mu_parts = DisjointMut::new(out_mu);
    let var_parts = DisjointMut::new(out_var);
    pool.run_tasks(tiles.len(), &|ti| {
        let r = tiles[ti].clone();
        let len = (r.end - r.start) * plane_out;
        // SAFETY: tiles are disjoint plane ranges; run_tasks blocks until
        // every tile completes.
        let (mc, vc) = unsafe {
            (
                mu_parts.slice(r.start * plane_out, len),
                var_parts.slice(r.start * plane_out, len),
            )
        };
        pfp_maxpool2_planes_into(isa, mu, var, h, w, r, mc, vc);
    });
}

/// Serial plane walk shared by both vectorized-pool entry points: both
/// source rows two elements at a time — contiguous, fixed-pattern loads
/// the compiler can keep in registers.
#[allow(clippy::too_many_arguments)]
fn pool2_serial(
    b: Backend,
    mu: &[f32],
    var: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    let (oh, ow) = (h / 2, w / 2);
    for plane in 0..n * c {
        pool2_plane(b, mu, var, plane * h * w, h, w, out_mu, out_var, plane * oh * ow);
    }
}

/// Vectorized fixed-k=2/stride-2 PFP max-pool: balanced tree
/// `gmax(gmax(a,b), gmax(c,d))` with row-contiguous inner loops.
/// Matches the Pallas kernel bit-for-bit in structure (and, with
/// `Isa::Scalar`, in arithmetic).
pub fn pfp_maxpool2_vectorized(input: &ProbTensor, isa: Isa) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let s = input.mu.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out_mu = vec![0.0f32; n * c * oh * ow];
    let mut out_var = vec![0.0f32; n * c * oh * ow];
    pool2_serial(
        simd::resolve(isa),
        input.mu.data(),
        input.aux.data(),
        n,
        c,
        h,
        w,
        &mut out_mu,
        &mut out_var,
    );
    ProbTensor::new(
        Tensor::new(vec![n, c, oh, ow], out_mu).unwrap(),
        Tensor::new(vec![n, c, oh, ow], out_var).unwrap(),
        Rep::Var,
    )
}

/// One NCHW plane of the vectorized k=2/stride-2 pool: reads `h*w` mean/
/// variance values at `base`, writes `oh*ow` outputs at `out_off`.
///
/// On a SIMD backend the three pairwise matches run 8 output pixels at a
/// time: the strided window operands are gathered into fixed stack
/// buffers (cheap — the erf/exp/div/sqrt inside `gaussian_max` dominate),
/// short rows pad the unused lanes. Same balanced-tree association order
/// as the scalar walk.
#[inline(always)]
fn pool2_plane(
    b: Backend,
    mu: &[f32],
    var: &[f32],
    base: usize,
    h: usize,
    w: usize,
    out_mu: &mut [f32],
    out_var: &mut [f32],
    out_off: usize,
) {
    let (oh, ow) = (h / 2, w / 2);
    if b == Backend::Scalar {
        for oy in 0..oh {
            let r0 = base + (2 * oy) * w;
            let r1 = base + (2 * oy + 1) * w;
            let orow = out_off + oy * ow;
            for ox in 0..ow {
                let i0 = r0 + 2 * ox;
                let i1 = r1 + 2 * ox;
                let (ma, va) = gaussian_max(mu[i0], var[i0], mu[i0 + 1], var[i0 + 1]);
                let (mb, vb) = gaussian_max(mu[i1], var[i1], mu[i1 + 1], var[i1 + 1]);
                let (m, v) = gaussian_max(ma, va, mb, vb);
                out_mu[orow + ox] = m;
                out_var[orow + ox] = v;
            }
        }
        return;
    }
    for oy in 0..oh {
        let r0 = base + (2 * oy) * w;
        let r1 = base + (2 * oy + 1) * w;
        let orow = out_off + oy * ow;
        let mut ox = 0;
        while ox < ow {
            let lanes = (ow - ox).min(8);
            // gather the four window corners; pad tails with (0, 1) so
            // the vector math stays finite on unused lanes
            let mut am = [0.0f32; 8];
            let mut av = [1.0f32; 8];
            let mut bm = [0.0f32; 8];
            let mut bv = [1.0f32; 8];
            let mut cm = [0.0f32; 8];
            let mut cv = [1.0f32; 8];
            let mut dm = [0.0f32; 8];
            let mut dv = [1.0f32; 8];
            for j in 0..lanes {
                let i0 = r0 + 2 * (ox + j);
                let i1 = r1 + 2 * (ox + j);
                am[j] = mu[i0];
                av[j] = var[i0];
                bm[j] = mu[i0 + 1];
                bv[j] = var[i0 + 1];
                cm[j] = mu[i1];
                cv[j] = var[i1];
                dm[j] = mu[i1 + 1];
                dv[j] = var[i1 + 1];
            }
            let mut m1 = [0.0f32; 8];
            let mut v1 = [0.0f32; 8];
            let mut m2 = [0.0f32; 8];
            let mut v2 = [0.0f32; 8];
            simd::gaussian_max2_into(b, &am, &av, &bm, &bv, &mut m1, &mut v1);
            simd::gaussian_max2_into(b, &cm, &cv, &dm, &dv, &mut m2, &mut v2);
            let mut mo = [0.0f32; 8];
            let mut vo = [0.0f32; 8];
            simd::gaussian_max2_into(b, &m1, &v1, &m2, &v2, &mut mo, &mut vo);
            out_mu[orow + ox..orow + ox + lanes].copy_from_slice(&mo[..lanes]);
            out_var[orow + ox..orow + ox + lanes].copy_from_slice(&vo[..lanes]);
            ox += lanes;
        }
    }
}

/// Pool-parallel vectorized k=2/stride-2 PFP max-pool: the `N*C` planes
/// are split across `threads` persistent-pool tasks. Bit-identical to
/// [`pfp_maxpool2_vectorized`] at the same ISA (planes are independent;
/// only the schedule changes, not the association order).
pub fn pfp_maxpool2_vectorized_in(
    pool: &ThreadPool,
    input: &ProbTensor,
    threads: usize,
    isa: Isa,
) -> ProbTensor {
    debug_assert_eq!(input.rep, Rep::Var);
    let s = input.mu.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out_mu = vec![0.0f32; n * c * oh * ow];
    let mut out_var = vec![0.0f32; n * c * oh * ow];
    pfp_maxpool2_vectorized_into(
        pool,
        isa,
        input.mu.data(),
        input.aux.data(),
        n,
        c,
        h,
        w,
        threads,
        &mut out_mu,
        &mut out_var,
    );
    ProbTensor::new(
        Tensor::new(vec![n, c, oh, ow], out_mu).unwrap(),
        Tensor::new(vec![n, c, oh, ow], out_var).unwrap(),
        Rep::Var,
    )
}

/// One NCHW plane of the deterministic k=2/stride-2 max-pool.
#[inline(always)]
fn det_pool2_plane(
    d: &[f32],
    base: usize,
    h: usize,
    w: usize,
    out: &mut [f32],
    out_off: usize,
) {
    let (oh, ow) = (h / 2, w / 2);
    for oy in 0..oh {
        let r0 = base + (2 * oy) * w;
        let r1 = base + (2 * oy + 1) * w;
        for ox in 0..ow {
            let a = d[r0 + 2 * ox].max(d[r0 + 2 * ox + 1]);
            let b = d[r1 + 2 * ox].max(d[r1 + 2 * ox + 1]);
            out[out_off + oy * ow + ox] = a.max(b);
        }
    }
}

/// One tile of the deterministic k=2/stride-2 max-pool: planes `planes`
/// into a chunk-relative output slice.
pub fn det_maxpool2_planes_into(
    d: &[f32],
    h: usize,
    w: usize,
    planes: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let plane_out = (h / 2) * (w / 2);
    debug_assert_eq!(out.len(), (planes.end - planes.start) * plane_out);
    for (local, plane) in planes.enumerate() {
        det_pool2_plane(d, plane * h * w, h, w, out, local * plane_out);
    }
}

/// Planned-tile deterministic max-pool: plane ranges gang-dispatched with
/// zero allocation; bit-identical to the serial pass.
#[allow(clippy::too_many_arguments)]
pub fn det_maxpool2_tiled_into(
    pool: &ThreadPool,
    d: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    tiles: &[std::ops::Range<usize>],
    out: &mut [f32],
) {
    if tiles.len() <= 1 {
        det_maxpool2_planes_into(d, h, w, 0..n * c, out);
        return;
    }
    let plane_out = (h / 2) * (w / 2);
    let parts = DisjointMut::new(out);
    pool.run_tasks(tiles.len(), &|ti| {
        let r = tiles[ti].clone();
        let len = (r.end - r.start) * plane_out;
        // SAFETY: disjoint plane ranges.
        let chunk = unsafe { parts.slice(r.start * plane_out, len) };
        det_maxpool2_planes_into(d, h, w, r, chunk);
    });
}

/// Slice-level deterministic max-pool (k=2, stride 2).
pub fn det_maxpool2_into(d: &[f32], n: usize, c: usize, h: usize, w: usize, out: &mut [f32]) {
    debug_assert_eq!(d.len(), n * c * h * w);
    det_maxpool2_planes_into(d, h, w, 0..n * c, out);
}

/// Deterministic max-pool (k=2, stride 2) for the baselines.
pub fn det_maxpool2(x: &Tensor) -> Tensor {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; n * c * oh * ow];
    det_maxpool2_into(x.data(), n, c, h, w, &mut out);
    Tensor::new(vec![n, c, oh, ow], out).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::SplitMix64;

    fn rand_prob(g: &mut Gen, n: usize, c: usize, h: usize, w: usize) -> ProbTensor {
        ProbTensor::new(
            Tensor::new(vec![n, c, h, w], g.normal_vec(n * c * h * w, 1.0)).unwrap(),
            Tensor::new(vec![n, c, h, w], g.var_vec(n * c * h * w, 0.5)).unwrap(),
            Rep::Var,
        )
    }

    #[test]
    fn gaussian_max_monte_carlo() {
        let mut rng = SplitMix64::new(5);
        let (mu1, v1, mu2, v2) = (0.3f32, 0.8f32, -0.2f32, 1.4f32);
        let n = 400_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let a = mu1 as f64 + (v1 as f64).sqrt() * rng.normal();
            let b = mu2 as f64 + (v2 as f64).sqrt() * rng.normal();
            let z = a.max(b);
            s += z;
            s2 += z * z;
        }
        let (m, v) = gaussian_max(mu1, v1, mu2, v2);
        let emp_m = s / n as f64;
        let emp_v = s2 / n as f64 - emp_m * emp_m;
        assert!((m as f64 - emp_m).abs() < 5e-3, "{m} vs {emp_m}");
        assert!((v as f64 - emp_v).abs() < 2e-2, "{v} vs {emp_v}");
    }

    #[test]
    fn gaussian_max_degenerate_cases() {
        // far-apart means: max == the larger input
        let (m, v) = gaussian_max(10.0, 0.5, -10.0, 0.5);
        assert!((m - 10.0).abs() < 1e-4);
        assert!((v - 0.5).abs() < 1e-3);
        // symmetric inputs: mean = theta*phi(0)
        let (m, _) = gaussian_max(0.0, 1.0, 0.0, 1.0);
        let want = (2.0f32).sqrt() * 0.3989423;
        assert!((m - want).abs() < 1e-4);
    }

    #[test]
    fn vectorized_equals_tree_generic_shape() {
        // the vectorized pool halves H and W
        let mut g = Gen::new(1);
        let p = rand_prob(&mut g, 2, 3, 8, 10);
        let out = pfp_maxpool2_vectorized(&p, Isa::Native);
        assert_eq!(out.shape(), &[2, 3, 4, 5]);
        assert!(out.aux.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn generic_close_to_vectorized_k2() {
        // different association order -> slightly different approximations
        check(10, |g| {
            let p = rand_prob(g, 1, 2, 6, 6);
            let a = pfp_maxpool_generic(&p, 2, 2);
            let b = pfp_maxpool2_vectorized(&p, Isa::Scalar);
            let dm: f32 = a
                .mu
                .data()
                .iter()
                .zip(b.mu.data())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f32::max);
            assert!(dm < 0.1, "max |mu| diff {dm}");
        });
    }

    #[test]
    fn simd_isa_close_to_scalar_isa() {
        // same balanced tree, different rendering: <= 1e-4 relative
        // (odd widths exercise the gathered padded-lane tail)
        check(8, |g| {
            let n = g.usize_in(1, 2);
            let c = g.usize_in(1, 3);
            let h = 2 * g.usize_in(1, 5);
            let w = 2 * g.usize_in(1, 7);
            let p = rand_prob(g, n, c, h, w);
            let a = pfp_maxpool2_vectorized(&p, Isa::Scalar);
            let b = pfp_maxpool2_vectorized(&p, Isa::Native);
            assert!(b.mu.allclose(&a.mu, 1e-4, 1e-5), "mu [{n},{c},{h},{w}]");
            assert!(b.aux.allclose(&a.aux, 1e-3, 1e-4), "var [{n},{c},{h},{w}]");
        });
    }

    #[test]
    fn deterministic_limit_equals_det_maxpool() {
        let mut g = Gen::new(3);
        let x = Tensor::new(vec![1, 2, 6, 6], g.normal_vec(72, 1.0)).unwrap();
        let p = ProbTensor::new(x.clone(), Tensor::full(vec![1, 2, 6, 6], 1e-10), Rep::Var);
        for isa in [Isa::Scalar, Isa::Native] {
            let pooled = pfp_maxpool2_vectorized(&p, isa);
            let want = det_maxpool2(&x);
            assert!(pooled.mu.allclose(&want, 1e-3, 1e-3), "{isa:?}");
        }
    }

    #[test]
    fn pooled_mean_dominates_inputs_mean() {
        // E[max(X,Y)] >= max(E[X], E[Y])
        check(20, |g| {
            let mu1 = g.normal(2.0);
            let mu2 = g.normal(2.0);
            let v1 = g.normal(1.0).abs() + 1e-4;
            let v2 = g.normal(1.0).abs() + 1e-4;
            let (m, _) = gaussian_max(mu1, v1, mu2, v2);
            assert!(m >= mu1.max(mu2) - 1e-4);
        });
    }

    #[test]
    fn pool_parallel_matches_serial() {
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let mut g = Gen::new(11);
        let p = rand_prob(&mut g, 3, 4, 8, 8);
        for isa in [Isa::Scalar, Isa::Native] {
            let a = pfp_maxpool2_vectorized(&p, isa);
            let b = pfp_maxpool2_vectorized_in(&pool, &p, 3, isa);
            // planes are independent: parallel split must be bit-identical
            assert_eq!(a.mu.data(), b.mu.data(), "{isa:?}");
            assert_eq!(a.aux.data(), b.aux.data(), "{isa:?}");
        }
    }

    #[test]
    fn tiled_pool_bit_identical_to_serial() {
        let pool = crate::util::threadpool::ThreadPool::new(3);
        let mut g = Gen::new(13);
        let (n, c, h, w) = (3usize, 4, 8, 8);
        let p = rand_prob(&mut g, n, c, h, w);
        for isa in [Isa::Scalar, Isa::Native] {
            let want = pfp_maxpool2_vectorized(&p, isa);
            for tasks in [2usize, 3, 5, 12] {
                let tiles = split_ranges(n * c, tasks);
                let mut mu = vec![0.0f32; n * c * (h / 2) * (w / 2)];
                let mut var = vec![0.0f32; n * c * (h / 2) * (w / 2)];
                pfp_maxpool2_tiled_into(
                    &pool,
                    isa,
                    p.mu.data(),
                    p.aux.data(),
                    n,
                    c,
                    h,
                    w,
                    &tiles,
                    &mut mu,
                    &mut var,
                );
                assert_eq!(mu.as_slice(), want.mu.data(), "{isa:?} tasks={tasks}");
                assert_eq!(var.as_slice(), want.aux.data(), "{isa:?} tasks={tasks}");
            }
        }
        // det variant too
        let x = Tensor::new(vec![n, c, h, w], g.normal_vec(n * c * h * w, 1.0)).unwrap();
        let want_det = det_maxpool2(&x);
        let tiles = split_ranges(n * c, 5);
        let mut out = vec![0.0f32; n * c * (h / 2) * (w / 2)];
        det_maxpool2_tiled_into(&pool, x.data(), n, c, h, w, &tiles, &mut out);
        assert_eq!(out.as_slice(), want_det.data());
    }

    #[test]
    fn generic_supports_k3_stride1() {
        let mut g = Gen::new(9);
        let p = rand_prob(&mut g, 1, 1, 5, 5);
        let out = pfp_maxpool_generic(&p, 3, 1);
        assert_eq!(out.shape(), &[1, 1, 3, 3]);
    }
}
