//! Explicit SIMD microkernels with one-time runtime ISA dispatch — the
//! paper's "code generation for CPU targets" lever (TVM emits NEON on the
//! Jetson; we emit AVX2+FMA / NEON through `std::arch` intrinsics), layered
//! *beneath* the scheduled operator library so every schedule knob keeps
//! working on top of it.
//!
//! Three backends behind one slice-level API:
//!
//! * [`Backend::Scalar`] — always compiled, always tested: plain loops over
//!   the exact same scalar helpers the pre-SIMD operators used
//!   ([`erf`](super::erf::erf), [`relu_moments`](super::relu::relu_moments),
//!   [`gaussian_max`](super::maxpool::gaussian_max)), so forcing scalar
//!   reproduces the historical outputs bit for bit.
//! * [`Backend::Avx2`] — `x86_64`, 8 f32 lanes, selected at runtime when
//!   `avx2` **and** `fma` are present.
//! * [`Backend::Neon`] — `aarch64`, 4 f32 lanes (NEON is baseline on
//!   aarch64, so it is selected unconditionally there).
//!
//! Detection runs **once** per process ([`detect`]) and is cached in a
//! `OnceLock`, so resolving a schedule's [`Isa`] knob on the hot path is a
//! single atomic load — no allocation, preserving the compiled plan's
//! zero-steady-state-allocation guarantee. Setting `PFP_FORCE_SCALAR=1`
//! makes detection report [`Backend::Scalar`] regardless of hardware (the
//! CI dispatch-path matrix runs the whole suite once per branch).
//!
//! ## Accuracy contract (policed by the differential test suite)
//!
//! * Within one backend the kernels are deterministic: the same inputs
//!   produce bit-identical outputs at every plan tile count (partitioning
//!   never crosses a reduction or changes per-element math).
//! * Across backends outputs may differ — FMA contraction reassociates the
//!   dense reductions, and the vector `exp` is a polynomial
//!   (Cephes-style, ~7e-8 max relative error, validated in unit tests)
//!   rather than libm — but stay within **1e-4 relative** end to end
//!   (`tests/integration_simd_parity.rs`) and within ~1e-6 absolute of a
//!   high-precision `erf`/`norm_cdf`/`norm_pdf` reference
//!   (`ops/erf.rs` table tests).

use std::sync::OnceLock;

use super::erf::{ERF_A1, ERF_A2, ERF_A3, ERF_A4, ERF_A5, ERF_P, FRAC_1_SQRT_2, INV_SQRT_2PI};
use crate::util::half::{self, Precision};

/// Variance floor shared with the scalar moment-matching ops.
const EPS: f32 = 1e-12;

// ---------------------------------------------------------------------------
// ISA selection
// ---------------------------------------------------------------------------

/// Schedule-level ISA knob: what a [`Schedule`](super::Schedule) asks for.
/// `Native` resolves to the best backend the host supports at runtime
/// ([`detect`]); `Scalar` pins the portable fallback. The tuner explores
/// this dimension like any other knob and records it with the schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernels (the pre-SIMD code paths, bit for bit).
    Scalar,
    /// Runtime-detected SIMD backend (AVX2+FMA / NEON), falling back to
    /// scalar on hosts without one or under `PFP_FORCE_SCALAR=1`.
    Native,
}

impl Isa {
    /// CLI / record spelling: `"scalar"` or `"native"`.
    pub fn as_str(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Native => "native",
        }
    }

    /// Parse the CLI / record spelling (case-sensitive, lowercase).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "native" => Some(Isa::Native),
            _ => None,
        }
    }
}

/// A concrete instruction-set backend. All variants exist on every
/// architecture (so records and logs are portable); only the ones the
/// build target supports are ever *returned* by [`detect`] or executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    /// x86_64 AVX2 + FMA, 8 f32 lanes.
    Avx2,
    /// aarch64 NEON, 4 f32 lanes.
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2+fma",
            Backend::Neon => "neon",
        }
    }
}

static DETECTED: OnceLock<Backend> = OnceLock::new();

/// The best backend this host supports, detected once per process and
/// cached (later calls are one atomic load — no allocation, hot-path
/// safe). `PFP_FORCE_SCALAR=1` forces [`Backend::Scalar`], which is how
/// CI exercises the fallback dispatch path on SIMD-capable runners.
pub fn detect() -> Backend {
    *DETECTED.get_or_init(|| {
        if std::env::var("PFP_FORCE_SCALAR").as_deref() == Ok("1") {
            return Backend::Scalar;
        }
        native_backend()
    })
}

#[allow(unreachable_code)]
fn native_backend() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
            return Backend::Avx2;
        }
        return Backend::Scalar;
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is architecturally baseline on aarch64.
        return Backend::Neon;
    }
    Backend::Scalar
}

/// Resolve a schedule's [`Isa`] knob to the backend that will execute it.
#[inline]
pub fn resolve(isa: Isa) -> Backend {
    match isa {
        Isa::Scalar => Backend::Scalar,
        Isa::Native => detect(),
    }
}

static F16C: OnceLock<bool> = OnceLock::new();

/// Whether the x86 `F16C` conversion extension is available. F16C is a
/// separate CPUID bit from AVX2+FMA, so the f16 widen/narrow paths gate
/// on it independently of [`detect`]; without it the AVX2 kernels widen
/// f16 through the scalar reference (bitwise the same values — widening
/// is exact — just slower). Detected once and cached like [`detect`].
/// `PFP_FORCE_SCALAR=1` or `PFP_FORCE_NO_F16C=1` force the fallback,
/// which is how CI asserts the no-F16C dispatch path on capable hosts.
pub fn f16c_available() -> bool {
    *F16C.get_or_init(detect_f16c)
}

#[allow(unreachable_code)]
fn detect_f16c() -> bool {
    if std::env::var("PFP_FORCE_SCALAR").as_deref() == Ok("1")
        || std::env::var("PFP_FORCE_NO_F16C").as_deref() == Ok("1")
    {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        return std::is_x86_feature_detected!("f16c");
    }
    false
}

// ---------------------------------------------------------------------------
// shared polynomial-exp constants (Cephes expf: 2^k * P(r) with Cody-Waite
// range reduction; max relative error ~7e-8, validated in the unit tests)
// ---------------------------------------------------------------------------

const EXP_HI: f32 = 88.376_26;
const EXP_LO: f32 = -87.336_54;
const LOG2EF: f32 = 1.442_695;
const EXP_C1: f32 = 0.693_359_4;
const EXP_C2: f32 = -2.121_944_4e-4;
const EXP_P0: f32 = 1.987_569_2e-4;
const EXP_P1: f32 = 1.398_199_9e-3;
const EXP_P2: f32 = 8.333_452e-3;
const EXP_P3: f32 = 4.166_579_6e-2;
const EXP_P4: f32 = 1.666_666_5e-1;
const EXP_P5: f32 = 5.000_000_3e-1;

/// Scalar reference implementation of the vector `exp` polynomial (the
/// exact algorithm the AVX2/NEON lanes run, minus FMA contraction). Kept
/// public so the accuracy tests can pin the approximation itself, not
/// just one backend's rendering of it.
pub fn exp_poly(x: f32) -> f32 {
    let x = x.clamp(EXP_LO, EXP_HI);
    let kf = (x * LOG2EF).round_ties_even();
    let r = x - kf * EXP_C1;
    let r = r - kf * EXP_C2;
    let mut y = EXP_P0;
    y = y * r + EXP_P1;
    y = y * r + EXP_P2;
    y = y * r + EXP_P3;
    y = y * r + EXP_P4;
    y = y * r + EXP_P5;
    let y = y * (r * r) + r + 1.0;
    let scale = f32::from_bits((((kf as i32) + 127) << 23) as u32);
    y * scale
}

// ---------------------------------------------------------------------------
// slice-level vector math (dispatched once per call)
// ---------------------------------------------------------------------------

/// erf over a slice. Scalar backend = [`erf`](super::erf::erf) per element.
pub fn erf_into(b: Backend, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::erf_into(x, out) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::erf_into(x, out) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = super::erf::erf(v);
            }
        }
    }
}

/// Standard normal CDF over a slice.
pub fn norm_cdf_into(b: Backend, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::norm_cdf_into(x, out) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::norm_cdf_into(x, out) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = super::erf::norm_cdf(v);
            }
        }
    }
}

/// Standard normal PDF over a slice.
pub fn norm_pdf_into(b: Backend, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::norm_pdf_into(x, out) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::norm_pdf_into(x, out) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = super::erf::norm_pdf(v);
            }
        }
    }
}

/// Moment-matched ReLU over slices: (mu, var) -> (mu', E\[x'^2\]), the
/// vectorized body of [`relu_moments`](super::relu::relu_moments).
pub fn relu_moments_into(
    b: Backend,
    mu: &[f32],
    var: &[f32],
    out_mu: &mut [f32],
    out_e2: &mut [f32],
) {
    debug_assert_eq!(mu.len(), var.len());
    debug_assert_eq!(mu.len(), out_mu.len());
    debug_assert_eq!(mu.len(), out_e2.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::relu_moments_into(mu, var, out_mu, out_e2) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::relu_moments_into(mu, var, out_mu, out_e2) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            for i in 0..mu.len() {
                let (m, e2) = super::relu::relu_moments(mu[i], var[i]);
                out_mu[i] = m;
                out_e2[i] = e2;
            }
        }
    }
}

/// Elementwise moment-matched Gaussian max over slices — the vectorized
/// body of [`gaussian_max`](super::maxpool::gaussian_max), used by the
/// k=2 max-pool tree with gathered lane buffers.
#[allow(clippy::too_many_arguments)]
pub fn gaussian_max2_into(
    b: Backend,
    mu1: &[f32],
    var1: &[f32],
    mu2: &[f32],
    var2: &[f32],
    out_mu: &mut [f32],
    out_var: &mut [f32],
) {
    debug_assert_eq!(mu1.len(), var1.len());
    debug_assert_eq!(mu1.len(), mu2.len());
    debug_assert_eq!(mu1.len(), var2.len());
    debug_assert_eq!(mu1.len(), out_mu.len());
    debug_assert_eq!(mu1.len(), out_var.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
            avx2::gaussian_max2_into(mu1, var1, mu2, var2, out_mu, out_var)
        },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
            neon::gaussian_max2_into(mu1, var1, mu2, var2, out_mu, out_var)
        },
        _ => {
            for i in 0..mu1.len() {
                let (m, v) = super::maxpool::gaussian_max(mu1[i], var1[i], mu2[i], var2[i]);
                out_mu[i] = m;
                out_var[i] = v;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// dense-reduction microkernels (the Eq. 12/13 mu+var inner loops)
// ---------------------------------------------------------------------------

/// Eq. 12 joint dot product over one (row, row) pair:
/// returns `(Σ mu_x·mu_w, Σ (E[x²]E[w²] − (mu_x·mu_w)²))`. Two
/// accumulators per lane, exactly like the scalar [`JointEq12`]
/// formulation: the variance lanes accumulate the **per-element
/// difference** (`fnmadd(t, t, xa·wa)`), never two independent large sums
/// whose subtraction would magnify cancellation when the variance is a
/// tiny residual of the raw moments (confident posteriors).
pub fn dot_joint_eq12(b: Backend, xm: &[f32], xa: &[f32], wm: &[f32], wa: &[f32]) -> (f32, f32) {
    debug_assert_eq!(xm.len(), wm.len());
    debug_assert_eq!(xm.len(), xa.len());
    debug_assert_eq!(xm.len(), wa.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_joint_eq12(xm, xa, wm, wa) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_joint_eq12(xm, xa, wm, wa) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            let (mut mu, mut var) = (0.0f32, 0.0f32);
            for i in 0..xm.len() {
                let t = xm[i] * wm[i];
                mu += t;
                var += xa[i] * wa[i] - t * t;
            }
            (mu, var)
        }
    }
}

/// Eq. 13 first-layer dot product (deterministic input):
/// returns `(Σ x·mu_w, Σ x²·var_w)`.
pub fn dot_first_layer(b: Backend, xm: &[f32], wm: &[f32], wa: &[f32]) -> (f32, f32) {
    debug_assert_eq!(xm.len(), wm.len());
    debug_assert_eq!(xm.len(), wa.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_first_layer(xm, wm, wa) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_first_layer(xm, wm, wa) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            let (mut mu, mut var) = (0.0f32, 0.0f32);
            for i in 0..xm.len() {
                mu += xm[i] * wm[i];
                var += xm[i] * xm[i] * wa[i];
            }
            (mu, var)
        }
    }
}

/// Mean-only dot product (det mode / separate-operator baseline).
pub fn dot_mean(b: Backend, xm: &[f32], wm: &[f32]) -> f32 {
    debug_assert_eq!(xm.len(), wm.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_mean(xm, wm) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_mean(xm, wm) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            let mut mu = 0.0f32;
            for i in 0..xm.len() {
                mu += xm[i] * wm[i];
            }
            mu
        }
    }
}

// ---------------------------------------------------------------------------
// packed-storage conversions + packed-operand dot kernels (mixed precision)
// ---------------------------------------------------------------------------

/// A borrowed moment operand: plain f32, or reduced-precision bits packed
/// as `u16`. Each operand carries its **own** precision, so the mean and
/// variance paths of one layer mix freely (the ROADMAP's open question is
/// how little precision the variance path tolerates given the Eq. 12/13
/// cancellation — the certification harness sweeps the combinations).
///
/// Widening is exact, so a packed kernel fed `U16` operands is **bitwise
/// identical** to the corresponding f32 kernel fed pre-widened copies of
/// the same data, per backend — the invariant the differential harness
/// pins.
#[derive(Clone, Copy, Debug)]
pub enum PackedSlice<'a> {
    F32(&'a [f32]),
    /// Packed f16/bf16 bit patterns. `Precision::F32` is invalid here —
    /// f32 data always uses the `F32` variant.
    U16(Precision, &'a [u16]),
}

impl<'a> PackedSlice<'a> {
    pub fn len(&self) -> usize {
        match self {
            PackedSlice::F32(s) => s.len(),
            PackedSlice::U16(_, s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Widen one element to f32 (exact: widening never rounds).
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self {
            PackedSlice::F32(s) => s[i],
            PackedSlice::U16(p, s) => half::widen(*p, s[i]),
        }
    }

    /// Reborrow a sub-range (element indexing is layout-independent).
    #[inline]
    pub fn slice(&self, r: std::ops::Range<usize>) -> PackedSlice<'a> {
        match self {
            PackedSlice::F32(s) => PackedSlice::F32(&s[r]),
            PackedSlice::U16(p, s) => PackedSlice::U16(*p, &s[r]),
        }
    }
}

/// Widen a packed f16/bf16 slice to f32. Vectorized on AVX2 (`F16C`
/// hardware conversion when present, integer shifts for bf16) and NEON
/// (bf16); everything else goes through the bit-exact scalar reference in
/// [`util::half`](crate::util::half). No allocation — hot-path safe.
pub fn widen_into(b: Backend, prec: Precision, src: &[u16], out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    debug_assert!(prec != Precision::F32, "f32 has no packed representation");
    match (b, prec) {
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, Precision::F16) if f16c_available() => unsafe {
            // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma
            // verified at runtime) and the guard verified `f16c`; the
            // kernel handles any slice length with a scalar tail.
            avx2::widen_f16_into(src, out)
        },
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, Precision::Bf16) => unsafe {
            // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma
            // verified at runtime); integer ops only, any length is safe.
            avx2::widen_bf16_into(src, out)
        },
        #[cfg(target_arch = "aarch64")]
        (Backend::Neon, Precision::Bf16) => unsafe {
            // SAFETY: `b == Neon` only comes from [`detect`] (neon is
            // baseline on aarch64); integer ops only, any length is safe.
            neon::widen_bf16_into(src, out)
        },
        // Scalar backend, f16 without F16C, and f16 on NEON (stable
        // `std::arch` has no aarch64 fp16 vector conversions yet) all
        // take the scalar reference — bitwise identical, widening is
        // exact.
        _ => {
            for (o, &h) in out.iter_mut().zip(src) {
                *o = half::widen(prec, h);
            }
        }
    }
}

/// Narrow an f32 slice to packed f16/bf16 bits with round-to-nearest-even,
/// bitwise identical to the scalar reference on every backend (the f16
/// hardware path is `vcvtps2ph` with RN rounding — the mode the scalar
/// conversion replicates). No allocation — hot-path safe.
pub fn narrow_into(b: Backend, prec: Precision, src: &[f32], out: &mut [u16]) {
    debug_assert_eq!(src.len(), out.len());
    debug_assert!(prec != Precision::F32, "f32 has no packed representation");
    match (b, prec) {
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, Precision::F16) if f16c_available() => unsafe {
            // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma
            // verified at runtime) and the guard verified `f16c`; the
            // kernel handles any slice length with a scalar tail.
            avx2::narrow_f16_into(src, out)
        },
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, Precision::Bf16) => unsafe {
            // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma
            // verified at runtime); integer ops only, any length is safe.
            avx2::narrow_bf16_into(src, out)
        },
        #[cfg(target_arch = "aarch64")]
        (Backend::Neon, Precision::Bf16) => unsafe {
            // SAFETY: `b == Neon` only comes from [`detect`] (neon is
            // baseline on aarch64); integer ops only, any length is safe.
            neon::narrow_bf16_into(src, out)
        },
        _ => {
            for (o, &x) in out.iter_mut().zip(src) {
                *o = half::narrow(prec, x);
            }
        }
    }
}

/// [`dot_joint_eq12`] with packed weight operands: widen tiles to f32
/// registers, accumulate in f32, identical loop/lane/h-sum structure —
/// bitwise the widen-then-f32 kernel, per backend.
pub fn dot_joint_eq12_packed(
    b: Backend,
    xm: &[f32],
    xa: &[f32],
    wm: PackedSlice<'_>,
    wa: PackedSlice<'_>,
) -> (f32, f32) {
    debug_assert_eq!(xm.len(), wm.len());
    debug_assert_eq!(xm.len(), xa.len());
    debug_assert_eq!(xm.len(), wa.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_joint_eq12_packed(xm, xa, wm, wa, f16c_available()) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_joint_eq12_packed(xm, xa, wm, wa) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            let (mut mu, mut var) = (0.0f32, 0.0f32);
            for i in 0..xm.len() {
                let t = xm[i] * wm.get(i);
                mu += t;
                var += xa[i] * wa.get(i) - t * t;
            }
            (mu, var)
        }
    }
}

/// [`dot_first_layer`] with packed weight operands (see
/// [`dot_joint_eq12_packed`] for the bit-parity contract).
pub fn dot_first_layer_packed(
    b: Backend,
    xm: &[f32],
    wm: PackedSlice<'_>,
    wa: PackedSlice<'_>,
) -> (f32, f32) {
    debug_assert_eq!(xm.len(), wm.len());
    debug_assert_eq!(xm.len(), wa.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_first_layer_packed(xm, wm, wa, f16c_available()) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_first_layer_packed(xm, wm, wa) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            let (mut mu, mut var) = (0.0f32, 0.0f32);
            for i in 0..xm.len() {
                mu += xm[i] * wm.get(i);
                var += xm[i] * xm[i] * wa.get(i);
            }
            (mu, var)
        }
    }
}

/// [`dot_mean`] with a packed weight operand (see
/// [`dot_joint_eq12_packed`] for the bit-parity contract).
pub fn dot_mean_packed(b: Backend, xm: &[f32], wm: PackedSlice<'_>) -> f32 {
    debug_assert_eq!(xm.len(), wm.len());
    match b {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_mean_packed(xm, wm, f16c_available()) }, // SAFETY: `b == Avx2` only comes from [`detect`] (avx2+fma was verified at runtime); the kernels accept any slice length.
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_mean_packed(xm, wm) }, // SAFETY: `b == Neon` only comes from [`detect`] (neon is baseline on aarch64); the kernels accept any slice length.
        _ => {
            let mut mu = 0.0f32;
            for i in 0..xm.len() {
                mu += xm[i] * wm.get(i);
            }
            mu
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA backend (x86_64, 8 f32 lanes)
// ---------------------------------------------------------------------------

/// SAFETY: every function in this module is `#[target_feature(enable =
/// "avx2,fma")]` and is only reached through [`detect`]-gated dispatch,
/// which verified both features at runtime. Loads/stores are unaligned
/// (`loadu`/`storeu`); tails go through padded stack buffers so slices of
/// any length are safe.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{
        EPS, ERF_A1, ERF_A2, ERF_A3, ERF_A4, ERF_A5, ERF_P, EXP_C1, EXP_C2, EXP_HI, EXP_LO,
        EXP_P0, EXP_P1, EXP_P2, EXP_P3, EXP_P4, EXP_P5, FRAC_1_SQRT_2, INV_SQRT_2PI, LOG2EF,
    };
    use super::PackedSlice;
    use crate::util::half::{self, Precision};

    /// exp(x) as 2^k * P(r): Cody-Waite reduction, degree-6 polynomial,
    /// exponent built by integer bit manipulation.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn exp_v(x: __m256) -> __m256 {
        let x = _mm256_min_ps(x, _mm256_set1_ps(EXP_HI));
        let x = _mm256_max_ps(x, _mm256_set1_ps(EXP_LO));
        let k_i = _mm256_cvtps_epi32(_mm256_mul_ps(x, _mm256_set1_ps(LOG2EF)));
        let kf = _mm256_cvtepi32_ps(k_i);
        let r = _mm256_fnmadd_ps(kf, _mm256_set1_ps(EXP_C1), x);
        let r = _mm256_fnmadd_ps(kf, _mm256_set1_ps(EXP_C2), r);
        let mut y = _mm256_set1_ps(EXP_P0);
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(EXP_P1));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(EXP_P2));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(EXP_P3));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(EXP_P4));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(EXP_P5));
        let y = _mm256_add_ps(
            _mm256_fmadd_ps(y, _mm256_mul_ps(r, r), r),
            _mm256_set1_ps(1.0),
        );
        let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
            k_i,
            _mm256_set1_epi32(127),
        )));
        _mm256_mul_ps(y, scale)
    }

    /// A&S 7.1.26 erf, sign handled by bit masking.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn erf_v(x: __m256) -> __m256 {
        let sign_mask = _mm256_set1_ps(-0.0);
        let sign = _mm256_and_ps(x, sign_mask);
        let xa = _mm256_andnot_ps(sign_mask, x);
        let one = _mm256_set1_ps(1.0);
        let t = _mm256_div_ps(one, _mm256_fmadd_ps(_mm256_set1_ps(ERF_P), xa, one));
        let mut poly = _mm256_set1_ps(ERF_A5);
        poly = _mm256_fmadd_ps(poly, t, _mm256_set1_ps(ERF_A4));
        poly = _mm256_fmadd_ps(poly, t, _mm256_set1_ps(ERF_A3));
        poly = _mm256_fmadd_ps(poly, t, _mm256_set1_ps(ERF_A2));
        poly = _mm256_fmadd_ps(poly, t, _mm256_set1_ps(ERF_A1));
        poly = _mm256_mul_ps(poly, t);
        let e = exp_v(_mm256_sub_ps(_mm256_setzero_ps(), _mm256_mul_ps(xa, xa)));
        let r = _mm256_fnmadd_ps(poly, e, one);
        _mm256_or_ps(r, sign)
    }

    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn norm_cdf_v(x: __m256) -> __m256 {
        let z = _mm256_mul_ps(x, _mm256_set1_ps(FRAC_1_SQRT_2));
        _mm256_mul_ps(
            _mm256_set1_ps(0.5),
            _mm256_add_ps(_mm256_set1_ps(1.0), erf_v(z)),
        )
    }

    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn norm_pdf_v(x: __m256) -> __m256 {
        let arg = _mm256_mul_ps(_mm256_set1_ps(-0.5), _mm256_mul_ps(x, x));
        _mm256_mul_ps(_mm256_set1_ps(INV_SQRT_2PI), exp_v(arg))
    }

    /// Run the named lane function over the slice 8 lanes at a time; the
    /// tail is padded into a stack buffer so every element goes through
    /// the same vector code (a direct call, not a closure — closures
    /// would leave the `unsafe fn` / target-feature context).
    macro_rules! map_v {
        ($x:expr, $out:expr, $op:ident) => {{
            let x: &[f32] = $x;
            let out: &mut [f32] = $out;
            let n = x.len();
            let mut i = 0;
            while i + 8 <= n {
                let v = _mm256_loadu_ps(x.as_ptr().add(i));
                _mm256_storeu_ps(out.as_mut_ptr().add(i), $op(v));
                i += 8;
            }
            if i < n {
                let mut buf = [0.0f32; 8];
                buf[..n - i].copy_from_slice(&x[i..]);
                let r = $op(_mm256_loadu_ps(buf.as_ptr()));
                _mm256_storeu_ps(buf.as_mut_ptr(), r);
                out[i..].copy_from_slice(&buf[..n - i]);
            }
        }};
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn erf_into(x: &[f32], out: &mut [f32]) {
        map_v!(x, out, erf_v);
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn norm_cdf_into(x: &[f32], out: &mut [f32]) {
        map_v!(x, out, norm_cdf_v);
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn norm_pdf_into(x: &[f32], out: &mut [f32]) {
        map_v!(x, out, norm_pdf_v);
    }

    /// (mu, var) -> (mu', E[x'^2]) — the Eqs. 8/9 body on 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn relu_v(mu: __m256, var: __m256) -> (__m256, __m256) {
        let var = _mm256_max_ps(var, _mm256_set1_ps(EPS));
        let std = _mm256_sqrt_ps(var);
        let cdf = norm_cdf_v(_mm256_div_ps(mu, std));
        let mu2 = _mm256_mul_ps(mu, mu);
        let arg = _mm256_sub_ps(
            _mm256_setzero_ps(),
            _mm256_div_ps(mu2, _mm256_mul_ps(_mm256_set1_ps(2.0), var)),
        );
        let pdf = _mm256_mul_ps(_mm256_mul_ps(std, _mm256_set1_ps(INV_SQRT_2PI)), exp_v(arg));
        let m = _mm256_fmadd_ps(mu, cdf, pdf);
        let e2 = _mm256_fmadd_ps(_mm256_add_ps(var, mu2), cdf, _mm256_mul_ps(mu, pdf));
        (m, _mm256_max_ps(e2, _mm256_setzero_ps()))
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn relu_moments_into(
        mu: &[f32],
        var: &[f32],
        out_mu: &mut [f32],
        out_e2: &mut [f32],
    ) {
        let n = mu.len();
        let mut i = 0;
        while i + 8 <= n {
            let (m, e2) = relu_v(
                _mm256_loadu_ps(mu.as_ptr().add(i)),
                _mm256_loadu_ps(var.as_ptr().add(i)),
            );
            _mm256_storeu_ps(out_mu.as_mut_ptr().add(i), m);
            _mm256_storeu_ps(out_e2.as_mut_ptr().add(i), e2);
            i += 8;
        }
        if i < n {
            let mut mb = [0.0f32; 8];
            let mut vb = [1.0f32; 8]; // pad variance 1: sqrt/div stay finite
            mb[..n - i].copy_from_slice(&mu[i..]);
            vb[..n - i].copy_from_slice(&var[i..]);
            let (m, e2) = relu_v(_mm256_loadu_ps(mb.as_ptr()), _mm256_loadu_ps(vb.as_ptr()));
            _mm256_storeu_ps(mb.as_mut_ptr(), m);
            _mm256_storeu_ps(vb.as_mut_ptr(), e2);
            out_mu[i..].copy_from_slice(&mb[..n - i]);
            out_e2[i..].copy_from_slice(&vb[..n - i]);
        }
    }

    /// Moment-matched max of two Gaussians on 8 lanes (Roth 2021).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn gmax_v(
        mu1: __m256,
        var1: __m256,
        mu2: __m256,
        var2: __m256,
    ) -> (__m256, __m256) {
        let one = _mm256_set1_ps(1.0);
        let theta = _mm256_sqrt_ps(_mm256_max_ps(
            _mm256_add_ps(var1, var2),
            _mm256_set1_ps(EPS),
        ));
        let alpha = _mm256_div_ps(_mm256_sub_ps(mu1, mu2), theta);
        let cdf = norm_cdf_v(alpha);
        let q = _mm256_sub_ps(one, cdf);
        let pdf = norm_pdf_v(alpha);
        let tp = _mm256_mul_ps(theta, pdf);
        let m = _mm256_fmadd_ps(mu1, cdf, _mm256_fmadd_ps(mu2, q, tp));
        let s1 = _mm256_fmadd_ps(mu1, mu1, var1);
        let s2 = _mm256_fmadd_ps(mu2, mu2, var2);
        let e2 = _mm256_fmadd_ps(
            s1,
            cdf,
            _mm256_fmadd_ps(s2, q, _mm256_mul_ps(_mm256_add_ps(mu1, mu2), tp)),
        );
        let v = _mm256_max_ps(_mm256_fnmadd_ps(m, m, e2), _mm256_setzero_ps());
        (m, v)
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn gaussian_max2_into(
        mu1: &[f32],
        var1: &[f32],
        mu2: &[f32],
        var2: &[f32],
        out_mu: &mut [f32],
        out_var: &mut [f32],
    ) {
        let n = mu1.len();
        let mut i = 0;
        while i + 8 <= n {
            let (m, v) = gmax_v(
                _mm256_loadu_ps(mu1.as_ptr().add(i)),
                _mm256_loadu_ps(var1.as_ptr().add(i)),
                _mm256_loadu_ps(mu2.as_ptr().add(i)),
                _mm256_loadu_ps(var2.as_ptr().add(i)),
            );
            _mm256_storeu_ps(out_mu.as_mut_ptr().add(i), m);
            _mm256_storeu_ps(out_var.as_mut_ptr().add(i), v);
            i += 8;
        }
        if i < n {
            let mut m1 = [0.0f32; 8];
            let mut v1 = [1.0f32; 8];
            let mut m2 = [0.0f32; 8];
            let mut v2 = [1.0f32; 8];
            m1[..n - i].copy_from_slice(&mu1[i..]);
            v1[..n - i].copy_from_slice(&var1[i..]);
            m2[..n - i].copy_from_slice(&mu2[i..]);
            v2[..n - i].copy_from_slice(&var2[i..]);
            let (m, v) = gmax_v(
                _mm256_loadu_ps(m1.as_ptr()),
                _mm256_loadu_ps(v1.as_ptr()),
                _mm256_loadu_ps(m2.as_ptr()),
                _mm256_loadu_ps(v2.as_ptr()),
            );
            _mm256_storeu_ps(m1.as_mut_ptr(), m);
            _mm256_storeu_ps(v1.as_mut_ptr(), v);
            out_mu[i..].copy_from_slice(&m1[..n - i]);
            out_var[i..].copy_from_slice(&v1[..n - i]);
        }
    }

    /// Deterministic 8-lane horizontal sum (pairwise, fixed order).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2+fma, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn hsum(v: __m256) -> f32 {
        let mut buf = [0.0f32; 8];
        _mm256_storeu_ps(buf.as_mut_ptr(), v);
        ((buf[0] + buf[4]) + (buf[1] + buf[5])) + ((buf[2] + buf[6]) + (buf[3] + buf[7]))
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_joint_eq12(
        xm: &[f32],
        xa: &[f32],
        wm: &[f32],
        wa: &[f32],
    ) -> (f32, f32) {
        let k = xm.len();
        let mut mu = _mm256_setzero_ps();
        let mut var = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= k {
            let xmv = _mm256_loadu_ps(xm.as_ptr().add(i));
            let wmv = _mm256_loadu_ps(wm.as_ptr().add(i));
            let xav = _mm256_loadu_ps(xa.as_ptr().add(i));
            let wav = _mm256_loadu_ps(wa.as_ptr().add(i));
            let t = _mm256_mul_ps(xmv, wmv);
            mu = _mm256_add_ps(mu, t);
            // per-element difference, like the scalar kernel: the
            // variance lanes never hold the (much larger) raw-moment sum
            var = _mm256_add_ps(var, _mm256_fnmadd_ps(t, t, _mm256_mul_ps(xav, wav)));
            i += 8;
        }
        let mut mu_s = hsum(mu);
        let mut var_s = hsum(var);
        while i < k {
            let t = xm[i] * wm[i];
            mu_s += t;
            var_s += xa[i] * wa[i] - t * t;
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_first_layer(xm: &[f32], wm: &[f32], wa: &[f32]) -> (f32, f32) {
        let k = xm.len();
        let mut mu = _mm256_setzero_ps();
        let mut var = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= k {
            let xmv = _mm256_loadu_ps(xm.as_ptr().add(i));
            let wmv = _mm256_loadu_ps(wm.as_ptr().add(i));
            let wav = _mm256_loadu_ps(wa.as_ptr().add(i));
            mu = _mm256_fmadd_ps(xmv, wmv, mu);
            var = _mm256_fmadd_ps(_mm256_mul_ps(xmv, xmv), wav, var);
            i += 8;
        }
        let mut mu_s = hsum(mu);
        let mut var_s = hsum(var);
        while i < k {
            mu_s += xm[i] * wm[i];
            var_s += xm[i] * xm[i] * wa[i];
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_mean(xm: &[f32], wm: &[f32]) -> f32 {
        let k = xm.len();
        let mut mu = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= k {
            mu = _mm256_fmadd_ps(
                _mm256_loadu_ps(xm.as_ptr().add(i)),
                _mm256_loadu_ps(wm.as_ptr().add(i)),
                mu,
            );
            i += 8;
        }
        let mut mu_s = hsum(mu);
        while i < k {
            mu_s += xm[i] * wm[i];
            i += 1;
        }
        mu_s
    }

    // -- mixed-precision conversions + packed-operand dots ------------------

    /// Widen 8 packed f16 via the `F16C` hardware conversion (exact).
    #[inline]
    #[target_feature(enable = "avx2,fma,f16c")]
    // SAFETY: requires f16c on top of avx2+fma — every caller guards on
    // `f16c_available()` before taking this path; reads exactly 16 bytes
    // at `p`, which callers guarantee are in bounds.
    unsafe fn widen8_f16c(p: *const u16) -> __m256 {
        _mm256_cvtph_ps(_mm_loadu_si128(p as *const __m128i))
    }

    /// Widen 8 packed bf16 by zero-extend + 16-bit left shift (exact —
    /// bf16 is a truncated f32).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: integer ops only; requires avx2, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has;
    // reads exactly 16 bytes at `p`, in bounds per caller.
    unsafe fn widen8_bf16(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
    }

    /// Load 8 lanes of a packed operand as f32. The f16-without-F16C path
    /// widens through the scalar reference into a stack buffer — the same
    /// bits (widening is exact), just slower; this is the asserted CI
    /// fallback on hosts without F16C.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: requires avx2+fma (guaranteed by detect-gated callers);
    // all memory access is 8 in-bounds lanes at element offset `i`
    // (callers keep `i + 8 <= len`) or a padded stack buffer.
    unsafe fn load8(s: PackedSlice<'_>, i: usize, has_f16c: bool) -> __m256 {
        match s {
            PackedSlice::F32(v) => _mm256_loadu_ps(v.as_ptr().add(i)),
            PackedSlice::U16(Precision::F16, v) if has_f16c => {
                widen8_f16c(v.as_ptr().add(i))
            }
            PackedSlice::U16(Precision::Bf16, v) => widen8_bf16(v.as_ptr().add(i)),
            PackedSlice::U16(p, v) => {
                let mut buf = [0.0f32; 8];
                for (l, b) in buf.iter_mut().enumerate() {
                    *b = half::widen(p, *v.get_unchecked(i + l));
                }
                _mm256_loadu_ps(buf.as_ptr())
            }
        }
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    // SAFETY: callable only with avx2+fma+f16c available — guaranteed by
    // the `f16c_available()`-guarded dispatch above. Unaligned 8-lane
    // loads/stores plus a scalar tail keep every slice length in bounds.
    pub unsafe fn widen_f16_into(src: &[u16], out: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(out.as_mut_ptr().add(i), widen8_f16c(src.as_ptr().add(i)));
            i += 8;
        }
        while i < n {
            out[i] = half::f16_bits_to_f32(src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    // SAFETY: callable only with avx2+fma+f16c available — guaranteed by
    // the `f16c_available()`-guarded dispatch above. Unaligned 8-lane
    // loads/stores plus a scalar tail keep every slice length in bounds.
    pub unsafe fn narrow_f16_into(src: &[f32], out: &mut [u16]) {
        let n = src.len();
        let mut i = 0;
        while i + 8 <= n {
            // RN rounding control: the mode the scalar reference matches.
            let h = _mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(
                _mm256_loadu_ps(src.as_ptr().add(i)),
            );
            _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, h);
            i += 8;
        }
        while i < n {
            out[i] = half::f32_to_f16_bits(src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Unaligned 8-lane loads/stores plus a
    // scalar tail keep every slice length in bounds.
    pub unsafe fn widen_bf16_into(src: &[u16], out: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(out.as_mut_ptr().add(i), widen8_bf16(src.as_ptr().add(i)));
            i += 8;
        }
        while i < n {
            out[i] = half::bf16_bits_to_f32(src[i]);
            i += 1;
        }
    }

    /// Narrow 8 f32 lanes to bf16 bits with round-to-nearest-even, NaNs
    /// truncated with the quiet bit forced — bitwise the scalar reference.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    // SAFETY: register-only math; requires avx2, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn narrow8_bf16(v: __m256) -> __m128i {
        let bits = _mm256_castps_si256(v);
        let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), _mm256_set1_epi32(1));
        let bias = _mm256_add_epi32(_mm256_set1_epi32(0x7fff), lsb);
        let rounded = _mm256_srli_epi32::<16>(_mm256_add_epi32(bits, bias));
        // NaN lanes truncate + force the quiet bit (rounding a NaN could
        // carry the payload into the infinity encoding).
        let qnan = _mm256_or_si256(
            _mm256_srli_epi32::<16>(bits),
            _mm256_set1_epi32(0x0040),
        );
        let nan = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_UNORD_Q>(v, v));
        let r32 = _mm256_blendv_epi8(rounded, qnan, nan);
        // Every 32-bit lane now holds a u16 value (<= 0xffff, so the
        // signed-saturating pack is exact); pack the halves and restore
        // lane order across the 128-bit boundary.
        let packed = _mm256_packus_epi32(r32, r32);
        _mm256_castsi256_si128(_mm256_permute4x64_epi64::<0b00_00_10_00>(packed))
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Unaligned 8-lane loads/stores plus a
    // scalar tail keep every slice length in bounds.
    pub unsafe fn narrow_bf16_into(src: &[f32], out: &mut [u16]) {
        let n = src.len();
        let mut i = 0;
        while i + 8 <= n {
            let h = narrow8_bf16(_mm256_loadu_ps(src.as_ptr().add(i)));
            _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, h);
            i += 8;
        }
        while i < n {
            out[i] = half::f32_to_bf16_bits(src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_joint_eq12_packed(
        xm: &[f32],
        xa: &[f32],
        wm: PackedSlice<'_>,
        wa: PackedSlice<'_>,
        has_f16c: bool,
    ) -> (f32, f32) {
        let k = xm.len();
        let mut mu = _mm256_setzero_ps();
        let mut var = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= k {
            let xmv = _mm256_loadu_ps(xm.as_ptr().add(i));
            let wmv = load8(wm, i, has_f16c);
            let xav = _mm256_loadu_ps(xa.as_ptr().add(i));
            let wav = load8(wa, i, has_f16c);
            let t = _mm256_mul_ps(xmv, wmv);
            mu = _mm256_add_ps(mu, t);
            // identical accumulation structure to the f32 kernel — the
            // packed kernel IS the widen-then-f32 kernel, bitwise
            var = _mm256_add_ps(var, _mm256_fnmadd_ps(t, t, _mm256_mul_ps(xav, wav)));
            i += 8;
        }
        let mut mu_s = hsum(mu);
        let mut var_s = hsum(var);
        while i < k {
            let t = xm[i] * wm.get(i);
            mu_s += t;
            var_s += xa[i] * wa.get(i) - t * t;
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_first_layer_packed(
        xm: &[f32],
        wm: PackedSlice<'_>,
        wa: PackedSlice<'_>,
        has_f16c: bool,
    ) -> (f32, f32) {
        let k = xm.len();
        let mut mu = _mm256_setzero_ps();
        let mut var = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= k {
            let xmv = _mm256_loadu_ps(xm.as_ptr().add(i));
            let wmv = load8(wm, i, has_f16c);
            let wav = load8(wa, i, has_f16c);
            mu = _mm256_fmadd_ps(xmv, wmv, mu);
            var = _mm256_fmadd_ps(_mm256_mul_ps(xmv, xmv), wav, var);
            i += 8;
        }
        let mut mu_s = hsum(mu);
        let mut var_s = hsum(var);
        while i < k {
            mu_s += xm[i] * wm.get(i);
            var_s += xm[i] * xm[i] * wa.get(i);
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: callable only with avx2+fma available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_mean_packed(xm: &[f32], wm: PackedSlice<'_>, has_f16c: bool) -> f32 {
        let k = xm.len();
        let mut mu = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= k {
            mu = _mm256_fmadd_ps(
                _mm256_loadu_ps(xm.as_ptr().add(i)),
                load8(wm, i, has_f16c),
                mu,
            );
            i += 8;
        }
        let mut mu_s = hsum(mu);
        while i < k {
            mu_s += xm[i] * wm.get(i);
            i += 1;
        }
        mu_s
    }
}

// ---------------------------------------------------------------------------
// NEON backend (aarch64, 4 f32 lanes)
// ---------------------------------------------------------------------------

/// SAFETY: NEON is baseline on aarch64 and [`detect`] only returns
/// [`Backend::Neon`] there; tails are padded exactly like the AVX2 module.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::{
        EPS, ERF_A1, ERF_A2, ERF_A3, ERF_A4, ERF_A5, ERF_P, EXP_C1, EXP_C2, EXP_HI, EXP_LO,
        EXP_P0, EXP_P1, EXP_P2, EXP_P3, EXP_P4, EXP_P5, FRAC_1_SQRT_2, INV_SQRT_2PI, LOG2EF,
    };
    use super::PackedSlice;
    use crate::util::half::{self, Precision};

    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn exp_v(x: float32x4_t) -> float32x4_t {
        let x = vminq_f32(x, vdupq_n_f32(EXP_HI));
        let x = vmaxq_f32(x, vdupq_n_f32(EXP_LO));
        let k_i = vcvtnq_s32_f32(vmulq_f32(x, vdupq_n_f32(LOG2EF)));
        let kf = vcvtq_f32_s32(k_i);
        let r = vfmsq_f32(x, kf, vdupq_n_f32(EXP_C1));
        let r = vfmsq_f32(r, kf, vdupq_n_f32(EXP_C2));
        let mut y = vdupq_n_f32(EXP_P0);
        y = vfmaq_f32(vdupq_n_f32(EXP_P1), y, r);
        y = vfmaq_f32(vdupq_n_f32(EXP_P2), y, r);
        y = vfmaq_f32(vdupq_n_f32(EXP_P3), y, r);
        y = vfmaq_f32(vdupq_n_f32(EXP_P4), y, r);
        y = vfmaq_f32(vdupq_n_f32(EXP_P5), y, r);
        let y = vaddq_f32(vfmaq_f32(r, y, vmulq_f32(r, r)), vdupq_n_f32(1.0));
        let scale =
            vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(k_i, vdupq_n_s32(127))));
        vmulq_f32(y, scale)
    }

    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn erf_v(x: float32x4_t) -> float32x4_t {
        let xa = vabsq_f32(x);
        let one = vdupq_n_f32(1.0);
        let t = vdivq_f32(one, vfmaq_f32(one, vdupq_n_f32(ERF_P), xa));
        let mut poly = vdupq_n_f32(ERF_A5);
        poly = vfmaq_f32(vdupq_n_f32(ERF_A4), poly, t);
        poly = vfmaq_f32(vdupq_n_f32(ERF_A3), poly, t);
        poly = vfmaq_f32(vdupq_n_f32(ERF_A2), poly, t);
        poly = vfmaq_f32(vdupq_n_f32(ERF_A1), poly, t);
        poly = vmulq_f32(poly, t);
        let e = exp_v(vnegq_f32(vmulq_f32(xa, xa)));
        let r = vfmsq_f32(one, poly, e);
        // transplant the argument's sign bit onto the magnitude result
        vbslq_f32(vdupq_n_u32(0x8000_0000), x, r)
    }

    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn norm_cdf_v(x: float32x4_t) -> float32x4_t {
        let z = vmulq_f32(x, vdupq_n_f32(FRAC_1_SQRT_2));
        vmulq_f32(vdupq_n_f32(0.5), vaddq_f32(vdupq_n_f32(1.0), erf_v(z)))
    }

    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn norm_pdf_v(x: float32x4_t) -> float32x4_t {
        let arg = vmulq_f32(vdupq_n_f32(-0.5), vmulq_f32(x, x));
        vmulq_f32(vdupq_n_f32(INV_SQRT_2PI), exp_v(arg))
    }

    macro_rules! map_v {
        ($x:expr, $out:expr, $op:ident) => {{
            let x: &[f32] = $x;
            let out: &mut [f32] = $out;
            let n = x.len();
            let mut i = 0;
            while i + 4 <= n {
                let v = vld1q_f32(x.as_ptr().add(i));
                vst1q_f32(out.as_mut_ptr().add(i), $op(v));
                i += 4;
            }
            if i < n {
                let mut buf = [0.0f32; 4];
                buf[..n - i].copy_from_slice(&x[i..]);
                let r = $op(vld1q_f32(buf.as_ptr()));
                vst1q_f32(buf.as_mut_ptr(), r);
                out[i..].copy_from_slice(&buf[..n - i]);
            }
        }};
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn erf_into(x: &[f32], out: &mut [f32]) {
        map_v!(x, out, erf_v);
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn norm_cdf_into(x: &[f32], out: &mut [f32]) {
        map_v!(x, out, norm_cdf_v);
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn norm_pdf_into(x: &[f32], out: &mut [f32]) {
        map_v!(x, out, norm_pdf_v);
    }

    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn relu_v(mu: float32x4_t, var: float32x4_t) -> (float32x4_t, float32x4_t) {
        let var = vmaxq_f32(var, vdupq_n_f32(EPS));
        let std = vsqrtq_f32(var);
        let cdf = norm_cdf_v(vdivq_f32(mu, std));
        let mu2 = vmulq_f32(mu, mu);
        let arg = vnegq_f32(vdivq_f32(mu2, vmulq_f32(vdupq_n_f32(2.0), var)));
        let pdf = vmulq_f32(vmulq_f32(std, vdupq_n_f32(INV_SQRT_2PI)), exp_v(arg));
        let m = vfmaq_f32(pdf, mu, cdf);
        let e2 = vfmaq_f32(vmulq_f32(mu, pdf), vaddq_f32(var, mu2), cdf);
        (m, vmaxq_f32(e2, vdupq_n_f32(0.0)))
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn relu_moments_into(
        mu: &[f32],
        var: &[f32],
        out_mu: &mut [f32],
        out_e2: &mut [f32],
    ) {
        let n = mu.len();
        let mut i = 0;
        while i + 4 <= n {
            let (m, e2) = relu_v(vld1q_f32(mu.as_ptr().add(i)), vld1q_f32(var.as_ptr().add(i)));
            vst1q_f32(out_mu.as_mut_ptr().add(i), m);
            vst1q_f32(out_e2.as_mut_ptr().add(i), e2);
            i += 4;
        }
        if i < n {
            let mut mb = [0.0f32; 4];
            let mut vb = [1.0f32; 4];
            mb[..n - i].copy_from_slice(&mu[i..]);
            vb[..n - i].copy_from_slice(&var[i..]);
            let (m, e2) = relu_v(vld1q_f32(mb.as_ptr()), vld1q_f32(vb.as_ptr()));
            vst1q_f32(mb.as_mut_ptr(), m);
            vst1q_f32(vb.as_mut_ptr(), e2);
            out_mu[i..].copy_from_slice(&mb[..n - i]);
            out_e2[i..].copy_from_slice(&vb[..n - i]);
        }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn gmax_v(
        mu1: float32x4_t,
        var1: float32x4_t,
        mu2: float32x4_t,
        var2: float32x4_t,
    ) -> (float32x4_t, float32x4_t) {
        let one = vdupq_n_f32(1.0);
        let theta = vsqrtq_f32(vmaxq_f32(vaddq_f32(var1, var2), vdupq_n_f32(EPS)));
        let alpha = vdivq_f32(vsubq_f32(mu1, mu2), theta);
        let cdf = norm_cdf_v(alpha);
        let q = vsubq_f32(one, cdf);
        let pdf = norm_pdf_v(alpha);
        let tp = vmulq_f32(theta, pdf);
        let m = vfmaq_f32(vfmaq_f32(tp, mu2, q), mu1, cdf);
        let s1 = vfmaq_f32(var1, mu1, mu1);
        let s2 = vfmaq_f32(var2, mu2, mu2);
        let e2 = vfmaq_f32(
            vfmaq_f32(vmulq_f32(vaddq_f32(mu1, mu2), tp), s2, q),
            s1,
            cdf,
        );
        let v = vmaxq_f32(vfmsq_f32(e2, m, m), vdupq_n_f32(0.0));
        (m, v)
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn gaussian_max2_into(
        mu1: &[f32],
        var1: &[f32],
        mu2: &[f32],
        var2: &[f32],
        out_mu: &mut [f32],
        out_var: &mut [f32],
    ) {
        let n = mu1.len();
        let mut i = 0;
        while i + 4 <= n {
            let (m, v) = gmax_v(
                vld1q_f32(mu1.as_ptr().add(i)),
                vld1q_f32(var1.as_ptr().add(i)),
                vld1q_f32(mu2.as_ptr().add(i)),
                vld1q_f32(var2.as_ptr().add(i)),
            );
            vst1q_f32(out_mu.as_mut_ptr().add(i), m);
            vst1q_f32(out_var.as_mut_ptr().add(i), v);
            i += 4;
        }
        if i < n {
            let mut m1 = [0.0f32; 4];
            let mut v1 = [1.0f32; 4];
            let mut m2 = [0.0f32; 4];
            let mut v2 = [1.0f32; 4];
            m1[..n - i].copy_from_slice(&mu1[i..]);
            v1[..n - i].copy_from_slice(&var1[i..]);
            m2[..n - i].copy_from_slice(&mu2[i..]);
            v2[..n - i].copy_from_slice(&var2[i..]);
            let (m, v) = gmax_v(
                vld1q_f32(m1.as_ptr()),
                vld1q_f32(v1.as_ptr()),
                vld1q_f32(m2.as_ptr()),
                vld1q_f32(v2.as_ptr()),
            );
            vst1q_f32(m1.as_mut_ptr(), m);
            vst1q_f32(v1.as_mut_ptr(), v);
            out_mu[i..].copy_from_slice(&m1[..n - i]);
            out_var[i..].copy_from_slice(&v1[..n - i]);
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_joint_eq12(
        xm: &[f32],
        xa: &[f32],
        wm: &[f32],
        wa: &[f32],
    ) -> (f32, f32) {
        let k = xm.len();
        let mut mu = vdupq_n_f32(0.0);
        let mut var = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= k {
            let xmv = vld1q_f32(xm.as_ptr().add(i));
            let wmv = vld1q_f32(wm.as_ptr().add(i));
            let xav = vld1q_f32(xa.as_ptr().add(i));
            let wav = vld1q_f32(wa.as_ptr().add(i));
            let t = vmulq_f32(xmv, wmv);
            mu = vaddq_f32(mu, t);
            // per-element difference, like the scalar kernel
            var = vaddq_f32(var, vfmsq_f32(vmulq_f32(xav, wav), t, t));
            i += 4;
        }
        let mut mu_s = vaddvq_f32(mu);
        let mut var_s = vaddvq_f32(var);
        while i < k {
            let t = xm[i] * wm[i];
            mu_s += t;
            var_s += xa[i] * wa[i] - t * t;
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_first_layer(xm: &[f32], wm: &[f32], wa: &[f32]) -> (f32, f32) {
        let k = xm.len();
        let mut mu = vdupq_n_f32(0.0);
        let mut var = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= k {
            let xmv = vld1q_f32(xm.as_ptr().add(i));
            let wmv = vld1q_f32(wm.as_ptr().add(i));
            let wav = vld1q_f32(wa.as_ptr().add(i));
            mu = vfmaq_f32(mu, xmv, wmv);
            var = vfmaq_f32(var, vmulq_f32(xmv, xmv), wav);
            i += 4;
        }
        let mut mu_s = vaddvq_f32(mu);
        let mut var_s = vaddvq_f32(var);
        while i < k {
            mu_s += xm[i] * wm[i];
            var_s += xm[i] * xm[i] * wa[i];
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_mean(xm: &[f32], wm: &[f32]) -> f32 {
        let k = xm.len();
        let mut mu = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= k {
            mu = vfmaq_f32(mu, vld1q_f32(xm.as_ptr().add(i)), vld1q_f32(wm.as_ptr().add(i)));
            i += 4;
        }
        let mut mu_s = vaddvq_f32(mu);
        while i < k {
            mu_s += xm[i] * wm[i];
            i += 1;
        }
        mu_s
    }

    // -- mixed-precision conversions + packed-operand dots ------------------

    /// Widen 4 packed bf16 by zero-extend + 16-bit left shift (exact).
    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: integer ops only; requires neon (baseline on aarch64,
    // guaranteed by detect-gated callers); reads exactly 8 bytes at `p`,
    // which callers guarantee are in bounds.
    unsafe fn widen4_bf16(p: *const u16) -> float32x4_t {
        vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vld1_u16(p))))
    }

    /// Load 4 lanes of a packed operand as f32. Stable `std::arch` has no
    /// aarch64 fp16 vector conversions yet, so the f16 path widens through
    /// the scalar reference into a stack buffer — the same bits (widening
    /// is exact), just slower.
    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: requires neon (guaranteed by detect-gated callers); all
    // memory access is 4 in-bounds lanes at element offset `i` (callers
    // keep `i + 4 <= len`) or a padded stack buffer.
    unsafe fn load4(s: PackedSlice<'_>, i: usize) -> float32x4_t {
        match s {
            PackedSlice::F32(v) => vld1q_f32(v.as_ptr().add(i)),
            PackedSlice::U16(Precision::Bf16, v) => widen4_bf16(v.as_ptr().add(i)),
            PackedSlice::U16(p, v) => {
                let mut buf = [0.0f32; 4];
                for (l, b) in buf.iter_mut().enumerate() {
                    *b = half::widen(p, *v.get_unchecked(i + l));
                }
                vld1q_f32(buf.as_ptr())
            }
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Unaligned 4-lane loads/stores plus a
    // scalar tail keep every slice length in bounds.
    pub unsafe fn widen_bf16_into(src: &[u16], out: &mut [f32]) {
        let n = src.len();
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(out.as_mut_ptr().add(i), widen4_bf16(src.as_ptr().add(i)));
            i += 4;
        }
        while i < n {
            out[i] = half::bf16_bits_to_f32(src[i]);
            i += 1;
        }
    }

    /// Narrow 4 f32 lanes to bf16 bits with round-to-nearest-even, NaNs
    /// truncated with the quiet bit forced — bitwise the scalar reference.
    #[inline]
    #[target_feature(enable = "neon")]
    // SAFETY: register-only math; requires neon, which every caller
    // (itself a target_feature fn reached via detect-gated dispatch) has.
    unsafe fn narrow4_bf16(v: float32x4_t) -> uint16x4_t {
        let bits = vreinterpretq_u32_f32(v);
        let lsb = vandq_u32(vshrq_n_u32::<16>(bits), vdupq_n_u32(1));
        let bias = vaddq_u32(vdupq_n_u32(0x7fff), lsb);
        let rounded = vshrq_n_u32::<16>(vaddq_u32(bits, bias));
        // NaN lanes truncate + force the quiet bit (rounding a NaN could
        // carry the payload into the infinity encoding).
        let qnan = vorrq_u32(vshrq_n_u32::<16>(bits), vdupq_n_u32(0x0040));
        let is_num = vceqq_f32(v, v); // all-ones on non-NaN lanes
        vmovn_u32(vbslq_u32(is_num, rounded, qnan))
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Unaligned 4-lane loads/stores plus a
    // scalar tail keep every slice length in bounds.
    pub unsafe fn narrow_bf16_into(src: &[f32], out: &mut [u16]) {
        let n = src.len();
        let mut i = 0;
        while i + 4 <= n {
            vst1_u16(
                out.as_mut_ptr().add(i),
                narrow4_bf16(vld1q_f32(src.as_ptr().add(i))),
            );
            i += 4;
        }
        while i < n {
            out[i] = half::f32_to_bf16_bits(src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_joint_eq12_packed(
        xm: &[f32],
        xa: &[f32],
        wm: PackedSlice<'_>,
        wa: PackedSlice<'_>,
    ) -> (f32, f32) {
        let k = xm.len();
        let mut mu = vdupq_n_f32(0.0);
        let mut var = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= k {
            let xmv = vld1q_f32(xm.as_ptr().add(i));
            let wmv = load4(wm, i);
            let xav = vld1q_f32(xa.as_ptr().add(i));
            let wav = load4(wa, i);
            let t = vmulq_f32(xmv, wmv);
            mu = vaddq_f32(mu, t);
            // identical accumulation structure to the f32 kernel — the
            // packed kernel IS the widen-then-f32 kernel, bitwise
            var = vaddq_f32(var, vfmsq_f32(vmulq_f32(xav, wav), t, t));
            i += 4;
        }
        let mut mu_s = vaddvq_f32(mu);
        let mut var_s = vaddvq_f32(var);
        while i < k {
            let t = xm[i] * wm.get(i);
            mu_s += t;
            var_s += xa[i] * wa.get(i) - t * t;
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_first_layer_packed(
        xm: &[f32],
        wm: PackedSlice<'_>,
        wa: PackedSlice<'_>,
    ) -> (f32, f32) {
        let k = xm.len();
        let mut mu = vdupq_n_f32(0.0);
        let mut var = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= k {
            let xmv = vld1q_f32(xm.as_ptr().add(i));
            let wmv = load4(wm, i);
            let wav = load4(wa, i);
            mu = vfmaq_f32(mu, xmv, wmv);
            var = vfmaq_f32(var, vmulq_f32(xmv, xmv), wav);
            i += 4;
        }
        let mut mu_s = vaddvq_f32(mu);
        let mut var_s = vaddvq_f32(var);
        while i < k {
            mu_s += xm[i] * wm.get(i);
            var_s += xm[i] * xm[i] * wa.get(i);
            i += 1;
        }
        (mu_s, var_s)
    }

    #[target_feature(enable = "neon")]
    // SAFETY: callable only with neon available — guaranteed by the
    // detect-gated dispatch above. Memory access is unaligned loads/stores
    // over the argument slices plus padded stack tail buffers, so every
    // slice length stays in bounds.
    pub unsafe fn dot_mean_packed(xm: &[f32], wm: PackedSlice<'_>) -> f32 {
        let k = xm.len();
        let mut mu = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= k {
            mu = vfmaq_f32(mu, vld1q_f32(xm.as_ptr().add(i)), load4(wm, i));
            i += 4;
        }
        let mut mu_s = vaddvq_f32(mu);
        while i < k {
            mu_s += xm[i] * wm.get(i);
            i += 1;
        }
        mu_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn isa_spelling_roundtrips() {
        for isa in [Isa::Scalar, Isa::Native] {
            assert_eq!(Isa::parse(isa.as_str()), Some(isa));
        }
        assert_eq!(Isa::parse("avx512"), None);
        assert_eq!(resolve(Isa::Scalar), Backend::Scalar);
        // Native resolves to *some* backend, deterministically
        assert_eq!(resolve(Isa::Native), resolve(Isa::Native));
    }

    #[test]
    fn exp_poly_matches_f64_exp() {
        // the shared polynomial algorithm itself, before any backend
        // renders it: ~1e-7 relative against f64 exp over the range the
        // moment-matching ops use (erf feeds it -x^2, x in [-6, 6])
        let mut worst = 0.0f64;
        for i in -3600..=100 {
            let x = i as f32 * 0.01;
            let got = exp_poly(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            worst = worst.max(rel);
        }
        assert!(worst < 5e-7, "exp_poly max relative error {worst}");
    }

    #[test]
    fn detected_backend_is_stable_and_named() {
        let b = detect();
        assert_eq!(b, detect());
        assert!(!b.name().is_empty());
    }

    #[test]
    fn simd_erf_matches_scalar_closely() {
        let b = detect();
        let xs: Vec<f32> = (-600..=600).map(|i| i as f32 * 0.01).collect();
        let mut got = vec![0.0f32; xs.len()];
        erf_into(b, &xs, &mut got);
        for (&x, &g) in xs.iter().zip(&got) {
            let s = crate::ops::erf::erf(x);
            assert!(
                (g - s).abs() <= 1e-6,
                "erf({x}): {} backend {g} vs scalar {s}",
                b.name()
            );
        }
    }

    #[test]
    fn simd_relu_moments_match_scalar_closely() {
        let b = detect();
        check(10, |g| {
            let n = g.usize_in(1, 67); // odd sizes exercise the padded tail
            let mu: Vec<f32> = g.normal_vec(n, 2.0);
            let var: Vec<f32> = g.var_vec(n, 1.0);
            let mut om = vec![0.0f32; n];
            let mut oe = vec![0.0f32; n];
            relu_moments_into(b, &mu, &var, &mut om, &mut oe);
            for i in 0..n {
                let (m, e2) = crate::ops::relu::relu_moments(mu[i], var[i]);
                assert!(
                    (om[i] - m).abs() <= 1e-5 + 1e-4 * m.abs(),
                    "relu mu lane {i}: {} vs {m}",
                    om[i]
                );
                assert!(
                    (oe[i] - e2).abs() <= 1e-5 + 1e-4 * e2.abs(),
                    "relu e2 lane {i}: {} vs {e2}",
                    oe[i]
                );
            }
        });
    }

    #[test]
    fn simd_gaussian_max_matches_scalar_closely() {
        let b = detect();
        check(10, |g| {
            let n = g.usize_in(1, 35);
            let m1: Vec<f32> = g.normal_vec(n, 2.0);
            let v1: Vec<f32> = g.var_vec(n, 1.0);
            let m2: Vec<f32> = g.normal_vec(n, 2.0);
            let v2: Vec<f32> = g.var_vec(n, 1.0);
            let mut om = vec![0.0f32; n];
            let mut ov = vec![0.0f32; n];
            gaussian_max2_into(b, &m1, &v1, &m2, &v2, &mut om, &mut ov);
            for i in 0..n {
                let (m, v) = crate::ops::maxpool::gaussian_max(m1[i], v1[i], m2[i], v2[i]);
                assert!((om[i] - m).abs() <= 1e-5 + 1e-4 * m.abs(), "gmax mu lane {i}");
                assert!((ov[i] - v).abs() <= 1e-4 + 1e-3 * v.abs(), "gmax var lane {i}");
            }
        });
    }

    #[test]
    fn simd_dots_match_naive_reductions() {
        let b = detect();
        check(12, |g| {
            let k = g.usize_in(1, 130); // covers sub-lane and remainder
            let xm: Vec<f32> = g.normal_vec(k, 1.0);
            let xa: Vec<f32> = g.var_vec(k, 1.0);
            let wm: Vec<f32> = g.normal_vec(k, 0.3);
            let wa: Vec<f32> = g.var_vec(k, 0.1);
            // f64 references
            let (mut mu64, mut e64, mut c64, mut f64v) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for i in 0..k {
                let t = xm[i] as f64 * wm[i] as f64;
                mu64 += t;
                e64 += xa[i] as f64 * wa[i] as f64;
                c64 += t * t;
                f64v += (xm[i] as f64) * (xm[i] as f64) * wa[i] as f64;
            }
            let (mu, var) = dot_joint_eq12(b, &xm, &xa, &wm, &wa);
            assert!((mu as f64 - mu64).abs() <= 1e-4 + 1e-4 * mu64.abs(), "eq12 mu");
            let want_var = e64 - c64;
            assert!(
                (var as f64 - want_var).abs() <= 1e-3 + 1e-3 * want_var.abs(),
                "eq12 var {var} vs {want_var}"
            );
            let (fmu, fvar) = dot_first_layer(b, &xm, &wm, &wa);
            assert!((fmu as f64 - mu64).abs() <= 1e-4 + 1e-4 * mu64.abs(), "eq13 mu");
            assert!((fvar as f64 - f64v).abs() <= 1e-4 + 1e-4 * f64v.abs(), "eq13 var");
            let m = dot_mean(b, &xm, &wm);
            assert!((m as f64 - mu64).abs() <= 1e-4 + 1e-4 * mu64.abs(), "mean");
        });
    }

    #[test]
    fn scalar_backend_is_bit_identical_to_scalar_helpers() {
        // the always-available fallback must reproduce the historical
        // scalar ops exactly — it IS those ops
        let mut g = Gen::new(9);
        let n = 23;
        let mu: Vec<f32> = g.normal_vec(n, 2.0);
        let var: Vec<f32> = g.var_vec(n, 1.0);
        let mut om = vec![0.0f32; n];
        let mut oe = vec![0.0f32; n];
        relu_moments_into(Backend::Scalar, &mu, &var, &mut om, &mut oe);
        for i in 0..n {
            let (m, e2) = crate::ops::relu::relu_moments(mu[i], var[i]);
            assert_eq!(om[i].to_bits(), m.to_bits());
            assert_eq!(oe[i].to_bits(), e2.to_bits());
        }
        let mut out = vec![0.0f32; n];
        erf_into(Backend::Scalar, &mu, &mut out);
        for i in 0..n {
            assert_eq!(out[i].to_bits(), crate::ops::erf::erf(mu[i]).to_bits());
        }
    }

    #[test]
    fn simd_conversions_bit_match_scalar_reference() {
        // narrow/widen on the detected backend must be bitwise the scalar
        // reference in util::half, for every slice length (odd lengths
        // exercise the scalar tails) — seeds printed for replay.
        let b = detect();
        check(12, |g| {
            let n = g.usize_in(1, 67);
            let mut xs: Vec<f32> = g.normal_vec(n, 100.0);
            // salt in values the rounding edge cases care about
            if n > 2 {
                xs[0] = 2.0f32.powi(-25) * 1.5; // f16 subnormal range
                xs[1] = 65520.0; // f16 overflow-by-rounding boundary
                xs[2] = f32::from_bits(0x3f80_0000 | (1 << 12)); // RNE tie
            }
            for prec in [Precision::F16, Precision::Bf16] {
                let mut packed = vec![0u16; n];
                narrow_into(b, prec, &xs, &mut packed);
                for i in 0..n {
                    assert_eq!(
                        packed[i],
                        half::narrow(prec, xs[i]),
                        "{} narrow lane {i} of {n} ({prec})",
                        b.name()
                    );
                }
                let mut widened = vec![0.0f32; n];
                widen_into(b, prec, &packed, &mut widened);
                for i in 0..n {
                    assert_eq!(
                        widened[i].to_bits(),
                        half::widen(prec, packed[i]).to_bits(),
                        "{} widen lane {i} of {n} ({prec})",
                        b.name()
                    );
                }
            }
        });
    }

    #[test]
    fn simd_conversions_handle_specials_bitwise() {
        let b = detect();
        let xs = [
            0.0f32,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::MAX,
            f32::MIN_POSITIVE,
            2.0f32.powi(-25),
            -2.0f32.powi(-24),
            65504.0,
            65520.0,
        ];
        for prec in [Precision::F16, Precision::Bf16] {
            let mut packed = vec![0u16; xs.len()];
            narrow_into(b, prec, &xs, &mut packed);
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(packed[i], half::narrow(prec, x), "special {x} ({prec})");
            }
        }
    }

    #[test]
    fn packed_dots_are_bitwise_widen_then_f32() {
        // The packed-operand kernels must equal the f32 kernels run on
        // pre-widened weight copies, bit for bit, on every backend and
        // for every mean/var precision combination (f32 allowed in either
        // slot).
        let precisions = [Precision::F32, Precision::F16, Precision::Bf16];
        for b in [Backend::Scalar, detect()] {
            check(6, |g| {
                let k = g.usize_in(1, 130);
                let xm: Vec<f32> = g.normal_vec(k, 1.0);
                let xa: Vec<f32> = g.var_vec(k, 1.0);
                let wm: Vec<f32> = g.normal_vec(k, 0.3);
                let wa: Vec<f32> = g.var_vec(k, 0.1);
                for pm in precisions {
                    for pa in precisions {
                        // quantize to the storage precision, then compare
                        // packed kernel vs f32 kernel on the widened copy
                        let (wm_q, wm_packed): (Vec<f32>, Vec<u16>) = match pm {
                            Precision::F32 => (wm.clone(), Vec::new()),
                            p => {
                                let packed: Vec<u16> =
                                    wm.iter().map(|&x| half::narrow(p, x)).collect();
                                (packed.iter().map(|&h| half::widen(p, h)).collect(), packed)
                            }
                        };
                        let (wa_q, wa_packed): (Vec<f32>, Vec<u16>) = match pa {
                            Precision::F32 => (wa.clone(), Vec::new()),
                            p => {
                                let packed: Vec<u16> =
                                    wa.iter().map(|&x| half::narrow(p, x)).collect();
                                (packed.iter().map(|&h| half::widen(p, h)).collect(), packed)
                            }
                        };
                        let pm_s = match pm {
                            Precision::F32 => PackedSlice::F32(&wm_q),
                            p => PackedSlice::U16(p, &wm_packed),
                        };
                        let pa_s = match pa {
                            Precision::F32 => PackedSlice::F32(&wa_q),
                            p => PackedSlice::U16(p, &wa_packed),
                        };

                        let (m0, v0) = dot_joint_eq12(b, &xm, &xa, &wm_q, &wa_q);
                        let (m1, v1) = dot_joint_eq12_packed(b, &xm, &xa, pm_s, pa_s);
                        assert_eq!(m0.to_bits(), m1.to_bits(), "{} eq12 mu {pm}/{pa}", b.name());
                        assert_eq!(v0.to_bits(), v1.to_bits(), "{} eq12 var {pm}/{pa}", b.name());

                        let (fm0, fv0) = dot_first_layer(b, &xm, &wm_q, &wa_q);
                        let (fm1, fv1) = dot_first_layer_packed(b, &xm, pm_s, pa_s);
                        assert_eq!(fm0.to_bits(), fm1.to_bits(), "{} eq13 mu {pm}/{pa}", b.name());
                        assert_eq!(fv0.to_bits(), fv1.to_bits(), "{} eq13 var {pm}/{pa}", b.name());

                        let d0 = dot_mean(b, &xm, &wm_q);
                        let d1 = dot_mean_packed(b, &xm, pm_s);
                        assert_eq!(d0.to_bits(), d1.to_bits(), "{} mean {pm}", b.name());
                    }
                }
            });
        }
    }

    #[test]
    fn packed_slice_accessors() {
        let f = [1.0f32, 2.0, 3.0];
        let s = PackedSlice::F32(&f);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
        assert_eq!(s.get(1), 2.0);
        assert_eq!(s.slice(1..3).get(0), 2.0);
        let packed: Vec<u16> = f.iter().map(|&x| half::f32_to_f16_bits(x)).collect();
        let p = PackedSlice::U16(Precision::F16, &packed);
        assert_eq!(p.len(), 3);
        assert_eq!(p.get(2), 3.0); // small integers are exact in f16
        assert_eq!(p.slice(0..2).len(), 2);
    }
}
