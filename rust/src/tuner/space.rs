//! Schedule search space (what the paper's TE schedule templates expose).

use crate::ops::simd::Isa;
use crate::ops::{LoopOrder, Schedule};
use crate::util::half::Precision;
use crate::util::rng::SplitMix64;

/// Bounds of the schedule search.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub orders: Vec<LoopOrder>,
    pub unrolls: Vec<usize>,
    pub tile_ns: Vec<usize>,
    pub tile_ks: Vec<usize>,
    pub max_threads: usize,
    /// ISA candidates (the explicit-SIMD dimension). Defaults to both;
    /// `pfp tune --isa scalar|native` narrows it to one.
    pub isas: Vec<Isa>,
    /// Fused-epilogue candidates. Defaults to both, so the search decides
    /// per layer whether fusing the elementwise chain into the compute
    /// kernel pays; `pfp tune --fuse on|off` narrows it to one.
    pub fuses: Vec<bool>,
    /// Storage-precision candidates (the mixed-precision dimension).
    /// Defaults to all three formats so the search decides per layer
    /// whether halved weight/activation traffic beats the widen cost;
    /// `pfp tune --precision f32|f16|bf16` narrows it to one.
    pub precisions: Vec<Precision>,
    /// probability of sampling a tiled candidate at all
    pub tile_prob: f64,
}

impl SearchSpace {
    /// Default dense/conv space on this host.
    pub fn dense_default(max_threads: usize) -> Self {
        Self {
            orders: vec![LoopOrder::Mkn, LoopOrder::Mnk],
            unrolls: vec![1, 2, 4, 8],
            tile_ns: vec![0, 8, 16, 32],
            tile_ks: vec![0, 32, 64, 128],
            max_threads: max_threads.max(1),
            isas: vec![Isa::Scalar, Isa::Native],
            fuses: vec![false, true],
            precisions: vec![Precision::F32, Precision::F16, Precision::Bf16],
            tile_prob: 0.25,
        }
    }

    fn pick<'a, T>(&self, xs: &'a [T], rng: &mut SplitMix64) -> &'a T {
        &xs[rng.randint(xs.len() as u64) as usize]
    }

    /// Uniform random candidate.
    pub fn sample(&self, rng: &mut SplitMix64) -> Schedule {
        let tiled = rng.uniform() < self.tile_prob;
        let (tile_n, tile_k) = if tiled {
            (
                *self.pick(&self.tile_ns[1..], rng),
                *self.pick(&self.tile_ks[1..], rng),
            )
        } else {
            (0, 0)
        };
        Schedule {
            loop_order: *self.pick(&self.orders, rng),
            tile_n,
            tile_k,
            unroll: *self.pick(&self.unrolls, rng),
            vectorize: rng.randint(2) == 0,
            threads: 1 + rng.randint(self.max_threads as u64) as usize,
            isa: *self.pick(&self.isas, rng),
            fuse: *self.pick(&self.fuses, rng),
            precision: *self.pick(&self.precisions, rng),
        }
    }

    /// Mutate one knob of a (non-tiled) parent — the stochastic-tuning
    /// step. Never *introduces* tiles (the paper's rule: tiling is outside
    /// the stochastic search).
    pub fn mutate(&self, parent: &Schedule, rng: &mut SplitMix64) -> Schedule {
        let mut s = *parent;
        match rng.randint(7) {
            0 => s.loop_order = *self.pick(&self.orders, rng),
            1 => s.unroll = *self.pick(&self.unrolls, rng),
            2 => s.vectorize = !s.vectorize,
            3 => s.isa = *self.pick(&self.isas, rng),
            4 => s.fuse = *self.pick(&self.fuses, rng),
            5 => s.precision = *self.pick(&self.precisions, rng),
            _ => s.threads = 1 + rng.randint(self.max_threads as u64) as usize,
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_in_bounds() {
        let space = SearchSpace::dense_default(4);
        let mut rng = SplitMix64::new(1);
        let mut saw_native = false;
        let mut saw_scalar = false;
        let mut saw_fused = false;
        let mut saw_unfused = false;
        let mut saw_packed = false;
        let mut saw_f32 = false;
        for _ in 0..200 {
            let s = space.sample(&mut rng);
            assert!(space.unrolls.contains(&s.unroll));
            assert!((1..=4).contains(&s.threads));
            assert!(space.isas.contains(&s.isa));
            saw_native |= s.isa == Isa::Native;
            saw_scalar |= s.isa == Isa::Scalar;
            saw_fused |= s.fuse;
            saw_unfused |= !s.fuse;
            assert!(space.precisions.contains(&s.precision));
            saw_packed |= !s.precision.is_f32();
            saw_f32 |= s.precision.is_f32();
            if s.tile_n > 0 {
                assert!(space.tile_ns.contains(&s.tile_n));
                assert!(s.tile_k > 0);
            }
        }
        assert!(saw_native && saw_scalar, "sampling must cover the ISA dimension");
        assert!(saw_fused && saw_unfused, "sampling must cover the fuse dimension");
        assert!(saw_packed && saw_f32, "sampling must cover the precision dimension");
    }

    #[test]
    fn restricted_isa_space_samples_only_that_isa() {
        let mut space = SearchSpace::dense_default(2);
        space.isas = vec![Isa::Scalar];
        let mut rng = SplitMix64::new(5);
        for _ in 0..50 {
            assert_eq!(space.sample(&mut rng).isa, Isa::Scalar);
            let child = space.mutate(&Schedule::tuned(1).with_isa(Isa::Scalar), &mut rng);
            assert_eq!(child.isa, Isa::Scalar);
        }
    }

    #[test]
    fn restricted_fuse_space_samples_only_that_setting() {
        // `pfp tune --fuse off` pins the dimension: no fused candidate may
        // be sampled or mutated into existence
        let mut space = SearchSpace::dense_default(2);
        space.fuses = vec![false];
        let mut rng = SplitMix64::new(9);
        for _ in 0..50 {
            assert!(!space.sample(&mut rng).fuse);
            let child = space.mutate(&Schedule::tuned(1), &mut rng);
            assert!(!child.fuse);
        }
    }

    #[test]
    fn restricted_precision_space_samples_only_that_format() {
        // `pfp tune --precision f32` pins the dimension: no packed
        // candidate may be sampled or mutated into existence
        let mut space = SearchSpace::dense_default(2);
        space.precisions = vec![Precision::F32];
        let mut rng = SplitMix64::new(11);
        for _ in 0..50 {
            assert!(space.sample(&mut rng).precision.is_f32());
            let child = space.mutate(&Schedule::tuned(1), &mut rng);
            assert!(child.precision.is_f32());
        }
    }

    #[test]
    fn mutation_changes_one_knob_and_never_adds_tiles() {
        let space = SearchSpace::dense_default(4);
        let mut rng = SplitMix64::new(2);
        let parent = Schedule::tuned(2);
        for _ in 0..100 {
            let child = space.mutate(&parent, &mut rng);
            assert_eq!(child.tile_n, 0);
            assert_eq!(child.tile_k, 0);
            let diffs = [
                child.loop_order != parent.loop_order,
                child.unroll != parent.unroll,
                child.vectorize != parent.vectorize,
                child.isa != parent.isa,
                child.fuse != parent.fuse,
                child.precision != parent.precision,
                child.threads != parent.threads,
            ]
            .iter()
            .filter(|&&d| d)
            .count();
            assert!(diffs <= 1);
        }
    }
}
