//! Auto-tuner — the paper's Meta-Scheduler analog (Section 6.3).
//!
//! Searches the [`Schedule`](crate::ops::Schedule) space with on-device
//! measurement: random sampling plus a small evolutionary refinement
//! (mutation of the incumbent population — the "stochastic tuning" the
//! paper leans on). The paper's footnote that *tiling does not support
//! stochastic tuning* is mirrored here: schedules with tiles enabled are
//! only reachable through random sampling, never through mutation.
//!
//! Tuning records are persisted to `artifacts/tuning/*.json` so serving
//! picks up tuned schedules without re-searching.

pub mod records;
pub mod space;

pub use records::TuningRecords;
pub use space::SearchSpace;

use std::sync::Arc;

use crate::model::{pack_tensor, Arch, FusePolicy, PosteriorWeights, Schedules};
use crate::ops::dense::{
    dense_kernel_packed_tiled_into, dense_kernel_tiled_into, DenseSlices, JointEq12,
    PackedDenseSlices,
};
use crate::ops::simd::PackedSlice;
use crate::ops::{Epilogue, Schedule};
use crate::plan::{tile_ranges, CompiledPlan, DenseWorkload, PlanMode};
use crate::tensor::Tensor;
use crate::util::half::Precision;
use crate::util::rng::SplitMix64;
use crate::util::threadpool;

/// One measured trial.
#[derive(Clone, Debug)]
pub struct Trial {
    pub schedule: Schedule,
    pub median_ms: f64,
}

/// Search outcome.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: Schedule,
    pub best_ms: f64,
    pub baseline_ms: f64,
    pub trials: Vec<Trial>,
}

impl TuneResult {
    pub fn speedup(&self) -> f64 {
        if self.best_ms > 0.0 {
            self.baseline_ms / self.best_ms
        } else {
            0.0
        }
    }
}

/// Measure a schedule: median latency in ms over a few repetitions.
pub fn measure<F: FnMut(&Schedule)>(sched: &Schedule, reps: usize, mut work: F) -> f64 {
    // one warmup
    work(sched);
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = std::time::Instant::now();
        work(sched);
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    crate::util::stats::median(&samples)
}

/// Configuration for the search loops.
#[derive(Clone, Copy, Debug)]
pub struct TuneOpts {
    pub random_trials: usize,
    pub generations: usize,
    pub population: usize,
    pub reps: usize,
    pub seed: u64,
}

impl Default for TuneOpts {
    fn default() -> Self {
        Self {
            random_trials: 24,
            generations: 4,
            population: 6,
            reps: 5,
            seed: 0xBEEF,
        }
    }
}

/// Random + evolutionary schedule search over `space`, measuring with
/// `work` (one full operator execution per call).
pub fn tune<F: FnMut(&Schedule)>(
    space: &SearchSpace,
    opts: TuneOpts,
    mut work: F,
) -> TuneResult {
    let mut rng = SplitMix64::new(opts.seed);
    let baseline = Schedule::baseline();
    let baseline_ms = measure(&baseline, opts.reps, &mut work);
    let mut trials = vec![Trial { schedule: baseline, median_ms: baseline_ms }];

    // phase 1: random sampling (covers the tiled region too)
    for _ in 0..opts.random_trials {
        let s = space.sample(&mut rng);
        let ms = measure(&s, opts.reps, &mut work);
        trials.push(Trial { schedule: s, median_ms: ms });
    }

    // phase 2: evolutionary refinement — mutate the incumbent population.
    // Tiled schedules are excluded from mutation (the paper's "tiling
    // disables stochastic tuning" rule).
    for _ in 0..opts.generations {
        let mut pop: Vec<Trial> = trials.clone();
        pop.sort_by(|a, b| a.median_ms.partial_cmp(&b.median_ms).unwrap());
        pop.truncate(opts.population);
        for parent in pop {
            if parent.schedule.tile_n > 0 || parent.schedule.tile_k > 0 {
                continue;
            }
            let child = space.mutate(&parent.schedule, &mut rng);
            if trials.iter().any(|t| t.schedule == child) {
                continue;
            }
            let ms = measure(&child, opts.reps, &mut work);
            trials.push(Trial { schedule: child, median_ms: ms });
        }
    }

    let best = trials
        .iter()
        .min_by(|a, b| a.median_ms.partial_cmp(&b.median_ms).unwrap())
        .unwrap()
        .clone();
    TuneResult {
        best: best.schedule,
        best_ms: best.median_ms,
        baseline_ms,
        trials,
    }
}

/// One tuned layer: the workload it was measured on plus the search
/// outcome.
#[derive(Clone, Debug)]
pub struct LayerTuneResult {
    pub workload: DenseWorkload,
    pub result: TuneResult,
}

/// Tune every compute layer of `arch` on its **actual** workload shape at
/// `batch` (conv layers are measured on their im2col'd dense dims, which
/// is exactly the kernel the plan executes) — the per-operator-workload
/// search the paper's Meta-Scheduler runs, feeding
/// [`Schedules::per_layer`] via [`TuningRecords::layer_key`] records.
///
/// Measurement runs the **planned executor**, not the Tensor-level
/// operator API: each candidate's `threads` knob becomes the same
/// pre-partitioned row-tile set the compiled plan would bind
/// ([`tile_ranges`]), gang-dispatched onto the process pool into reused
/// output buffers ([`dense_kernel_tiled_into`]) — so a persisted record
/// describes exactly the code path that serves it, parallel, tiled,
/// explicit-SIMD (`isa`), and fused-epilogue (`fuse`) candidates included
/// (the candidate's ISA knob resolves through the same runtime detector
/// serving uses, and `fuse: true` candidates run the epilogue the plan
/// would actually fuse into this layer — [`DenseWorkload::ep`], resolved
/// by lowering the probe plan with [`FusePolicy::On`]). Inputs are the
/// posterior's real weight tensors (flattened to `[N, K]` — identical
/// memory layout) and synthetic activations of the layer's true shape.
pub fn tune_per_layer(
    arch: &Arch,
    weights: &PosteriorWeights,
    batch: usize,
    opts: TuneOpts,
    space: &SearchSpace,
) -> Vec<LayerTuneResult> {
    // a throwaway plan lowering resolves every layer's concrete dims and
    // fusable epilogues (policy On so `DenseWorkload::ep` reports what a
    // fused plan would run; the knob-off measurement path ignores it)
    let plan = CompiledPlan::compile(
        arch,
        Arc::new(weights.clone()),
        &Schedules::baseline().with_fuse(FusePolicy::On),
        batch,
        PlanMode::Pfp,
    )
    .expect("plan lowering failed");
    let mut rng = SplitMix64::new(opts.seed ^ 0xA11C);
    let pool = threadpool::global();
    plan.dense_workloads()
        .into_iter()
        .map(|wl| {
            let lw = &weights.layers[wl.compute_idx];
            let w_mu = Tensor::new(vec![wl.n, wl.k], lw.w_mu.data().to_vec()).unwrap();
            let w_e2 = Tensor::new(vec![wl.n, wl.k], lw.w_e2.data().to_vec()).unwrap();
            let mut x = vec![0.0f32; wl.m * wl.k];
            rng.fill_normal(&mut x, 0.5, 0.25);
            let x_mu = Tensor::new(vec![wl.m, wl.k], x).unwrap();
            let x_e2 = x_mu.squared();
            // reused across trials, like the plan's workspace
            let mut out_mu = vec![0.0f32; wl.m * wl.n];
            let mut out_var = vec![0.0f32; wl.m * wl.n];
            let slices = DenseSlices {
                m: wl.m,
                k: wl.k,
                n: wl.n,
                x_mu: x_mu.data(),
                x_aux: x_e2.data(),
                w_mu: w_mu.data(),
                w_aux: w_e2.data(),
                b_mu: Some(lw.b_mu.data()),
                b_var: Some(lw.b_var.data()),
            };
            let fused_ep = wl.ep;
            // packed weight copies for the precision dimension, converted
            // once per layer (like plan compile) so the search loop only
            // pays the kernel, not the conversion
            let packs: Vec<(Precision, _, _)> = space
                .precisions
                .iter()
                .filter(|p| !p.is_f32())
                .map(|&p| {
                    (
                        p,
                        pack_tensor(&w_mu, p).expect("non-f32 precision packs"),
                        pack_tensor(&w_e2, p).expect("non-f32 precision packs"),
                    )
                })
                .collect();
            let result = tune(space, opts, |s| {
                let tiles = tile_ranges(wl.m, s.threads);
                // a `fuse: on` candidate is measured with the epilogue
                // the plan would fuse here; `fuse: off` measures the bare
                // kernel the unfused plan binds
                let ep = if s.fuse { fused_ep } else { Epilogue::None };
                if s.precision.is_f32() {
                    dense_kernel_tiled_into::<JointEq12>(
                        pool,
                        &slices,
                        s,
                        ep,
                        &tiles,
                        &mut out_mu,
                        &mut out_var,
                    );
                } else {
                    // a packed candidate is measured through the same
                    // packed-operand kernel a mixed-precision plan binds
                    let (_, pm, pa) =
                        packs.iter().find(|(p, ..)| *p == s.precision).unwrap();
                    let pslices = PackedDenseSlices {
                        m: wl.m,
                        k: wl.k,
                        n: wl.n,
                        x_mu: x_mu.data(),
                        x_aux: x_e2.data(),
                        w_mu: PackedSlice::U16(s.precision, pm.as_slice()),
                        w_aux: PackedSlice::U16(s.precision, pa.as_slice()),
                        b_mu: Some(lw.b_mu.data()),
                        b_var: Some(lw.b_var.data()),
                    };
                    dense_kernel_packed_tiled_into::<JointEq12>(
                        pool,
                        &pslices,
                        s,
                        ep,
                        &tiles,
                        &mut out_mu,
                        &mut out_var,
                    );
                }
            });
            LayerTuneResult { workload: wl, result }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::dense::{pfp_dense_joint, DenseArgs};
    use crate::util::prop::Gen;

    #[test]
    fn tune_finds_no_worse_than_baseline() {
        let mut g = Gen::new(1);
        let (m, k, n) = (4, 128, 32);
        let x_mu = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0)).unwrap();
        let x_e2 = x_mu.squared();
        let w_mu = Tensor::new(vec![n, k], g.normal_vec(n * k, 0.2)).unwrap();
        let w_e2 = w_mu.squared();
        let space = SearchSpace::dense_default(1);
        let opts = TuneOpts { random_trials: 6, generations: 1, population: 3, reps: 2, seed: 1 };
        let res = tune(&space, opts, |s| {
            let _ = pfp_dense_joint(
                &DenseArgs {
                    x_mu: &x_mu, x_aux: &x_e2, w_mu: &w_mu, w_aux: &w_e2,
                    b_mu: None, b_var: None,
                },
                s,
            );
        });
        assert!(res.best_ms <= res.baseline_ms * 1.2);
        assert!(res.trials.len() >= 7);
        assert!(res.speedup() > 0.0);
    }

    #[test]
    fn per_layer_tuning_measures_actual_shapes() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 2);
        let space = SearchSpace::dense_default(1);
        let opts = TuneOpts {
            random_trials: 2,
            generations: 0,
            population: 2,
            reps: 1,
            seed: 3,
        };
        let res = tune_per_layer(&arch, &w, 4, opts, &space);
        assert_eq!(res.len(), 3, "one search per compute layer");
        // each layer searched on its own (m, k, n), not one class shape
        assert_eq!((res[0].workload.m, res[0].workload.k, res[0].workload.n), (4, 784, 100));
        assert_eq!((res[2].workload.k, res[2].workload.n), (100, 10));
        assert!(res.iter().all(|r| r.result.best_ms > 0.0));
    }

    #[test]
    fn per_layer_tuning_searches_parallel_and_tiled_candidates() {
        // with a multi-thread space every candidate — parallel and tiled
        // included — must measure through the planned tile executor
        // without error; the recorded trials cover the parallel region
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 4);
        let mut space = SearchSpace::dense_default(3);
        space.tile_prob = 0.6; // force tiled candidates into the sample
        let opts = TuneOpts {
            random_trials: 8,
            generations: 1,
            population: 3,
            reps: 1,
            seed: 7,
        };
        let res = tune_per_layer(&arch, &w, 4, opts, &space);
        let trials: Vec<&Trial> =
            res.iter().flat_map(|r| r.result.trials.iter()).collect();
        assert!(
            trials.iter().any(|t| t.schedule.threads > 1),
            "no parallel candidate was measured"
        );
        assert!(
            trials.iter().any(|t| t.schedule.tile_n > 0),
            "no tiled candidate was measured"
        );
        assert!(trials.iter().all(|t| t.median_ms > 0.0));
    }

    #[test]
    fn per_layer_tuning_measures_packed_candidates() {
        // the default space carries the precision dimension; non-f32
        // candidates must route through the packed-operand kernel and
        // produce usable timings
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 9);
        let space = SearchSpace::dense_default(1);
        let opts = TuneOpts {
            random_trials: 10,
            generations: 0,
            population: 2,
            reps: 1,
            seed: 11,
        };
        let res = tune_per_layer(&arch, &w, 2, opts, &space);
        let trials: Vec<&Trial> =
            res.iter().flat_map(|r| r.result.trials.iter()).collect();
        assert!(
            trials.iter().any(|t| !t.schedule.precision.is_f32()),
            "no packed candidate was measured"
        );
        assert!(trials.iter().all(|t| t.median_ms > 0.0));
    }
}
