//! Tuning records: persisted best schedules per (operator, arch, batch)
//! so serving and benches reuse tuning results without re-searching —
//! the analog of TVM's tuning logs.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::ops::Schedule;
use crate::util::json::Json;

/// Key -> (schedule, measured median ms).
#[derive(Clone, Debug, Default)]
pub struct TuningRecords {
    pub records: BTreeMap<String, (Schedule, f64)>,
}

impl TuningRecords {
    pub fn key(op: &str, arch: &str, batch: usize) -> String {
        format!("{op}/{arch}/b{batch}")
    }

    /// Key for one compute layer's workload (the per-layer schedule
    /// table): `dense/mlp/L2/b10`. Layer keys never collide with class
    /// keys — [`lookup`](Self::lookup)'s `b`-prefix parse rejects the
    /// `L<i>/` segment.
    pub fn layer_key(op: &str, arch: &str, layer: usize, batch: usize) -> String {
        format!("{op}/{arch}/L{layer}/b{batch}")
    }

    pub fn insert(&mut self, key: String, sched: Schedule, ms: f64) {
        self.records.insert(key, (sched, ms));
    }

    pub fn get(&self, key: &str) -> Option<&(Schedule, f64)> {
        self.records.get(key)
    }

    /// Best schedule for (op, arch, batch), falling back to the nearest
    /// recorded batch for that op/arch, then to `default`.
    pub fn lookup(&self, op: &str, arch: &str, batch: usize, default: Schedule) -> Schedule {
        if let Some((s, _)) = self.get(&Self::key(op, arch, batch)) {
            return *s;
        }
        let prefix = format!("{op}/{arch}/b");
        let mut best: Option<(usize, Schedule)> = None;
        for (k, (s, _)) in &self.records {
            if let Some(b) = k.strip_prefix(&prefix).and_then(|v| v.parse::<usize>().ok()) {
                let dist = b.abs_diff(batch);
                if best.map_or(true, |(d, _)| dist < d) {
                    best = Some((dist, *s));
                }
            }
        }
        best.map(|(_, s)| s).unwrap_or(default)
    }

    /// Best schedule for compute layer `layer` of (op, arch) at `batch`:
    /// exact layer key, else nearest recorded batch for that layer, else
    /// the class-level [`lookup`](Self::lookup), else `default`.
    pub fn lookup_layer(
        &self,
        op: &str,
        arch: &str,
        layer: usize,
        batch: usize,
        default: Schedule,
    ) -> Schedule {
        if let Some((s, _)) = self.get(&Self::layer_key(op, arch, layer, batch)) {
            return *s;
        }
        let prefix = format!("{op}/{arch}/L{layer}/b");
        let mut best: Option<(usize, Schedule)> = None;
        for (k, (s, _)) in &self.records {
            if let Some(b) = k.strip_prefix(&prefix).and_then(|v| v.parse::<usize>().ok()) {
                let dist = b.abs_diff(batch);
                if best.map_or(true, |(d, _)| dist < d) {
                    best = Some((dist, *s));
                }
            }
        }
        best.map(|(_, s)| s)
            .unwrap_or_else(|| self.lookup(op, arch, batch, default))
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (k, (s, ms)) in &self.records {
            obj.insert(
                k.clone(),
                Json::obj(vec![
                    ("schedule", s.to_json()),
                    ("median_ms", Json::Num(*ms)),
                ]),
            );
        }
        Json::Obj(obj)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Json("tuning records must be an object".into()))?;
        let mut records = BTreeMap::new();
        for (k, entry) in obj {
            let sched = Schedule::from_json(
                entry
                    .get("schedule")
                    .ok_or_else(|| Error::Json("record missing schedule".into()))?,
            )?;
            let ms = entry.num_field("median_ms")?;
            records.insert(k.clone(), (sched, ms));
        }
        Ok(Self { records })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Load if present, else empty.
    pub fn load_or_default(path: &Path) -> Self {
        Self::load(path).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_json() {
        let mut r = TuningRecords::default();
        r.insert(
            TuningRecords::key("dense", "mlp", 10),
            Schedule::tuned(2),
            0.5,
        );
        r.insert(
            TuningRecords::key("conv", "lenet", 1),
            Schedule::tiled(16, 64),
            1.25,
        );
        let j = r.to_json();
        let back = TuningRecords::from_json(&j).unwrap();
        assert_eq!(back.records.len(), 2);
        assert_eq!(
            back.get("dense/mlp/b10").unwrap().0,
            Schedule::tuned(2)
        );
    }

    #[test]
    fn lookup_falls_back_to_nearest_batch() {
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 10), Schedule::tuned(2), 0.5);
        r.insert(TuningRecords::key("dense", "mlp", 100), Schedule::tuned(4), 3.0);
        let s = r.lookup("dense", "mlp", 16, Schedule::baseline());
        assert_eq!(s, Schedule::tuned(2));
        let s = r.lookup("dense", "mlp", 90, Schedule::baseline());
        assert_eq!(s, Schedule::tuned(4));
        let s = r.lookup("dense", "lenet", 10, Schedule::baseline());
        assert_eq!(s, Schedule::baseline());
    }

    #[test]
    fn layer_keys_do_not_pollute_class_lookup() {
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 10), Schedule::tuned(2), 0.5);
        r.insert(
            TuningRecords::layer_key("dense", "mlp", 0, 10),
            Schedule::tuned(4),
            0.2,
        );
        // class lookup must not parse the L0 record's key
        assert_eq!(r.lookup("dense", "mlp", 64, Schedule::baseline()), Schedule::tuned(2));
        // exact layer hit
        assert_eq!(
            r.lookup_layer("dense", "mlp", 0, 10, Schedule::baseline()),
            Schedule::tuned(4)
        );
        // nearest batch for the same layer
        assert_eq!(
            r.lookup_layer("dense", "mlp", 0, 64, Schedule::baseline()),
            Schedule::tuned(4)
        );
        // unknown layer falls back to the class record
        assert_eq!(
            r.lookup_layer("dense", "mlp", 2, 10, Schedule::baseline()),
            Schedule::tuned(2)
        );
        // unknown op/arch falls back to the default
        assert_eq!(
            r.lookup_layer("conv", "lenet", 0, 10, Schedule::baseline()),
            Schedule::baseline()
        );
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("pfp_tuning_test");
        let path = dir.join("records.json");
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 1), Schedule::tuned(1), 0.1);
        r.save(&path).unwrap();
        let back = TuningRecords::load(&path).unwrap();
        assert_eq!(back.records.len(), 1);
        std::fs::remove_dir_all(dir).ok();
    }
}
