//! Tuning records: persisted best schedules per (operator, arch, batch)
//! so serving and benches reuse tuning results without re-searching —
//! the analog of TVM's tuning logs.
//!
//! Records carry a schema [`version`](SCHEMA_VERSION): a records file
//! tuned against a different code revision (different measurement
//! harness, schedule semantics, or executor) binds schedules that no
//! longer describe what runs, so a version mismatch is **warned about and
//! ignored** instead of silently loaded.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::ops::Schedule;
use crate::util::json::Json;

/// Current tuning-record schema version, stamped into every saved file as
/// the reserved `__version__` key. Bump whenever what a record *means*
/// changes. History:
///
/// * (unversioned) — PR 3: schedules measured on the Tensor-level
///   interpreted operator API.
/// * 2 — PR 4: schedules measured against the planned tile executor
///   (row-partition gang dispatch, `threads` = plan-time tile count).
/// * 3 — PR 5: schedules carry the explicit-SIMD `isa` knob and were
///   measured on the ISA they name; v2 records (no `isa` field — tuned
///   against scalar-only kernels on a different search space) are
///   ignored so a stale scalar best can't silently outrank the SIMD
///   microkernels.
/// * 4 — PR 8: schedules carry the fused-epilogue `fuse` knob and were
///   measured with the epilogue the plan would fuse into the layer; v3
///   records (no `fuse` field — measured on the bare kernel only) would
///   silently bind `fuse: off` against a fused-capable plan, so they are
///   ignored the same way v2 was at the `isa` bump.
/// * 5 — PR 9: schedules carry the mixed-precision `precision` knob and
///   were measured on the storage format they name (packed weights +
///   activation narrow/widen traffic included); v4 records (no
///   `precision` field — measured on f32 storage against a 6-knob search
///   space) would silently bind `precision: f32` as if the search had
///   rejected the packed formats, so they are ignored the same way v3
///   was at the `fuse` bump.
pub const SCHEMA_VERSION: u64 = 5;

/// Key -> (schedule, measured median ms).
#[derive(Clone, Debug)]
pub struct TuningRecords {
    /// Schema version these records were produced under.
    pub version: u64,
    pub records: BTreeMap<String, (Schedule, f64)>,
}

impl Default for TuningRecords {
    fn default() -> Self {
        Self { version: SCHEMA_VERSION, records: BTreeMap::new() }
    }
}

impl TuningRecords {
    pub fn key(op: &str, arch: &str, batch: usize) -> String {
        format!("{op}/{arch}/b{batch}")
    }

    /// Key for one compute layer's workload (the per-layer schedule
    /// table): `dense/mlp/L2/b10`. Layer keys never collide with class
    /// keys — [`lookup`](Self::lookup)'s `b`-prefix parse rejects the
    /// `L<i>/` segment.
    pub fn layer_key(op: &str, arch: &str, layer: usize, batch: usize) -> String {
        format!("{op}/{arch}/L{layer}/b{batch}")
    }

    pub fn insert(&mut self, key: String, sched: Schedule, ms: f64) {
        self.records.insert(key, (sched, ms));
    }

    pub fn get(&self, key: &str) -> Option<&(Schedule, f64)> {
        self.records.get(key)
    }

    /// Best schedule for (op, arch, batch), falling back to the nearest
    /// recorded batch for that op/arch, then to `default`.
    pub fn lookup(&self, op: &str, arch: &str, batch: usize, default: Schedule) -> Schedule {
        if let Some((s, _)) = self.get(&Self::key(op, arch, batch)) {
            return *s;
        }
        let prefix = format!("{op}/{arch}/b");
        let mut best: Option<(usize, Schedule)> = None;
        for (k, (s, _)) in &self.records {
            if let Some(b) = k.strip_prefix(&prefix).and_then(|v| v.parse::<usize>().ok()) {
                let dist = b.abs_diff(batch);
                if best.map_or(true, |(d, _)| dist < d) {
                    best = Some((dist, *s));
                }
            }
        }
        best.map(|(_, s)| s).unwrap_or(default)
    }

    /// Best schedule for compute layer `layer` of (op, arch) at `batch`:
    /// exact layer key, else nearest recorded batch for that layer, else
    /// the class-level [`lookup`](Self::lookup), else `default`.
    pub fn lookup_layer(
        &self,
        op: &str,
        arch: &str,
        layer: usize,
        batch: usize,
        default: Schedule,
    ) -> Schedule {
        if let Some((s, _)) = self.get(&Self::layer_key(op, arch, layer, batch)) {
            return *s;
        }
        let prefix = format!("{op}/{arch}/L{layer}/b");
        let mut best: Option<(usize, Schedule)> = None;
        for (k, (s, _)) in &self.records {
            if let Some(b) = k.strip_prefix(&prefix).and_then(|v| v.parse::<usize>().ok()) {
                let dist = b.abs_diff(batch);
                if best.map_or(true, |(d, _)| dist < d) {
                    best = Some((dist, *s));
                }
            }
        }
        best.map(|(_, s)| s)
            .unwrap_or_else(|| self.lookup(op, arch, batch, default))
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("__version__".to_string(), Json::Num(self.version as f64));
        for (k, (s, ms)) in &self.records {
            obj.insert(
                k.clone(),
                Json::obj(vec![
                    ("schedule", s.to_json()),
                    ("median_ms", Json::Num(*ms)),
                ]),
            );
        }
        Json::Obj(obj)
    }

    /// Parse records. A file whose `__version__` is missing (pre-version
    /// era) or differs from [`SCHEMA_VERSION`] was tuned against a
    /// different code revision: it is ignored with a warning — the caller
    /// gets an empty table and falls back to the built-in schedules — not
    /// silently bound.
    pub fn from_json(v: &Json) -> Result<Self> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Json("tuning records must be an object".into()))?;
        let version = obj
            .get("__version__")
            .and_then(|n| n.as_f64())
            .map(|n| n as u64)
            .unwrap_or(0);
        if version != SCHEMA_VERSION {
            eprintln!(
                "warning: ignoring tuning records with schema version {version} \
                 (current {SCHEMA_VERSION}); re-run `pfp tune` to refresh them"
            );
            return Ok(Self::default());
        }
        let mut records = BTreeMap::new();
        for (k, entry) in obj {
            if k == "__version__" {
                continue;
            }
            let sched = Schedule::from_json(
                entry
                    .get("schedule")
                    .ok_or_else(|| Error::Json("record missing schedule".into()))?,
            )?;
            let ms = entry.num_field("median_ms")?;
            records.insert(k.clone(), (sched, ms));
        }
        Ok(Self { version, records })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Load if present, else empty.
    pub fn load_or_default(path: &Path) -> Self {
        Self::load(path).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_json() {
        let mut r = TuningRecords::default();
        r.insert(
            TuningRecords::key("dense", "mlp", 10),
            Schedule::tuned(2),
            0.5,
        );
        r.insert(
            TuningRecords::key("conv", "lenet", 1),
            Schedule::tiled(16, 64),
            1.25,
        );
        let j = r.to_json();
        let back = TuningRecords::from_json(&j).unwrap();
        assert_eq!(back.records.len(), 2);
        assert_eq!(
            back.get("dense/mlp/b10").unwrap().0,
            Schedule::tuned(2)
        );
    }

    #[test]
    fn lookup_falls_back_to_nearest_batch() {
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 10), Schedule::tuned(2), 0.5);
        r.insert(TuningRecords::key("dense", "mlp", 100), Schedule::tuned(4), 3.0);
        let s = r.lookup("dense", "mlp", 16, Schedule::baseline());
        assert_eq!(s, Schedule::tuned(2));
        let s = r.lookup("dense", "mlp", 90, Schedule::baseline());
        assert_eq!(s, Schedule::tuned(4));
        let s = r.lookup("dense", "lenet", 10, Schedule::baseline());
        assert_eq!(s, Schedule::baseline());
    }

    #[test]
    fn layer_keys_do_not_pollute_class_lookup() {
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 10), Schedule::tuned(2), 0.5);
        r.insert(
            TuningRecords::layer_key("dense", "mlp", 0, 10),
            Schedule::tuned(4),
            0.2,
        );
        // class lookup must not parse the L0 record's key
        assert_eq!(r.lookup("dense", "mlp", 64, Schedule::baseline()), Schedule::tuned(2));
        // exact layer hit
        assert_eq!(
            r.lookup_layer("dense", "mlp", 0, 10, Schedule::baseline()),
            Schedule::tuned(4)
        );
        // nearest batch for the same layer
        assert_eq!(
            r.lookup_layer("dense", "mlp", 0, 64, Schedule::baseline()),
            Schedule::tuned(4)
        );
        // unknown layer falls back to the class record
        assert_eq!(
            r.lookup_layer("dense", "mlp", 2, 10, Schedule::baseline()),
            Schedule::tuned(2)
        );
        // unknown op/arch falls back to the default
        assert_eq!(
            r.lookup_layer("conv", "lenet", 0, 10, Schedule::baseline()),
            Schedule::baseline()
        );
    }

    #[test]
    fn version_mismatch_is_warned_and_ignored() {
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 10), Schedule::tuned(2), 0.5);
        // tamper: pretend these were tuned under a future/old revision
        let mut j = r.to_json();
        if let Json::Obj(obj) = &mut j {
            obj.insert("__version__".into(), Json::Num((SCHEMA_VERSION + 1) as f64));
        }
        let back = TuningRecords::from_json(&j).unwrap();
        assert!(back.records.is_empty(), "stale records must not bind");
        assert_eq!(back.version, SCHEMA_VERSION, "fallback is a current empty table");
        // lookups on the ignored table fall back to the default schedule
        assert_eq!(
            back.lookup("dense", "mlp", 10, Schedule::baseline()),
            Schedule::baseline()
        );
    }

    #[test]
    fn v3_records_without_fuse_field_are_ignored() {
        // a PR-5-era (v3) file: has the `isa` knob but predates the
        // fused-epilogue dimension. Binding it would silently default
        // every layer to `fuse: off` against a fused-capable plan, so it
        // must be warned about and dropped, not loaded.
        let text = r#"{"__version__":3,
            "dense/mlp/b10":{"schedule":{"loop_order":"Mnk",
            "tile_n":0,"tile_k":0,"unroll":8,"vectorize":true,"threads":2,
            "isa":"native"},
            "median_ms":0.5}}"#;
        let back = TuningRecords::from_json(&Json::parse(text).unwrap()).unwrap();
        assert!(back.records.is_empty(), "v3 records must not bind");
        assert_eq!(back.version, SCHEMA_VERSION);
        assert_eq!(
            back.lookup("dense", "mlp", 10, Schedule::baseline()),
            Schedule::baseline()
        );
    }

    #[test]
    fn v4_records_without_precision_field_are_ignored() {
        // a PR-8-era (v4) file: has the `fuse` knob but predates the
        // mixed-precision dimension. Binding it would silently pin every
        // layer to f32 storage as if the tuner had searched the packed
        // formats and rejected them, so it must be warned about and
        // dropped, not loaded.
        let text = r#"{"__version__":4,
            "dense/mlp/b10":{"schedule":{"loop_order":"Mnk",
            "tile_n":0,"tile_k":0,"unroll":8,"vectorize":true,"threads":2,
            "isa":"native","fuse":true},
            "median_ms":0.5}}"#;
        let back = TuningRecords::from_json(&Json::parse(text).unwrap()).unwrap();
        assert!(back.records.is_empty(), "v4 records must not bind");
        assert_eq!(back.version, SCHEMA_VERSION);
        assert_eq!(
            back.lookup("dense", "mlp", 10, Schedule::baseline()),
            Schedule::baseline()
        );
    }

    #[test]
    fn unversioned_records_are_ignored() {
        // a PR-3-era file has no __version__ at all: same treatment
        let text = r#"{"dense/mlp/b10":{"schedule":{"loop_order":"Mnk",
            "tile_n":0,"tile_k":0,"unroll":8,"vectorize":true,"threads":1},
            "median_ms":0.5}}"#;
        let back = TuningRecords::from_json(&Json::parse(text).unwrap()).unwrap();
        assert!(back.records.is_empty());
    }

    #[test]
    fn current_version_roundtrips_through_disk_format() {
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 10), Schedule::tuned(2), 0.5);
        let back = TuningRecords::from_json(&r.to_json()).unwrap();
        assert_eq!(back.version, SCHEMA_VERSION);
        assert_eq!(back.records.len(), 1, "__version__ is not a record");
        assert!(back.get("__version__").is_none());
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("pfp_tuning_test");
        let path = dir.join("records.json");
        let mut r = TuningRecords::default();
        r.insert(TuningRecords::key("dense", "mlp", 1), Schedule::tuned(1), 0.1);
        r.save(&path).unwrap();
        let back = TuningRecords::load(&path).unwrap();
        assert_eq!(back.records.len(), 1);
        std::fs::remove_dir_all(dir).ok();
    }
}
