//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the serving hot path.
//!
//! The actual engine depends on the `xla` crate (PJRT CPU client), which
//! is heavyweight and not part of the offline crate set — it is gated
//! behind the **`xla-runtime`** cargo feature. Without the feature an
//! API-compatible [stub](stub) is compiled instead: manifest handling and
//! all native-operator paths work, and any attempt to construct the
//! engine reports how to enable the real one.

pub mod manifest;

pub use manifest::{Manifest, ManifestEntry};

#[cfg(feature = "xla-runtime")]
mod pjrt;
#[cfg(feature = "xla-runtime")]
pub use pjrt::{Engine, LoadedModel};

#[cfg(not(feature = "xla-runtime"))]
mod stub;
#[cfg(not(feature = "xla-runtime"))]
pub use stub::{Engine, LoadedModel};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming() {
        assert_eq!(
            Engine::artifact_name("mlp", "pfp", 10),
            "model_mlp_pfp_b10"
        );
    }
}
