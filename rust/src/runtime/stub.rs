//! Stub engine used when the crate is built **without** the
//! `xla-runtime` feature (the default — the `xla` crate and its PJRT
//! plugin are not in the offline crate set).
//!
//! API-compatible with the real engine in `pjrt.rs` so the coordinator,
//! benches and CLI compile unchanged; constructing an [`Engine`] fails at
//! runtime with a clear pointer at the feature flag. Manifest parsing and
//! every native-operator path are fully functional without the feature.

use std::path::Path;

use super::manifest::{Manifest, ManifestEntry};
use crate::error::{Error, Result};
use crate::model::PosteriorWeights;
use crate::tensor::Tensor;
use std::sync::Arc;

const UNAVAILABLE: &str =
    "XLA/PJRT runtime not built: rebuild with `--features xla-runtime` \
     (requires the `xla` crate and its xla_extension plugin)";

/// Placeholder for a compiled model artifact; never constructible without
/// the `xla-runtime` feature.
pub struct LoadedModel {
    pub entry: ManifestEntry,
    // Prevents construction from outside this module.
    _private: (),
}

impl LoadedModel {
    pub fn execute(&self, _input: &Tensor) -> Result<Vec<Tensor>> {
        Err(Error::Runtime(UNAVAILABLE.into()))
    }

    pub fn batch(&self) -> usize {
        self.entry.batch
    }

    pub fn execute_with_weights(
        &self,
        _input: &Tensor,
        _weights: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        Err(Error::Runtime(UNAVAILABLE.into()))
    }
}

/// Placeholder engine: `new` always fails (after validating the manifest,
/// so configuration errors still surface first).
pub struct Engine {
    pub manifest: Manifest,
    _private: (),
}

impl Engine {
    pub fn new(artifacts: &Path) -> Result<Self> {
        // parse the manifest anyway: a missing/broken manifest is the more
        // actionable error, and callers probe it before loading models
        let _manifest = Manifest::load(&artifacts.join("manifest.json"))?;
        Err(Error::Runtime(UNAVAILABLE.into()))
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load(&self, _name: &str, _weights: &PosteriorWeights) -> Result<Arc<LoadedModel>> {
        Err(Error::Runtime(UNAVAILABLE.into()))
    }

    /// Artifact name for (arch, variant, batch).
    pub fn artifact_name(arch: &str, variant: &str, batch: usize) -> String {
        format!("model_{arch}_{variant}_b{batch}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_errors_with_feature_hint() {
        let dir = std::env::temp_dir().join("pfp-stub-engine-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries": [], "metrics": {}}"#,
        )
        .unwrap();
        let err = Engine::new(&dir).unwrap_err();
        assert!(err.to_string().contains("xla-runtime"), "{err}");
    }
}
