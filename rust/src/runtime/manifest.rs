//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime — which HLO file implements which (arch, variant, batch),
//! and the exact parameter order/shapes its entry computation expects.

use std::path::Path;

use crate::error::{Error, Result};
use crate::model::PosteriorWeights;
use crate::tensor::Tensor;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub arch: String,
    /// "pfp" | "pfp_pallas" | "det"
    pub variant: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<String>,
    pub calibration_factor: Option<f32>,
}

impl ManifestEntry {
    pub fn is_pfp(&self) -> bool {
        self.variant.starts_with("pfp")
    }

    /// Materialise the weight tensors in parameter order from the
    /// posterior store. PFP entries take (w_mu, w_var, b_mu, b_var) per
    /// compute layer (variance already calibrated by the store); det
    /// entries take (w_mu, b_mu).
    pub fn weight_tensors(&self, weights: &PosteriorWeights) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(self.params.len());
        for layer in &weights.layers {
            if self.is_pfp() {
                out.push(layer.w_mu.clone());
                out.push(layer.w_var.clone());
                out.push(layer.b_mu.clone());
                out.push(layer.b_var.clone());
            } else {
                out.push(layer.w_mu.clone());
                out.push(layer.b_mu.clone());
            }
        }
        if out.len() != self.params.len() {
            return Err(Error::Manifest(format!(
                "{}: weight store provides {} tensors, manifest wants {}",
                self.name,
                out.len(),
                self.params.len()
            )));
        }
        Ok(out)
    }

    /// Materialise *sampled* weights for the SVI path (det-variant entry):
    /// (w, b) per layer from a caller-provided sampler.
    pub fn sampled_tensors(
        &self,
        weights: &PosteriorWeights,
        rng: &mut crate::util::rng::SplitMix64,
    ) -> Vec<Tensor> {
        use crate::ops::svi::sample_tensor;
        let mut out = Vec::new();
        for layer in &weights.layers {
            out.push(sample_tensor(&layer.w_mu, &layer.w_sigma, rng));
            out.push(sample_tensor(&layer.b_mu, &layer.b_sigma, rng));
        }
        out
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
    /// Table-1 metrics as recorded by the python pipeline.
    pub metrics: Json,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Manifest(format!("read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut entries = Vec::new();
        for e in v.arr_field("entries")? {
            let params = e
                .arr_field("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.str_field("name")?.to_string(),
                        shape: p
                            .get("shape")
                            .ok_or_else(|| Error::Manifest("param missing shape".into()))?
                            .to_usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry {
                name: e.str_field("name")?.to_string(),
                file: e.str_field("file")?.to_string(),
                arch: e.str_field("arch")?.to_string(),
                variant: e.str_field("variant")?.to_string(),
                batch: e.num_field("batch")? as usize,
                input_shape: e
                    .get("input_shape")
                    .ok_or_else(|| Error::Manifest("missing input_shape".into()))?
                    .to_usize_vec()?,
                params,
                outputs: e
                    .arr_field("outputs")?
                    .iter()
                    .map(|o| o.as_str().unwrap_or("").to_string())
                    .collect(),
                calibration_factor: e
                    .get("calibration_factor")
                    .and_then(Json::as_f64)
                    .map(|c| c as f32),
            });
        }
        let metrics = v.get("metrics").cloned().unwrap_or(Json::Null);
        Ok(Self { entries, metrics })
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entries for (arch, variant), sorted by batch.
    pub fn entries_for(&self, arch: &str, variant: &str) -> Vec<&ManifestEntry> {
        let mut v: Vec<&ManifestEntry> = self
            .entries
            .iter()
            .filter(|e| e.arch == arch && e.variant == variant)
            .collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Calibration factor recorded for an arch (from the training sweep).
    pub fn calibration_factor(&self, arch: &str) -> f32 {
        self.metrics
            .get(arch)
            .and_then(|m| m.get("pfp_calibration_factor"))
            .and_then(Json::as_f64)
            .map(|c| c as f32)
            .unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "model_mlp_pfp_b1", "file": "model_mlp_pfp_b1.hlo.txt",
         "arch": "mlp", "variant": "pfp", "batch": 1,
         "input_shape": [1, 784],
         "params": [{"name": "l0_w_mu", "shape": [100, 784]}],
         "outputs": ["mu", "var"], "calibration_factor": 0.3},
        {"name": "model_mlp_det_b10", "file": "model_mlp_det_b10.hlo.txt",
         "arch": "mlp", "variant": "det", "batch": 10,
         "input_shape": [10, 784],
         "params": [{"name": "l0_w", "shape": [100, 784]}],
         "outputs": ["logits"], "calibration_factor": null}
      ],
      "metrics": {"mlp": {"pfp_calibration_factor": 0.3}}
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("model_mlp_pfp_b1").unwrap();
        assert!(e.is_pfp());
        assert_eq!(e.params[0].shape, vec![100, 784]);
        assert_eq!(e.outputs, vec!["mu", "var"]);
        assert_eq!(e.calibration_factor, Some(0.3));
        let d = m.entry("model_mlp_det_b10").unwrap();
        assert!(!d.is_pfp());
        assert_eq!(d.calibration_factor, None);
    }

    #[test]
    fn entries_for_sorted() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries_for("mlp", "pfp").len(), 1);
        assert_eq!(m.entries_for("mlp", "svi").len(), 0);
        assert!((m.calibration_factor("mlp") - 0.3).abs() < 1e-6);
        assert!((m.calibration_factor("unknown") - 1.0).abs() < 1e-6);
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = crate::artifacts_dir();
        let p = dir.join("manifest.json");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&p).unwrap();
        assert!(m.entries.len() >= 12);
        assert!(m.entry("model_mlp_pfp_b10").is_some());
        assert!(m.entry("model_lenet_det_b100").is_some());
    }
}
