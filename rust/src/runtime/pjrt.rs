//! Real PJRT engine (`xla-runtime` feature): load AOT HLO-text artifacts
//! and execute them through the `xla` crate's CPU client.
//!
//! HLO *text* is the interchange format — jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! Weight tensors are transferred to device once per loaded model
//! (`execute_b` over cached `PjRtBuffer`s); only the input tensor is
//! transferred per call.

use super::manifest::{Manifest, ManifestEntry};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::model::PosteriorWeights;
use crate::tensor::Tensor;

/// A compiled model artifact with device-resident weights.
pub struct LoadedModel {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
    weight_buffers: Vec<xla::PjRtBuffer>,
    client: Arc<xla::PjRtClient>,
}

// SAFETY: the PJRT CPU client/executable handles are raw pointers behind
// Rc in the `xla` crate, but the CPU plugin itself is thread-safe for
// execution; the coordinator gives each model to exactly one worker
// thread and the cache is Mutex-guarded, so the Rc refcounts are never
// touched concurrently — ownership moves whole between threads.
unsafe impl Send for LoadedModel {}
// SAFETY: cross-thread *sharing* only happens through `&self` execute
// calls, which the CPU PJRT client explicitly supports (no interior
// mutation of the handles outside the plugin's own synchronization).
unsafe impl Sync for LoadedModel {}

impl LoadedModel {
    /// Execute on a batch: input `[B, ...]` (flattened) -> output tensors
    /// in the entry's declared order (`mu`,`var` for PFP; `logits` for det).
    pub fn execute(&self, input: &Tensor) -> Result<Vec<Tensor>> {
        let input_buf = self
            .client
            .buffer_from_host_buffer(input.data(), &self.entry.input_shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&input_buf];
        args.extend(self.weight_buffers.iter());
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != self.entry.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} outputs, got {}",
                self.entry.name,
                self.entry.outputs.len(),
                parts.len()
            )));
        }
        let batch = self.entry.batch;
        parts
            .into_iter()
            .map(|p| {
                let v = p.to_vec::<f32>()?;
                let cols = v.len() / batch;
                Tensor::new(vec![batch, cols], v)
            })
            .collect()
    }

    pub fn batch(&self) -> usize {
        self.entry.batch
    }

    /// Execute with explicit weight tensors instead of the cached device
    /// buffers — the SVI-on-XLA path: each posterior sample re-transfers
    /// its sampled weights (that transfer is part of the paper's measured
    /// per-sample cost).
    pub fn execute_with_weights(
        &self,
        input: &Tensor,
        weights: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        if weights.len() != self.entry.params.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} weight tensors, got {}",
                self.entry.name,
                self.entry.params.len(),
                weights.len()
            )));
        }
        let input_buf = self
            .client
            .buffer_from_host_buffer(input.data(), &self.entry.input_shape, None)?;
        let mut bufs = Vec::with_capacity(weights.len());
        for (param, t) in self.entry.params.iter().zip(weights) {
            bufs.push(
                self.client
                    .buffer_from_host_buffer(t.data(), &param.shape, None)?,
            );
        }
        let mut args: Vec<&xla::PjRtBuffer> = vec![&input_buf];
        args.extend(bufs.iter());
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let batch = self.entry.batch;
        parts
            .into_iter()
            .map(|p| {
                let v = p.to_vec::<f32>()?;
                let cols = v.len() / batch;
                Tensor::new(vec![batch, cols], v)
            })
            .collect()
    }
}

/// The PJRT engine: one CPU client + a cache of compiled executables.
pub struct Engine {
    client: Arc<xla::PjRtClient>,
    artifacts: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<LoadedModel>>>,
}

// SAFETY: see `LoadedModel` — CPU PJRT handles move whole between
// threads; the executable cache is Mutex-guarded.
unsafe impl Send for Engine {}
// SAFETY: shared access is `&self` execution plus the Mutex'd cache;
// the CPU PJRT client supports concurrent execute calls.
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(artifacts: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts.join("manifest.json"))?;
        let client = Arc::new(xla::PjRtClient::cpu()?);
        Ok(Self {
            client,
            artifacts: artifacts.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile + bind weights) an artifact by manifest name, with
    /// caching. Weight tensors come from the posterior store in the
    /// manifest-declared parameter order.
    pub fn load(&self, name: &str, weights: &PosteriorWeights) -> Result<Arc<LoadedModel>> {
        if let Some(m) = self.cache.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        let entry = self
            .manifest
            .entry(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact named '{name}'")))?
            .clone();
        let path = self.artifacts.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        let tensors = entry.weight_tensors(weights)?;
        let mut weight_buffers = Vec::with_capacity(tensors.len());
        for (param, t) in entry.params.iter().zip(&tensors) {
            if t.len() != param.shape.iter().product::<usize>() {
                return Err(Error::Manifest(format!(
                    "{}: param {} expects shape {:?}, weights give {} elements",
                    entry.name,
                    param.name,
                    param.shape,
                    t.len()
                )));
            }
            weight_buffers.push(self.client.buffer_from_host_buffer(
                t.data(),
                &param.shape,
                None,
            )?);
        }
        let model = Arc::new(LoadedModel {
            entry,
            exe,
            weight_buffers,
            client: self.client.clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), model.clone());
        Ok(model)
    }

    /// Artifact name for (arch, variant, batch).
    pub fn artifact_name(arch: &str, variant: &str, batch: usize) -> String {
        format!("model_{arch}_{variant}_b{batch}")
    }
}
