//! Instrumented stand-ins for the `std::sync` primitives.
//!
//! Model states must be cloneable and hashable, so the shims are plain
//! value types manipulated by [`Model::step`](crate::verify::Model::step)
//! handlers rather than RAII guards. The semantics mirror what the real
//! primitives guarantee:
//!
//! * [`MockMutex`] — ownership tracking. A thread whose next action needs
//!   the mutex is *disabled* (not merely spinning) while another thread
//!   holds it, exactly like a parked `std::sync::Mutex` acquirer.
//! * [`MockCondvar`] — a wait set plus wakeup grants scoped to the
//!   threads that were **waiting at notify time**. `notify_all` moves the
//!   whole current wait set into a woken set; `notify_one` records a
//!   token eligible to any one of the current waiters (which one wakes is
//!   left to the scheduler search, mirroring the real nondeterminism). A
//!   thread that starts waiting *after* a notify can never consume that
//!   notify — real condvars wake threads already in the wait queue, and
//!   an earlier (counter-based) version of this shim wrongly let a late
//!   waiter steal a `notify_all` grant, deadlocking sound protocols. A
//!   missed notify is observable as a permanently disabled thread (a
//!   lost wakeup, reported by the checker as a deadlock). Spurious
//!   wakeups are *not* modeled: the real code wraps every wait in a
//!   re-check loop, so a spurious wake only adds equivalent schedules.
//! * [`MockAtomic`] — a bare integer cell. Each model step is already
//!   atomic, so the value type only documents intent (which shared cells
//!   are lock-free in the real code) and centralizes the RMW helpers.
//!
//! The `wait` half of `Condvar::wait` is split the way loom splits it:
//! `wait()` atomically releases the mutex and joins the wait set (one
//! step); waking takes the grant (a second step); the woken thread then
//! re-acquires the mutex and re-checks its predicate (its pc loops back
//! to the acquire state). That is exactly the `while cond { cv.wait() }`
//! idiom used everywhere in `util/threadpool.rs`.

use std::collections::BTreeSet;

/// Ownership-tracking mutex for model states.
#[derive(Clone, PartialEq, Eq, Hash, Debug, Default)]
pub struct MockMutex {
    held_by: Option<usize>,
}

impl MockMutex {
    /// Is the mutex free (an acquirer would be enabled)?
    pub fn is_free(&self) -> bool {
        self.held_by.is_none()
    }

    /// Current owner, if any.
    pub fn holder(&self) -> Option<usize> {
        self.held_by
    }

    /// Acquire for `tid`. Callers must only step an acquire when
    /// [`MockMutex::is_free`] (the model's `enabled` gate); acquiring a
    /// held mutex is a model bug, not an explored behavior.
    pub fn acquire(&mut self, tid: usize) {
        assert!(self.held_by.is_none(), "acquire of a held MockMutex");
        self.held_by = Some(tid);
    }

    /// Release; panics if `tid` is not the owner (a model bug).
    pub fn release(&mut self, tid: usize) {
        assert_eq!(self.held_by, Some(tid), "release by non-owner");
        self.held_by = None;
    }
}

/// Wait-set condition variable for model states, with wakeup grants
/// scoped to the threads that were waiting when the notify happened.
#[derive(Clone, PartialEq, Eq, Hash, Debug, Default)]
pub struct MockCondvar {
    /// Threads parked in `wait` with no grant yet.
    waiters: BTreeSet<usize>,
    /// Threads released by a `notify_all` but not yet scheduled.
    woken: BTreeSet<usize>,
    /// One entry per pending `notify_one`: the wait set snapshotted at
    /// notify time. Any one member may consume the token — the scheduler
    /// explores every choice, mirroring the real "which waiter wakes"
    /// nondeterminism. A token whose members all leave the wait set by
    /// other means is dropped (the notify is absorbed, as in pthreads).
    tokens: Vec<BTreeSet<usize>>,
}

impl MockCondvar {
    /// Atomically release `m` and join the wait set (the blocking half of
    /// `Condvar::wait`). The caller's pc must transition to a "waiting"
    /// state whose only exit is [`MockCondvar::wake`].
    pub fn wait(&mut self, m: &mut MockMutex, tid: usize) {
        m.release(tid);
        assert!(
            !self.woken.contains(&tid),
            "thread {tid} waited again before taking its wakeup"
        );
        let fresh = self.waiters.insert(tid);
        assert!(fresh, "thread {tid} waited twice without waking");
    }

    /// Grant one wakeup to some current waiter (`Condvar::notify_one`).
    /// A no-op when nobody is waiting — that notify is *lost*, exactly
    /// the real-condvar behavior the checker exists to catch.
    pub fn notify_one(&mut self) {
        if !self.waiters.is_empty() {
            self.tokens.push(self.waiters.clone());
        }
    }

    /// Wake every **current** waiter (`Condvar::notify_all`). Threads
    /// that wait after this call are not covered by it.
    pub fn notify_all(&mut self) {
        self.woken.append(&mut self.waiters);
        // every token's eligible set was ⊆ the old wait set, which is now
        // wholly woken — those notify_ones are absorbed.
        self.tokens.clear();
    }

    /// Scheduler gate: may `tid` leave the wait set this step?
    pub fn can_wake(&self, tid: usize) -> bool {
        self.woken.contains(&tid) || self.tokens.iter().any(|t| t.contains(&tid))
    }

    /// Take the wakeup and leave the wait set. The caller's next action
    /// is re-acquiring the mutex (its pc loops to the acquire state,
    /// re-checking the wait predicate under the lock).
    pub fn wake(&mut self, tid: usize) {
        assert!(self.can_wake(tid), "wake without a grant");
        self.waiters.remove(&tid);
        if !self.woken.remove(&tid) {
            let i = self
                .tokens
                .iter()
                .position(|t| t.contains(&tid))
                .expect("can_wake implies a token");
            self.tokens.remove(i);
        }
        // `tid` left the wait set: it can no longer be the target of any
        // other pending notify_one.
        self.tokens.retain_mut(|t| {
            t.remove(&tid);
            !t.is_empty()
        });
    }

    /// Is `tid` parked in the wait (granted a wakeup or not)?
    pub fn is_waiting(&self, tid: usize) -> bool {
        self.waiters.contains(&tid) || self.woken.contains(&tid)
    }
}

/// Lock-free integer cell. Steps are atomic by construction; the type
/// marks which shared state is atomics (not mutex-protected) in the real
/// code and provides the RMW shapes the pool uses.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub struct MockAtomic(pub u64);

impl MockAtomic {
    pub fn load(&self) -> u64 {
        self.0
    }

    pub fn store(&mut self, v: u64) {
        self.0 = v;
    }

    pub fn fetch_add(&mut self, v: u64) -> u64 {
        let old = self.0;
        self.0 += v;
        old
    }

    pub fn fetch_sub(&mut self, v: u64) -> u64 {
        let old = self.0;
        self.0 -= v;
        old
    }

    /// `compare_exchange(current, new)` → `Ok(current)` / `Err(actual)`.
    pub fn compare_exchange(&mut self, current: u64, new: u64) -> Result<u64, u64> {
        if self.0 == current {
            self.0 = new;
            Ok(current)
        } else {
            Err(self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_tracks_ownership() {
        let mut m = MockMutex::default();
        assert!(m.is_free());
        m.acquire(1);
        assert!(!m.is_free());
        assert_eq!(m.holder(), Some(1));
        m.release(1);
        assert!(m.is_free());
    }

    #[test]
    #[should_panic(expected = "release by non-owner")]
    fn mutex_release_by_non_owner_is_a_model_bug() {
        let mut m = MockMutex::default();
        m.acquire(0);
        m.release(1);
    }

    #[test]
    fn condvar_grant_semantics() {
        let mut m = MockMutex::default();
        let mut cv = MockCondvar::default();
        // notify with no waiters is a no-op (real condvar semantics)
        cv.notify_one();
        assert_eq!(cv, MockCondvar::default());

        m.acquire(0);
        cv.wait(&mut m, 0);
        assert!(m.is_free(), "wait releases the mutex");
        assert!(cv.is_waiting(0));
        assert!(!cv.can_wake(0), "no grant yet: a lost wakeup blocks forever");

        cv.notify_one();
        assert!(cv.can_wake(0));
        cv.wake(0);
        assert!(!cv.is_waiting(0));
        assert!(!cv.can_wake(0));
    }

    #[test]
    fn notify_all_covers_every_current_waiter() {
        let mut m = MockMutex::default();
        let mut cv = MockCondvar::default();
        for tid in 0..3 {
            m.acquire(tid);
            cv.wait(&mut m, tid);
        }
        // notify_one twice ≠ notify_all for 3 waiters: any of the three
        // may take either token, but only two in total can wake.
        cv.notify_one();
        cv.notify_one();
        assert!((0..3).filter(|&t| cv.can_wake(t)).count() == 3, "tokens are shared");
        cv.wake(0);
        cv.wake(1);
        assert!(!cv.can_wake(2), "only two wakeups were granted");
        cv.notify_all();
        assert!(cv.can_wake(2));
        cv.wake(2);
    }

    #[test]
    fn late_waiter_cannot_steal_an_earlier_notify_all() {
        // Regression: a counter-based budget let a thread that waited
        // *after* notify_all consume the grant meant for an existing
        // waiter, making sound protocols (competing run_tasks leaders
        // sharing one sync condvar) look like deadlocks.
        let mut m = MockMutex::default();
        let mut cv = MockCondvar::default();
        m.acquire(0);
        cv.wait(&mut m, 0);
        cv.notify_all();
        m.acquire(1);
        cv.wait(&mut m, 1); // waits after the notify
        assert!(cv.can_wake(0), "the thread waiting at notify time keeps its grant");
        assert!(!cv.can_wake(1), "the late waiter is not covered");
        cv.wake(0);
        assert!(!cv.can_wake(1));
    }

    #[test]
    fn notify_one_token_is_absorbed_when_its_waiters_leave() {
        let mut m = MockMutex::default();
        let mut cv = MockCondvar::default();
        m.acquire(0);
        cv.wait(&mut m, 0);
        cv.notify_one(); // token eligible to {0} only
        cv.notify_all(); // 0 leaves via the broadcast instead
        cv.wake(0);
        m.acquire(1);
        cv.wait(&mut m, 1);
        assert!(
            !cv.can_wake(1),
            "the stale notify_one token must not wake a future waiter"
        );
    }

    #[test]
    fn atomic_rmw_helpers() {
        let mut a = MockAtomic::default();
        assert_eq!(a.fetch_add(2), 0);
        assert_eq!(a.load(), 2);
        assert_eq!(a.compare_exchange(2, 5), Ok(2));
        assert_eq!(a.compare_exchange(2, 9), Err(5));
        assert_eq!(a.fetch_sub(1), 5);
        a.store(7);
        assert_eq!(a.load(), 7);
    }
}
