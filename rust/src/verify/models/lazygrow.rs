//! Model of lazy pool growth vs. shutdown (`ThreadPool::submit`,
//! `worker_loop`'s queue path, and `Drop for ThreadPool`).
//!
//! The real pool spawns workers on demand: `submit` pushes the job,
//! bumps `outstanding`, and CAS-loops `spawned` upward until
//! `spawned >= min(outstanding, cap)`. `Drop` sets `shutdown` under the
//! lock, wakes everyone, and joins. The subtle properties:
//!
//! 1. **drain before shutdown** — a worker observing `shutdown == true`
//!    must still drain queued jobs first (the source checks the queue
//!    before the shutdown flag), so every submitted job runs even when
//!    `Drop` races the last submit;
//! 2. **no lost wakeup** — a parked worker is always woken while work
//!    remains ([`LazyGrow::lost_submit_notify_mutant`] drops the
//!    `notify_one` after a push and the checker reports the deadlock);
//! 3. **the grow rule spawns enough workers** — checked as a state
//!    invariant: after every submit completes its grow loop,
//!    `spawned >= min(outstanding, cap)`.
//!
//! Threads: tid 0 is the submitter (submits `jobs` jobs, then drops the
//! pool: shutdown + notify_all + join); tids `1..=cap` are workers that
//! begin unspawned and only become schedulable once the grow loop has
//! spawned them — lazy spawning is scheduling, not magic.

use crate::verify::checker::Model;
use crate::verify::shim::{MockAtomic, MockCondvar, MockMutex};

/// Model configuration. `threads() == 1 + cap`.
#[derive(Debug, Clone, Copy)]
pub struct LazyGrow {
    /// Jobs the submitter pushes before dropping the pool.
    pub jobs: usize,
    /// Worker cap (`ThreadPool::new(cap)` with lazy spawning).
    pub cap: usize,
    /// Seeded bug: `push_job` skips `work_cv.notify_one()`.
    pub lost_submit_notify_mutant: bool,
}

impl LazyGrow {
    pub fn new(jobs: usize, cap: usize) -> Self {
        Self { jobs, cap, lost_submit_notify_mutant: false }
    }

    pub fn with_lost_notify(mut self) -> Self {
        self.lost_submit_notify_mutant = true;
        self
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Pc {
    // submitter
    SPush,     // lock; queue += 1; unlock; notify_one; start grow loop
    SGrow,     // CAS spawned upward toward min(outstanding, cap)
    SAwait,    // latch wait: blocked until outstanding == 0
    SShutdown, // lock; shutdown = true; unlock; notify_all
    SJoin,     // blocked until every spawned worker has exited
    SDone,
    // workers
    WUnspawned, // not yet an OS thread; enabled once spawned covers it
    WLoop,      // lock; pop job / observe shutdown / park
    WRun,       // running a popped job outside the lock
    WParked,    // parked on work_cv
    WDone,      // worker_loop returned (joined by Drop)
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct State {
    m: MockMutex,
    work_cv: MockCondvar,
    queue: usize,
    shutdown: bool,
    /// Lock-free counters, as in the source (`AtomicUsize`).
    spawned: MockAtomic,
    outstanding: MockAtomic,
    /// Jobs fully executed (the drain property's witness).
    executed: usize,
    /// Jobs the submitter has pushed so far.
    pushed: usize,
    pc: Vec<Pc>,
}

impl LazyGrow {
    fn worker_tid(&self, k: usize) -> usize {
        1 + k
    }
}

impl Model for LazyGrow {
    type State = State;

    fn init(&self) -> State {
        let mut pc = vec![if self.jobs == 0 { Pc::SAwait } else { Pc::SPush }];
        pc.extend(std::iter::repeat(Pc::WUnspawned).take(self.cap));
        State {
            m: MockMutex::default(),
            work_cv: MockCondvar::default(),
            queue: 0,
            shutdown: false,
            spawned: MockAtomic::default(),
            outstanding: MockAtomic::default(),
            executed: 0,
            pushed: 0,
            pc,
        }
    }

    fn threads(&self) -> usize {
        1 + self.cap
    }

    fn enabled(&self, s: &State, tid: usize) -> bool {
        match s.pc[tid] {
            Pc::SPush | Pc::SShutdown | Pc::WLoop => s.m.is_free(),
            // the CAS loop and job bodies run without the mutex
            Pc::SGrow | Pc::WRun => true,
            // latch wait (`Scope::wait_all` analog): wakeups on the
            // latch's own condvar are modeled as perfect — this model
            // checks the *pool's* wakeup discipline, not the latch's
            Pc::SAwait => s.outstanding.load() == 0,
            Pc::SJoin => (0..self.cap).all(|k| {
                let w = self.worker_tid(k);
                // join returns once every spawned worker exited;
                // never-spawned workers have no handle to join
                s.pc[w] == Pc::WDone || s.pc[w] == Pc::WUnspawned
            }),
            Pc::WUnspawned => (tid - 1) < s.spawned.load() as usize,
            Pc::WParked => s.work_cv.can_wake(tid),
            Pc::SDone | Pc::WDone => false,
        }
    }

    fn done(&self, s: &State, tid: usize) -> bool {
        match s.pc[tid] {
            Pc::SDone | Pc::WDone => true,
            // a worker the grow rule never needed is fine at exit
            Pc::WUnspawned => (tid - 1) >= s.spawned.load() as usize,
            _ => false,
        }
    }

    fn step(&self, s: &mut State, tid: usize) -> Result<(), String> {
        match s.pc[tid] {
            Pc::SPush => {
                // outstanding.fetch_add precedes the push in the source;
                // both are lock-free / under the lock in one window the
                // grow loop only reads afterwards, so folding them with
                // the push is behavior-preserving for the grow bound.
                s.outstanding.fetch_add(1);
                s.m.acquire(tid);
                s.queue += 1;
                s.pushed += 1;
                s.m.release(tid);
                if !self.lost_submit_notify_mutant {
                    s.work_cv.notify_one();
                }
                s.pc[tid] = Pc::SGrow;
                Ok(())
            }
            Pc::SGrow => {
                // one CAS iteration of the grow loop
                let spawned = s.spawned.load();
                let target = s.outstanding.load().min(self.cap as u64);
                if spawned >= target {
                    // grow loop converged: next job, or wait for drain
                    // before dropping the pool (callers always join
                    // their work — scope latch / run_tasks block — so a
                    // lost wakeup strands this wait, not the shutdown
                    // broadcast, exactly as in production)
                    s.pc[tid] = if s.pushed < self.jobs { Pc::SPush } else { Pc::SAwait };
                } else {
                    // CAS always succeeds here: the submitter is the
                    // only thread that writes `spawned`
                    s.spawned
                        .compare_exchange(spawned, spawned + 1)
                        .map_err(|v| format!("spawned CAS raced: {v}"))?;
                }
                Ok(())
            }
            Pc::SAwait => {
                // outstanding drained to zero: proceed to Drop
                s.pc[tid] = Pc::SShutdown;
                Ok(())
            }
            Pc::SShutdown => {
                s.m.acquire(tid);
                s.shutdown = true;
                s.m.release(tid);
                s.work_cv.notify_all();
                s.pc[tid] = Pc::SJoin;
                Ok(())
            }
            Pc::SJoin => {
                s.pc[tid] = Pc::SDone;
                Ok(())
            }
            Pc::SDone => Err("stepped the done submitter".into()),
            Pc::WUnspawned => {
                // std::thread::spawn completed; enter worker_loop
                s.pc[tid] = Pc::WLoop;
                Ok(())
            }
            Pc::WLoop => {
                s.m.acquire(tid);
                if s.queue > 0 {
                    // pop_front before the shutdown check: drain first
                    s.queue -= 1;
                    s.m.release(tid);
                    s.pc[tid] = Pc::WRun;
                } else if s.shutdown {
                    s.m.release(tid);
                    s.pc[tid] = Pc::WDone;
                } else {
                    s.work_cv.wait(&mut s.m, tid);
                    s.pc[tid] = Pc::WParked;
                }
                Ok(())
            }
            Pc::WRun => {
                s.executed += 1;
                s.outstanding.fetch_sub(1);
                s.pc[tid] = Pc::WLoop;
                Ok(())
            }
            Pc::WParked => {
                s.work_cv.wake(tid);
                s.pc[tid] = Pc::WLoop;
                Ok(())
            }
            Pc::WDone => Err("stepped a done worker".into()),
        }
    }

    fn check(&self, s: &State) -> Result<(), String> {
        // The grow rule, as a state invariant: whenever the submitter is
        // back at the push/shutdown boundary (its grow loop converged),
        // enough workers exist for every outstanding job, up to the cap.
        if matches!(s.pc[0], Pc::SPush | Pc::SAwait | Pc::SShutdown | Pc::SJoin | Pc::SDone) {
            let need = s.outstanding.load().min(self.cap as u64);
            if s.spawned.load() < need {
                return Err(format!(
                    "grow rule violated: spawned {} < min(outstanding {}, cap {})",
                    s.spawned.load(),
                    s.outstanding.load(),
                    self.cap
                ));
            }
        }
        Ok(())
    }

    fn check_final(&self, s: &State) -> Result<(), String> {
        if s.executed != self.jobs {
            return Err(format!(
                "shutdown lost jobs: executed {} of {} submitted",
                s.executed, self.jobs
            ));
        }
        if s.queue != 0 {
            return Err(format!("{} jobs still queued at exit", s.queue));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Checker;

    #[test]
    fn grow_and_drain_are_sound_at_two_workers() {
        let report = Checker::default().run(&LazyGrow::new(2, 2));
        assert!(report.passed(), "{:?}", report.violation);
        assert!(report.states > 10);
    }

    #[test]
    fn more_jobs_than_workers_still_drains() {
        let report = Checker::default().run(&LazyGrow::new(3, 1));
        assert!(report.passed(), "{:?}", report.violation);
    }

    #[test]
    fn zero_jobs_shutdown_is_clean() {
        let report = Checker::default().run(&LazyGrow::new(0, 2));
        assert!(report.passed(), "{:?}", report.violation);
    }

    #[test]
    fn dropped_submit_notify_is_detected() {
        let report = Checker::default().run(&LazyGrow::new(2, 2).with_lost_notify());
        let v = report.violation.expect("checker must find the lost wakeup");
        assert!(
            v.message.contains("deadlock / lost wakeup") || v.message.contains("lost jobs"),
            "{v}"
        );
    }
}
