//! Extracted state-machine models of the three trickiest protocols in
//! the runtime, checked exhaustively by [`crate::verify::Checker`]:
//!
//! * [`broadcast`] — `ThreadPool::run_tasks` publish/claim/retire
//!   (`util/threadpool.rs`): no lost wakeup, no double-claimed tile
//!   index, no use of the published closure after its gang retires.
//! * [`lazygrow`] — lazy worker growth vs. pool shutdown
//!   (`ThreadPool::submit` / `worker_loop` / `Drop`): every submitted job
//!   runs before shutdown completes; the grow rule spawns enough workers.
//! * [`swapdrain`] — registry hot swap with refcount drain
//!   (`registry/mod.rs`): a request's pinned version is never freed
//!   under it; the displaced version frees exactly once at refcount zero.
//!
//! Each model carries seeded mutants (a dropped notify, a split
//! read-then-pin) proving the checker detects the bug class it exists to
//! rule out. Model granularity follows the soundness rule from
//! [`crate::verify::checker`]: everything done under one real mutex
//! acquisition is one atomic step, and every lock release / wait / wake
//! is an interleaving point.

pub mod broadcast;
pub mod lazygrow;
pub mod swapdrain;
