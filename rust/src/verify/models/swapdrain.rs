//! Model of the registry's hot-swap refcount drain (`registry/mod.rs`).
//!
//! In production a request pins its model version at submit time by
//! cloning the `Arc<ModelVersion>` out of the registry *while holding
//! the registry read lock*; `swap` replaces the active slot under the
//! write lock and drops the registry's own reference to the displaced
//! version, whose executor frees when the last in-flight request drops
//! its pin (Arc strong count → 0). The checked properties:
//!
//! 1. **no use-after-free** — a request never touches an executor whose
//!    version has been freed;
//! 2. **no double-free / no leak** — the displaced version frees exactly
//!    once, and only after every pin is gone; the new version stays
//!    alive (the registry holds it).
//!
//! The [`SwapDrain::split_pin_mutant`] seeds the TOCTOU bug this
//! protocol exists to prevent: reading the active version and
//! incrementing its refcount in two separate steps (i.e. cloning the
//! `Arc` *after* releasing the registry lock from a bare pointer). The
//! checker finds the interleaving where the swap drains and frees the
//! version between the read and the pin.
//!
//! The registry lock is modeled as a [`MockMutex`]: the read/write
//! distinction only widens the schedule set for readers, and with ≤2
//! request threads the mutex serialization explores the same races the
//! RwLock admits for this protocol (pin and swap both mutate refcounts
//! atomically; concurrent read-side pins commute).

use crate::verify::checker::Model;
use crate::verify::shim::{MockAtomic, MockMutex};

/// Model configuration: `requesters` request threads (tids
/// `0..requesters`) each pin/use/unpin once; the last tid is the admin
/// performing one swap from version 0 to version 1.
#[derive(Debug, Clone, Copy)]
pub struct SwapDrain {
    pub requesters: usize,
    /// Seeded TOCTOU bug: read the active version id and take the pin in
    /// two separate atomic steps instead of one.
    pub split_pin_mutant: bool,
}

impl SwapDrain {
    pub fn new(requesters: usize) -> Self {
        Self { requesters, split_pin_mutant: false }
    }

    pub fn with_split_pin(mut self) -> Self {
        self.split_pin_mutant = true;
        self
    }

    fn admin_tid(&self) -> usize {
        self.requesters
    }
}

const VERSIONS: usize = 2;

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Pc {
    // requesters
    RPin,     // lock; v = active; refcnt[v] += 1; unlock (atomic pin)
    RPinRead, // mutant: lock; v = active; unlock — pin comes later
    RPinInc,  // mutant: lock; refcnt[v] += 1; unlock (the stale pin)
    RUse,     // execute against the pinned version (no lock)
    RUnpin,   // drop the Arc: refcnt -= 1; free at zero
    RDone,
    // admin
    ASwap, // write lock; active = 1; move the registry's own ref
    ADone,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct State {
    lock: MockMutex,
    active: usize,
    /// Arc strong counts (registry ref + request pins).
    refcnt: [MockAtomic; VERSIONS],
    freed: [bool; VERSIONS],
    pc: Vec<Pc>,
    /// The version each requester pinned (valid from pin to unpin).
    pinned: Vec<usize>,
}

/// Drop one reference to `v`; free the executor at strong count zero.
fn drop_ref(s: &mut State, v: usize) -> Result<(), String> {
    if s.refcnt[v].load() == 0 {
        return Err(format!("refcount underflow on version {v}"));
    }
    if s.refcnt[v].fetch_sub(1) == 1 {
        if s.freed[v] {
            return Err(format!("double-free of version {v}"));
        }
        s.freed[v] = true;
    }
    Ok(())
}

impl Model for SwapDrain {
    type State = State;

    fn init(&self) -> State {
        let start = if self.split_pin_mutant { Pc::RPinRead } else { Pc::RPin };
        let mut pc = vec![start; self.requesters];
        pc.push(Pc::ASwap);
        State {
            lock: MockMutex::default(),
            active: 0,
            // the registry's own reference to version 0; version 1 is
            // constructed by the swap
            refcnt: [MockAtomic(1), MockAtomic(0)],
            freed: [false, false],
            pc,
            pinned: vec![0; self.requesters],
        }
    }

    fn threads(&self) -> usize {
        self.requesters + 1
    }

    fn enabled(&self, s: &State, tid: usize) -> bool {
        match s.pc[tid] {
            Pc::RPin | Pc::RPinRead | Pc::RPinInc | Pc::ASwap => s.lock.is_free(),
            // using the executor and dropping an Arc take no registry lock
            Pc::RUse | Pc::RUnpin => true,
            Pc::RDone | Pc::ADone => false,
        }
    }

    fn done(&self, s: &State, tid: usize) -> bool {
        matches!(s.pc[tid], Pc::RDone | Pc::ADone)
    }

    fn step(&self, s: &mut State, tid: usize) -> Result<(), String> {
        match s.pc[tid] {
            Pc::RPin => {
                // Arc::clone(&slot.active) under the registry read lock:
                // observing the version and pinning it are inseparable
                s.lock.acquire(tid);
                let v = s.active;
                s.refcnt[v].fetch_add(1);
                s.lock.release(tid);
                s.pinned[tid] = v;
                s.pc[tid] = Pc::RUse;
                Ok(())
            }
            Pc::RPinRead => {
                // mutant: remember which version is active ...
                s.lock.acquire(tid);
                s.pinned[tid] = s.active;
                s.lock.release(tid);
                s.pc[tid] = Pc::RPinInc;
                Ok(())
            }
            Pc::RPinInc => {
                // ... and pin it in a later step (TOCTOU window)
                let v = s.pinned[tid];
                s.lock.acquire(tid);
                s.refcnt[v].fetch_add(1);
                s.lock.release(tid);
                s.pc[tid] = Pc::RUse;
                Ok(())
            }
            Pc::RUse => {
                let v = s.pinned[tid];
                if s.freed[v] {
                    return Err(format!(
                        "use-after-free: requester {tid} executed against freed \
                         version {v}"
                    ));
                }
                s.pc[tid] = Pc::RUnpin;
                Ok(())
            }
            Pc::RUnpin => {
                let v = s.pinned[tid];
                drop_ref(s, v)?;
                s.pc[tid] = Pc::RDone;
                Ok(())
            }
            Pc::RDone => Err("stepped a done requester".into()),
            Pc::ASwap => {
                // under the write lock: install v1 (registry takes its
                // ref) and drop the registry's ref to v0 — the displaced
                // executor frees now iff no request still pins it
                s.lock.acquire(tid);
                s.active = 1;
                s.refcnt[1].fetch_add(1);
                let r = drop_ref(s, 0);
                s.lock.release(tid);
                s.pc[tid] = Pc::ADone;
                r
            }
            Pc::ADone => Err("stepped the done admin".into()),
        }
    }

    fn check(&self, s: &State) -> Result<(), String> {
        for v in 0..VERSIONS {
            if s.freed[v] && s.refcnt[v].load() > 0 {
                return Err(format!(
                    "version {v} freed while {} references remain",
                    s.refcnt[v].load()
                ));
            }
        }
        Ok(())
    }

    fn check_final(&self, s: &State) -> Result<(), String> {
        if !s.freed[0] {
            return Err("displaced version 0 leaked (never freed)".into());
        }
        if s.refcnt[0].load() != 0 {
            return Err(format!("version 0 still has {} refs", s.refcnt[0].load()));
        }
        if s.freed[1] || s.refcnt[1].load() != 1 {
            return Err(format!(
                "active version 1 must stay alive with exactly the registry's ref \
                 (freed = {}, refs = {})",
                s.freed[1],
                s.refcnt[1].load()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Checker;

    #[test]
    fn atomic_pin_drains_cleanly_with_two_requesters() {
        let report = Checker::default().run(&SwapDrain::new(2));
        assert!(report.passed(), "{:?}", report.violation);
        assert!(report.states > 10);
    }

    #[test]
    fn single_requester_is_sound() {
        let report = Checker::default().run(&SwapDrain::new(1));
        assert!(report.passed(), "{:?}", report.violation);
    }

    #[test]
    fn split_pin_mutant_is_caught_as_use_after_free() {
        let report = Checker::default().run(&SwapDrain::new(1).with_split_pin());
        let v = report.violation.expect("checker must find the TOCTOU");
        // the race surfaces either as the pinned-after-free invariant or
        // as the use itself, depending on which step DFS reaches first
        assert!(
            v.message.contains("use-after-free") || v.message.contains("freed while"),
            "{v}"
        );
    }

    #[test]
    fn split_pin_mutant_caught_at_two_requesters_too() {
        let report = Checker::default().run(&SwapDrain::new(2).with_split_pin());
        assert!(report.violation.is_some());
    }
}
