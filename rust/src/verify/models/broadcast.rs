//! Model of `ThreadPool::run_tasks` — the gang-broadcast primitive
//! (`util/threadpool.rs`).
//!
//! The real protocol publishes a borrowed closure by raw pointer in a
//! one-deep broadcast slot, lets workers and the calling thread claim
//! task indices under the pool mutex, and blocks the caller until
//! `next == n_tasks && active == 0` before retiring the slot. Three
//! properties keep that sound, and this model checks all of them across
//! every interleaving:
//!
//! 1. **no double-claim** — each task index is claimed exactly once
//!    (the disjoint-tile guarantee `DisjointMut` relies on);
//! 2. **no use-after-retire** — no worker dereferences the published
//!    closure after its `run_tasks` frame retires the gang (the
//!    lifetime-transmute's entire justification);
//! 3. **no lost wakeup** — the leader's drain wait and a second leader's
//!    slot wait are always eventually woken (checker deadlock detection).
//!
//! Step granularity mirrors the real lock structure: the leader's
//! claim-loop iteration (including the `active -= 1` re-entry) happens
//! under a single mutex acquisition in the source, so it is a single
//! atomic step here; task execution happens outside the lock, so it is
//! its own step. The publish step folds `drop(st); work_cv.notify_all()`
//! into one action: the only thread that could interleave in that window
//! either sees claimable work (and claims instead of parking) or parks
//! and is in the wait set when the (guaranteed-coming) notify arrives —
//! no behavior is lost, see the argument in `verify::shim`.
//!
//! The [`Broadcast::lost_notify_mutant`] flag drops the last-finisher
//! `sync_cv.notify_all()` on the worker path — the seeded bug proving
//! the checker can fail: the leader then drain-waits forever and the
//! checker reports the deadlock with its schedule.

use crate::verify::checker::Model;
use crate::verify::shim::{MockCondvar, MockMutex};

/// What the body of task 0 does: nothing extra, or a *nested*
/// `run_tasks` call — the re-entry case the pool's `IN_GANG`
/// thread-local exists for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Nested {
    /// Tasks are plain computations (the default model).
    None,
    /// Task 0 re-enters `run_tasks`; the `IN_GANG` guard makes the
    /// nested dispatch run inline on the calling thread (production).
    Inline,
    /// Regression mutant: the `IN_GANG` guard is removed, so the nested
    /// call tries to publish into the (occupied) broadcast slot and
    /// waits for it — while its own claim keeps `active > 0` forever.
    /// The checker must find the self-deadlock.
    Blocking,
}

/// Model configuration. Thread ids: `0..leaders` run `run_tasks` once
/// each; `leaders..leaders + workers` run `worker_loop` forever.
#[derive(Debug, Clone, Copy)]
pub struct Broadcast {
    pub leaders: usize,
    pub workers: usize,
    pub n_tasks: usize,
    /// Seeded bug: the last-claim finisher on the worker path skips
    /// `sync_cv.notify_all()`, losing the leader's drain wakeup.
    pub lost_notify_mutant: bool,
    /// Behavior of task 0's body (nested-re-entry corpus).
    pub nested: Nested,
}

impl Broadcast {
    /// The production shape: one caller gang-dispatching over the pool.
    pub fn leader_and_workers(workers: usize, n_tasks: usize) -> Self {
        Self { leaders: 1, workers, n_tasks, lost_notify_mutant: false, nested: Nested::None }
    }

    /// Two concurrent `run_tasks` callers serializing on the slot.
    pub fn competing_leaders(n_tasks: usize) -> Self {
        Self { leaders: 2, workers: 1, n_tasks, lost_notify_mutant: false, nested: Nested::None }
    }

    pub fn with_lost_notify(mut self) -> Self {
        self.lost_notify_mutant = true;
        self
    }

    pub fn with_nested(mut self, nested: Nested) -> Self {
        self.nested = nested;
        self
    }

    fn is_leader(&self, tid: usize) -> bool {
        tid < self.leaders
    }
}

/// Per-thread program counter.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Pc {
    // leader (run_tasks)
    LAcquire,   // lock; publish if slot free, else sync-wait
    LSlotWait,  // parked on sync_cv waiting for the slot
    LClaim,     // lock held path: claim next / drain-wait / retire
    LDrainWait, // parked on sync_cv waiting for active == 0
    LExec,      // running its claimed task outside the lock
    LDec,       // re-lock; active -= 1; next loop iteration (same guard)
    LNotify,    // retired: outside the lock, sync_cv.notify_all()
    LDone,
    // worker (worker_loop)
    WClaim,  // lock; claim next gang index or park on work_cv
    WExec,   // dereferencing the published closure outside the lock
    WDec,    // re-lock; active -= 1; last-finisher notify; next iteration
    WParked, // parked on work_cv
    // nested-re-entry mutant (`Nested::Blocking`): the task body calls
    // run_tasks without the IN_GANG inline guard
    WNestedAcquire, // lock; slot occupied (its own gang) → sync-wait
    WNestedWait,    // parked on sync_cv inside the task body
}

/// Published gang slot: `(owner leader, next unclaimed, active count)`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct Gang {
    owner: usize,
    next: usize,
    active: usize,
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct State {
    m: MockMutex,
    work_cv: MockCondvar,
    sync_cv: MockCondvar,
    gang: Option<Gang>,
    /// Is leader `l`'s closure still alive (between publish and retire)?
    alive: Vec<bool>,
    /// `executed[l][i]`: times task `i` of leader `l`'s gang ran.
    executed: Vec<Vec<u8>>,
    pc: Vec<Pc>,
    /// Claimed task index (valid in `LExec`/`WExec`).
    local_idx: Vec<usize>,
    /// Gang owner the claim came from (valid in `WExec`).
    local_gang: Vec<usize>,
    /// Nested inline dispatches completed (one per task-0 execution
    /// when [`Nested::Inline`]).
    nested_runs: u8,
}

impl Broadcast {
    /// Claim-or-park body shared by the worker's lock-holding steps.
    /// Runs with `m` held; releases it on every path. Mirrors the top of
    /// `worker_loop`'s loop (queue handling elided: this model's pool
    /// carries gang work only — `lazygrow` models the queue).
    fn worker_claim_or_park(&self, s: &mut State, tid: usize) {
        match s.gang {
            Some(ref mut g) if g.next < self.n_tasks => {
                let idx = g.next;
                g.next += 1;
                g.active += 1;
                let owner = g.owner;
                s.local_idx[tid] = idx;
                s.local_gang[tid] = owner;
                s.m.release(tid);
                s.pc[tid] = Pc::WExec;
            }
            _ => {
                // no claimable gang work, empty queue, no shutdown:
                // park on work_cv (releases the mutex atomically)
                s.work_cv.wait(&mut s.m, tid);
                s.pc[tid] = Pc::WParked;
            }
        }
    }

    /// Leader claim-loop body. Runs with `m` held; releases on every
    /// path. One iteration of the `loop` in `run_tasks`, which the
    /// source executes under a single `MutexGuard`.
    fn leader_claim_loop(&self, s: &mut State, tid: usize) -> Result<(), String> {
        let g = match s.gang {
            Some(ref mut g) if g.owner == tid => g,
            ref other => {
                return Err(format!(
                    "gang retired under its leader {tid}: slot = {other:?}"
                ))
            }
        };
        if g.next < self.n_tasks {
            let idx = g.next;
            g.next += 1;
            g.active += 1;
            s.local_idx[tid] = idx;
            s.m.release(tid);
            s.pc[tid] = Pc::LExec;
        } else if g.active > 0 {
            s.sync_cv.wait(&mut s.m, tid);
            s.pc[tid] = Pc::LDrainWait;
        } else {
            // retire: the frame is about to return, the closure dies
            s.gang = None;
            s.alive[tid] = false;
            s.m.release(tid);
            s.pc[tid] = Pc::LNotify;
        }
        Ok(())
    }
}

impl Model for Broadcast {
    type State = State;

    fn init(&self) -> State {
        let n = self.leaders + self.workers;
        let pc = (0..n)
            .map(|t| if self.is_leader(t) { Pc::LAcquire } else { Pc::WClaim })
            .collect();
        State {
            m: MockMutex::default(),
            work_cv: MockCondvar::default(),
            sync_cv: MockCondvar::default(),
            gang: None,
            alive: vec![false; self.leaders],
            executed: vec![vec![0; self.n_tasks]; self.leaders],
            pc,
            local_idx: vec![0; n],
            local_gang: vec![0; n],
            nested_runs: 0,
        }
    }

    fn threads(&self) -> usize {
        self.leaders + self.workers
    }

    fn enabled(&self, s: &State, tid: usize) -> bool {
        match s.pc[tid] {
            Pc::LAcquire
            | Pc::LClaim
            | Pc::LDec
            | Pc::WClaim
            | Pc::WDec
            | Pc::WNestedAcquire => s.m.is_free(),
            Pc::LSlotWait | Pc::LDrainWait | Pc::WNestedWait => s.sync_cv.can_wake(tid),
            Pc::WParked => s.work_cv.can_wake(tid),
            Pc::LExec | Pc::WExec | Pc::LNotify => true,
            Pc::LDone => false,
        }
    }

    fn done(&self, s: &State, tid: usize) -> bool {
        if self.is_leader(tid) {
            s.pc[tid] == Pc::LDone
        } else {
            // Workers run forever in reality; in this single-burst model
            // a worker is "done" once it is parked and no gang work can
            // ever arrive again (every leader has returned).
            s.pc[tid] == Pc::WParked
                && s.gang.is_none()
                && (0..self.leaders).all(|l| s.pc[l] == Pc::LDone)
        }
    }

    fn step(&self, s: &mut State, tid: usize) -> Result<(), String> {
        match s.pc[tid] {
            Pc::LAcquire => {
                s.m.acquire(tid);
                if s.gang.is_some() {
                    // slot occupied by another leader: wait for retire
                    s.sync_cv.wait(&mut s.m, tid);
                    s.pc[tid] = Pc::LSlotWait;
                } else {
                    // publish + drop(st) + work_cv.notify_all() (see the
                    // module docs for why folding the notify is sound)
                    s.gang = Some(Gang { owner: tid, next: 0, active: 0 });
                    s.alive[tid] = true;
                    s.m.release(tid);
                    s.work_cv.notify_all();
                    s.pc[tid] = Pc::LClaim;
                }
                Ok(())
            }
            Pc::LSlotWait => {
                s.sync_cv.wake(tid);
                s.pc[tid] = Pc::LAcquire;
                Ok(())
            }
            Pc::LClaim => {
                s.m.acquire(tid);
                self.leader_claim_loop(s, tid)
            }
            Pc::LDrainWait => {
                s.sync_cv.wake(tid);
                // woken: re-acquires the guard and re-runs the loop body
                s.pc[tid] = Pc::LClaim;
                Ok(())
            }
            Pc::LExec => {
                // the leader calls `task(idx)` through the original
                // borrow; record execution for the exactly-once check
                let idx = s.local_idx[tid];
                s.executed[tid][idx] += 1;
                if s.executed[tid][idx] > 1 {
                    return Err(format!(
                        "double-claim: leader {tid} ran its task {idx} twice"
                    ));
                }
                match self.nested {
                    // the leader set IN_GANG before its claim loop, so a
                    // nested run_tasks inside the task body runs inline
                    Nested::Inline if idx == 0 => s.nested_runs += 1,
                    // mutant: without the guard the task body re-enters
                    // run_tasks from scratch — and slot-waits on a gang
                    // its own unfinished claim keeps alive
                    Nested::Blocking if idx == 0 => {
                        s.pc[tid] = Pc::LAcquire;
                        return Ok(());
                    }
                    _ => {}
                }
                s.pc[tid] = Pc::LDec;
                Ok(())
            }
            Pc::LDec => {
                // `st = lock(); g.active -= 1;` and the next loop
                // iteration run under the same guard in the source, so
                // they are one atomic step here.
                s.m.acquire(tid);
                match s.gang {
                    Some(ref mut g) if g.owner == tid => g.active -= 1,
                    ref other => {
                        return Err(format!(
                            "gang retired under its leader {tid}: slot = {other:?}"
                        ))
                    }
                }
                self.leader_claim_loop(s, tid)
            }
            Pc::LNotify => {
                s.sync_cv.notify_all();
                s.pc[tid] = Pc::LDone;
                Ok(())
            }
            Pc::LDone => Err("stepped a done leader".into()),
            Pc::WClaim => {
                s.m.acquire(tid);
                self.worker_claim_or_park(s, tid);
                Ok(())
            }
            Pc::WExec => {
                let owner = s.local_gang[tid];
                if !s.alive[owner] {
                    return Err(format!(
                        "use-after-retire: worker {tid} dereferenced leader \
                         {owner}'s closure after its gang retired"
                    ));
                }
                let idx = s.local_idx[tid];
                s.executed[owner][idx] += 1;
                if s.executed[owner][idx] > 1 {
                    return Err(format!(
                        "double-claim: task {idx} of leader {owner} ran twice"
                    ));
                }
                match self.nested {
                    // worker_loop sets IN_GANG around the task call, so
                    // the nested dispatch runs inline right here
                    Nested::Inline if idx == 0 => s.nested_runs += 1,
                    Nested::Blocking if idx == 0 => {
                        s.pc[tid] = Pc::WNestedAcquire;
                        return Ok(());
                    }
                    _ => {}
                }
                s.pc[tid] = Pc::WDec;
                Ok(())
            }
            Pc::WNestedAcquire => {
                // the guard-less nested run_tasks: lock, find the slot
                // occupied (by the very gang whose task is running), and
                // wait for a retire that can never come — this thread's
                // own claim holds `active > 0`
                s.m.acquire(tid);
                if s.gang.is_some() {
                    s.sync_cv.wait(&mut s.m, tid);
                    s.pc[tid] = Pc::WNestedWait;
                } else {
                    // unreachable while our claim is active; tolerate it
                    s.m.release(tid);
                    s.pc[tid] = Pc::WDec;
                }
                Ok(())
            }
            Pc::WNestedWait => {
                s.sync_cv.wake(tid);
                s.pc[tid] = Pc::WNestedAcquire;
                Ok(())
            }
            Pc::WDec => {
                // re-lock; active -= 1; last-finisher notify; `continue`
                // loops straight into the claim match under the same
                // guard — one atomic step, exactly like the source.
                s.m.acquire(tid);
                match s.gang {
                    Some(ref mut g) => {
                        g.active -= 1;
                        if g.next >= self.n_tasks
                            && g.active == 0
                            && !self.lost_notify_mutant
                        {
                            // wake the drain-waiting leader
                            s.sync_cv.notify_all();
                        }
                    }
                    None => {
                        return Err(format!(
                            "gang retired while worker {tid}'s task was active"
                        ))
                    }
                }
                self.worker_claim_or_park(s, tid);
                Ok(())
            }
            Pc::WParked => {
                s.work_cv.wake(tid);
                s.pc[tid] = Pc::WClaim;
                Ok(())
            }
        }
    }

    fn check(&self, s: &State) -> Result<(), String> {
        if let Some(g) = s.gang {
            if g.active > self.threads() {
                return Err(format!("active count {} exceeds thread count", g.active));
            }
            if g.next > self.n_tasks {
                return Err(format!("next {} ran past n_tasks {}", g.next, self.n_tasks));
            }
        }
        Ok(())
    }

    fn check_final(&self, s: &State) -> Result<(), String> {
        if s.gang.is_some() {
            return Err("broadcast slot still occupied at termination".into());
        }
        for (l, counts) in s.executed.iter().enumerate() {
            for (i, &c) in counts.iter().enumerate() {
                if c != 1 {
                    return Err(format!(
                        "task {i} of leader {l} executed {c} times (want exactly 1)"
                    ));
                }
            }
        }
        if self.nested == Nested::Inline && s.nested_runs != self.leaders as u8 {
            return Err(format!(
                "nested inline dispatch ran {} times (want one per gang, {})",
                s.nested_runs, self.leaders
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::Checker;

    #[test]
    fn leader_with_two_workers_is_sound() {
        let report = Checker::default().run(&Broadcast::leader_and_workers(2, 2));
        assert!(report.passed(), "{:?}", report.violation);
        assert!(report.states > 10, "trivial exploration: {} states", report.states);
    }

    #[test]
    fn competing_leaders_serialize_on_the_slot() {
        let report = Checker::default().run(&Broadcast::competing_leaders(2));
        assert!(report.passed(), "{:?}", report.violation);
    }

    #[test]
    fn seeded_lost_notify_is_detected_as_lost_wakeup() {
        let m = Broadcast::leader_and_workers(2, 2).with_lost_notify();
        let report = Checker::default().run(&m);
        let v = report.violation.expect("checker must find the seeded lost wakeup");
        assert!(v.message.contains("deadlock / lost wakeup"), "{v}");
        assert!(!v.schedule.is_empty(), "violation must carry a replay schedule");
    }

    #[test]
    fn mutant_with_zero_workers_cannot_deadlock() {
        // With no workers the leader claims every index itself and the
        // dropped worker-side notify is unreachable: the mutant must
        // pass, proving detection comes from the protocol, not noise.
        let m = Broadcast {
            leaders: 1,
            workers: 0,
            n_tasks: 2,
            lost_notify_mutant: true,
            nested: Nested::None,
        };
        let report = Checker::default().run(&m);
        assert!(report.passed(), "{:?}", report.violation);
    }

    #[test]
    fn nested_reentry_is_sound_with_the_inline_guard() {
        let m = Broadcast::leader_and_workers(2, 2).with_nested(Nested::Inline);
        let report = Checker::default().run(&m);
        assert!(report.passed(), "{:?}", report.violation);
    }

    #[test]
    fn nested_reentry_without_the_guard_self_deadlocks() {
        // Regression corpus for the IN_GANG audit: removing the inline
        // guard must be caught as a deadlock (the nested publish waits
        // on a slot its own claim pins).
        let m = Broadcast::leader_and_workers(2, 2).with_nested(Nested::Blocking);
        let report = Checker::default().run(&m);
        let v = report.violation.expect("guard-less re-entry must deadlock");
        assert!(v.message.contains("deadlock / lost wakeup"), "{v}");
    }
}
