//! # verify — static analysis for the unsafe runtime
//!
//! The serving stack rests on hand-rolled concurrency (the
//! [`crate::util::ThreadPool`] gang broadcast, lazy worker growth, the
//! registry's refcount-drained hot swap) and ~74 `unsafe` sites across the
//! SIMD kernels, `DisjointMut`, and the mmap'd weight views. This module
//! is the layer that *checks* those invariants instead of asserting them
//! in prose:
//!
//! * [`checker`] — a dependency-free explicit-state model checker (a
//!   mini-loom): virtual threads step through extracted state machines of
//!   the concurrency protocols while a DFS with memoization exhaustively
//!   enumerates every interleaving, detecting assertion violations and
//!   lost-wakeup deadlocks, and reporting a replayable schedule trace.
//! * [`shim`] — `MockMutex` / `MockCondvar` / `MockAtomic`: cloneable,
//!   hashable stand-ins for the `std::sync` primitives; condvar wakeups
//!   are granted to the threads waiting at notify time (notify_one's
//!   "which waiter" choice is left to the scheduler search), so
//!   notify/wait nondeterminism is part of the explored state space.
//! * [`models`] — the protocol models: `run_tasks` broadcast
//!   publish/claim/retire, lazy-pool grow vs. shutdown, and registry swap
//!   refcount-drain, each with seeded mutants proving the checker can
//!   fail (not just pass).
//! * [`lint`] — the project-invariant lint pass behind the `pfp-lint`
//!   binary: `SAFETY:` comments on every unsafe site, the hot-path
//!   allocation ban, schema-version single-sourcing, and the
//!   bench-emitter/CI-gate consistency rule.
//!
//! Fast configurations of every model run under plain `cargo test`
//! (tier-1). The `model_check` cargo feature additionally compiles
//! `rust/tests/model_check.rs`, which explores the full-size
//! configurations and the mutant corpus (`make model-check`).

pub mod checker;
pub mod lint;
pub mod models;
pub mod shim;

pub use checker::{Checker, Model, Report, Violation};
