//! Explicit-state model checker: exhaustive DFS over thread interleavings.
//!
//! A [`Model`] describes a small concurrent program as a state machine:
//! a cloneable/hashable state, a fixed set of virtual threads, an
//! `enabled` predicate saying which threads can take a step, and a `step`
//! function executing one *atomic* action of one thread. The [`Checker`]
//! explores every reachable interleaving by depth-first search with a
//! visited-state memo, so each distinct (state, schedule-budget) pair is
//! expanded once — enough to make ≤3-thread protocol models exhaustive in
//! milliseconds without any real threads, locks, or nondeterminism.
//!
//! Detected failures:
//! * **assertion violations** — `step`/`check`/`check_final` returning
//!   `Err` (double-claim, use-after-retire, use-after-free, …);
//! * **lost wakeups / deadlock** — a state where no thread is enabled but
//!   not every thread is done. Because `MockCondvar` waiters are only
//!   enabled while a wakeup grant is pending, a missed `notify` shows up
//!   as exactly this kind of stuck state.
//!
//! Every violation carries the schedule (the sequence of thread ids) that
//! reproduces it from the initial state.

use std::collections::HashSet;
use std::hash::Hash;

/// A concurrent protocol expressed as an explorable state machine.
///
/// `step(state, tid)` must perform one *atomic* action: in the real code
/// an atomic action is everything done under one mutex acquisition (the
/// mutex serializes it), a single wait/wake transition, or one
/// lock-free instruction. Interleaving points — the only places another
/// thread can observe intermediate state — are the boundaries between
/// those actions, which is exactly where the checker branches.
pub trait Model {
    /// Full system state: shared variables + every thread's pc/locals.
    type State: Clone + Eq + Hash;

    /// The initial state.
    fn init(&self) -> Self::State;

    /// Number of virtual threads (thread ids are `0..threads()`).
    fn threads(&self) -> usize;

    /// Can `tid` take a step in `s`? Blocked lock acquirers and condvar
    /// waiters without a wakeup grant are disabled; `Done` threads too.
    fn enabled(&self, s: &Self::State, tid: usize) -> bool;

    /// Has `tid` finished its program? A state where every thread is done
    /// is terminal and checked with [`Model::check_final`]. A thread may
    /// be "done" conditionally on shared state (e.g. a pool worker parked
    /// on the work condvar once no more work can ever arrive).
    fn done(&self, s: &Self::State, tid: usize) -> bool;

    /// Execute one atomic action of `tid`. Returns `Err` on an assertion
    /// violation (the checker stops and reports the schedule).
    fn step(&self, s: &mut Self::State, tid: usize) -> Result<(), String>;

    /// Invariant checked after every step. Default: none.
    fn check(&self, _s: &Self::State) -> Result<(), String> {
        Ok(())
    }

    /// Property checked in terminal states (every thread done).
    fn check_final(&self, _s: &Self::State) -> Result<(), String> {
        Ok(())
    }
}

/// A property failure plus the schedule reproducing it.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Human-readable description of what broke.
    pub message: String,
    /// Thread ids in execution order from the initial state.
    pub schedule: Vec<usize>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} [schedule: {:?}]", self.message, self.schedule)
    }
}

/// Exploration statistics + outcome.
#[derive(Debug, Clone)]
pub struct Report {
    /// Distinct states expanded.
    pub states: usize,
    /// Scheduler branches taken (edges in the interleaving graph).
    pub transitions: usize,
    /// First violation found, if any (DFS order — deterministic).
    pub violation: Option<Violation>,
    /// True when the search saw every reachable state within its bounds
    /// (i.e. the depth bound never truncated a path).
    pub exhaustive: bool,
}

impl Report {
    /// Did the model pass (no violation, search exhaustive)?
    pub fn passed(&self) -> bool {
        self.violation.is_none() && self.exhaustive
    }
}

/// DFS explorer with depth and preemption bounds.
pub struct Checker {
    /// Maximum schedule length before a path is truncated (guards against
    /// models with unbounded loops; generous default for tiny protocols).
    pub max_depth: usize,
    /// Optional context-switch bound: `Some(k)` explores only schedules
    /// with at most `k` preemptions (switches away from a still-enabled
    /// thread). `None` = full exhaustive search.
    pub max_preemptions: Option<usize>,
}

impl Default for Checker {
    fn default() -> Self {
        Self { max_depth: 10_000, max_preemptions: None }
    }
}

/// DFS stack frame: a state plus the scheduling context it was reached in.
struct Node<S> {
    state: S,
    last: Option<usize>,
    preemptions: usize,
    depth: usize,
    schedule: Vec<usize>,
}

impl Checker {
    /// Exhaustively explore `model` from its initial state.
    pub fn run<M: Model>(&self, model: &M) -> Report {
        let n = model.threads();
        let mut visited: HashSet<(M::State, Option<usize>, usize)> = HashSet::new();
        let mut stack: Vec<Node<M::State>> = vec![Node {
            state: model.init(),
            last: None,
            preemptions: 0,
            depth: 0,
            schedule: Vec::new(),
        }];
        let mut report =
            Report { states: 0, transitions: 0, violation: None, exhaustive: true };

        while let Some(node) = stack.pop() {
            // Memo key includes the scheduling context only when it can
            // change which successors are explored (preemption bound).
            let key = match self.max_preemptions {
                Some(_) => (node.state.clone(), node.last, node.preemptions),
                None => (node.state.clone(), None, 0),
            };
            if !visited.insert(key) {
                continue;
            }
            report.states += 1;

            let enabled: Vec<usize> =
                (0..n).filter(|&t| model.enabled(&node.state, t)).collect();
            if enabled.is_empty() {
                if (0..n).all(|t| model.done(&node.state, t)) {
                    if let Err(msg) = model.check_final(&node.state) {
                        report.violation = Some(Violation {
                            message: format!("final-state check failed: {msg}"),
                            schedule: node.schedule,
                        });
                        return report;
                    }
                } else {
                    let stuck: Vec<usize> =
                        (0..n).filter(|&t| !model.done(&node.state, t)).collect();
                    report.violation = Some(Violation {
                        message: format!(
                            "deadlock / lost wakeup: no thread enabled but threads \
                             {stuck:?} are not done"
                        ),
                        schedule: node.schedule,
                    });
                    return report;
                }
                continue;
            }

            if node.depth >= self.max_depth {
                // Path truncated: the search is no longer exhaustive.
                report.exhaustive = false;
                continue;
            }

            for &tid in &enabled {
                let preempted = match node.last {
                    Some(prev) => {
                        prev != tid && model.enabled(&node.state, prev)
                    }
                    None => false,
                };
                let preemptions = node.preemptions + usize::from(preempted);
                if let Some(bound) = self.max_preemptions {
                    if preemptions > bound {
                        continue;
                    }
                }
                let mut next = node.state.clone();
                let mut schedule = node.schedule.clone();
                schedule.push(tid);
                report.transitions += 1;
                if let Err(msg) = model.step(&mut next, tid) {
                    report.violation = Some(Violation { message: msg, schedule });
                    return report;
                }
                if let Err(msg) = model.check(&next) {
                    report.violation = Some(Violation {
                        message: format!("invariant check failed: {msg}"),
                        schedule,
                    });
                    return report;
                }
                stack.push(Node {
                    state: next,
                    last: Some(tid),
                    preemptions,
                    depth: node.depth + 1,
                    schedule,
                });
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads increment a shared counter with a non-atomic
    /// read-modify-write (load to a local, then store local+1). The
    /// classic lost-update race: a final-state check of `counter == 2`
    /// must fail on some interleaving.
    struct RacyIncrement;

    #[derive(Clone, PartialEq, Eq, Hash)]
    struct RacyState {
        counter: u8,
        // pc: 0 = load, 1 = store, 2 = done; local = loaded value
        pc: [u8; 2],
        local: [u8; 2],
    }

    impl Model for RacyIncrement {
        type State = RacyState;

        fn init(&self) -> RacyState {
            RacyState { counter: 0, pc: [0, 0], local: [0, 0] }
        }

        fn threads(&self) -> usize {
            2
        }

        fn enabled(&self, s: &RacyState, tid: usize) -> bool {
            s.pc[tid] < 2
        }

        fn done(&self, s: &RacyState, tid: usize) -> bool {
            s.pc[tid] == 2
        }

        fn step(&self, s: &mut RacyState, tid: usize) -> Result<(), String> {
            match s.pc[tid] {
                0 => s.local[tid] = s.counter,
                1 => s.counter = s.local[tid] + 1,
                _ => unreachable!("stepped a done thread"),
            }
            s.pc[tid] += 1;
            Ok(())
        }

        fn check_final(&self, s: &RacyState) -> Result<(), String> {
            if s.counter == 2 {
                Ok(())
            } else {
                Err(format!("lost update: counter = {} != 2", s.counter))
            }
        }
    }

    #[test]
    fn finds_the_classic_lost_update() {
        let report = Checker::default().run(&RacyIncrement);
        let v = report.violation.expect("must find the lost update");
        assert!(v.message.contains("lost update"), "{v}");
        // The shortest failing schedule interleaves the two loads.
        assert!(v.schedule.len() >= 4, "{v}");
    }

    /// Same program with the read-modify-write made atomic (single step):
    /// no interleaving loses an update.
    struct AtomicIncrement;

    impl Model for AtomicIncrement {
        type State = RacyState;

        fn init(&self) -> RacyState {
            RacyState { counter: 0, pc: [0, 0], local: [0, 0] }
        }

        fn threads(&self) -> usize {
            2
        }

        fn enabled(&self, s: &RacyState, tid: usize) -> bool {
            s.pc[tid] < 2
        }

        fn done(&self, s: &RacyState, tid: usize) -> bool {
            s.pc[tid] == 2
        }

        fn step(&self, s: &mut RacyState, tid: usize) -> Result<(), String> {
            s.counter += 1;
            s.pc[tid] = 2;
            Ok(())
        }

        fn check_final(&self, s: &RacyState) -> Result<(), String> {
            if s.counter == 2 {
                Ok(())
            } else {
                Err(format!("counter = {}", s.counter))
            }
        }
    }

    #[test]
    fn atomic_variant_is_clean_and_exhaustive() {
        let report = Checker::default().run(&AtomicIncrement);
        assert!(report.passed(), "{:?}", report.violation);
        assert!(report.states > 0);
    }

    #[test]
    fn depth_bound_marks_search_non_exhaustive() {
        let report = Checker { max_depth: 1, max_preemptions: None }.run(&AtomicIncrement);
        assert!(!report.exhaustive);
    }

    #[test]
    fn preemption_bound_zero_still_finds_sequential_states() {
        // With zero preemptions only round-robin-free schedules run; the
        // atomic model still reaches its terminal state cleanly.
        let report =
            Checker { max_depth: 10_000, max_preemptions: Some(0) }.run(&AtomicIncrement);
        assert!(report.violation.is_none(), "{:?}", report.violation);
    }
}
