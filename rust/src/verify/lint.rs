//! Project-invariant lint pass (the library behind `cargo run --bin
//! pfp-lint`).
//!
//! Source-level analysis over `rust/src` — dependency-free, line-based,
//! with a small comment/string-aware scanner so tokens inside literals
//! and comments never count as code. Four rule families:
//!
//! 1. **`SAFETY:` discipline** — every `unsafe` block, fn, or impl must
//!    be justified by a `SAFETY:` comment on the same line or in the
//!    contiguous comment/attribute run directly above it (a `/// #
//!    Safety` doc section also counts, for `unsafe fn` whose contract is
//!    the doc).
//! 2. **hot-path allocation ban** — the plan-execute path promises zero
//!    steady-state allocation (asserted dynamically by the counting
//!    allocator in `tests/integration_plan_alloc.rs`; enforced
//!    *statically* here): no `Vec::`, `Box::new`, `.to_vec(`,
//!    `.collect(`, `format!`, `vec!`, `String::from`, `.to_string(` and
//!    no `Instant::now` inside the named hot functions
//!    ([`HOT_PATHS`]), except on lines annotated `// lint: allow(alloc)
//!    — <reason>` (cold growth paths).
//! 3. **version single-sourcing** — `SCHEMA_VERSION` /
//!    `PROTOCOL_VERSION` are each declared exactly once, and no JSON
//!    emission of a version key hardcodes a numeral instead of the
//!    constant.
//! 4. **bench-gate consistency** — every bench that emits a
//!    `BENCH_*.json` perf artifact must be named by an explicit
//!    `--bench` gate in `.github/workflows/ci.yml`, so a Cargo target
//!    regression cannot silently drop an emitter from CI.
//!
//! Everything here is pure (`&str` in, [`Finding`]s out) so the rules
//! are unit-testable on synthetic sources — including the required
//! demonstrations that deleting a `SAFETY:` comment or injecting a
//! `Vec::new()` into `plan/mod.rs::execute` fails the lint.

use std::fs;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the repo root, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule family id (`safety`, `hot-path-alloc`, `version`, `bench-gate`).
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// comment/string-aware scanner
// ---------------------------------------------------------------------------

/// Carry-over lexical state between lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lex {
    Code,
    /// Inside `/* */`, with nesting depth (Rust block comments nest).
    Block(usize),
    /// Inside a normal `"` string that spans lines.
    Str,
    /// Inside a raw string, with the number of `#`s in its delimiter.
    RawStr(usize),
}

/// Strips comments and string/char literal *contents* from source lines,
/// preserving everything else, so token scans see only code.
pub struct Scanner {
    state: Lex,
}

impl Default for Scanner {
    fn default() -> Self {
        Self::new()
    }
}

impl Scanner {
    pub fn new() -> Self {
        Self { state: Lex::Code }
    }

    /// Strip one line (call in file order; the scanner carries
    /// block-comment and multiline-string state across calls).
    pub fn strip(&mut self, line: &str) -> String {
        let b: Vec<char> = line.chars().collect();
        let mut out = String::with_capacity(line.len());
        let mut i = 0;
        while i < b.len() {
            match self.state {
                Lex::Block(depth) => {
                    if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                        i += 2;
                        self.state =
                            if depth == 1 { Lex::Code } else { Lex::Block(depth - 1) };
                    } else if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                        i += 2;
                        self.state = Lex::Block(depth + 1);
                    } else {
                        i += 1;
                    }
                }
                Lex::Str => {
                    if b[i] == '\\' {
                        i += 2;
                    } else if b[i] == '"' {
                        i += 1;
                        self.state = Lex::Code;
                    } else {
                        i += 1;
                    }
                }
                Lex::RawStr(hashes) => {
                    if b[i] == '"'
                        && (1..=hashes).all(|k| b.get(i + k) == Some(&'#'))
                    {
                        i += 1 + hashes;
                        self.state = Lex::Code;
                    } else {
                        i += 1;
                    }
                }
                Lex::Code => {
                    let c = b[i];
                    if c == '/' && b.get(i + 1) == Some(&'/') {
                        break; // line comment: rest of the line is gone
                    } else if c == '/' && b.get(i + 1) == Some(&'*') {
                        out.push(' ');
                        i += 2;
                        self.state = Lex::Block(1);
                    } else if c == '"' {
                        out.push(' ');
                        i += 1;
                        self.state = Lex::Str;
                    } else if (c == 'r' || c == 'b')
                        && !prev_is_ident(&b, i)
                        && raw_hashes(&b, i).is_some()
                    {
                        let hashes = raw_hashes(&b, i).unwrap();
                        out.push(' ');
                        // skip past `r##"` (or `br#"` etc.)
                        i += raw_prefix_len(&b, i) + hashes + 1;
                        self.state = Lex::RawStr(hashes);
                    } else if c == 'b' && b.get(i + 1) == Some(&'"') && !prev_is_ident(&b, i)
                    {
                        out.push(' ');
                        i += 2;
                        self.state = Lex::Str;
                    } else if c == '\'' {
                        i = skip_char_or_lifetime(&b, i, &mut out);
                    } else {
                        out.push(c);
                        i += 1;
                    }
                }
            }
        }
        out
    }
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// If `b[i..]` starts a raw string (`r"`, `r#"`, `br##"` …), the number
/// of `#`s in its delimiter.
fn raw_hashes(b: &[char], i: usize) -> Option<usize> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (b.get(j) == Some(&'"')).then_some(hashes)
}

fn raw_prefix_len(b: &[char], i: usize) -> usize {
    if b.get(i) == Some(&'b') {
        2 // `br`
    } else {
        1 // `r`
    }
}

/// Handles `'x'`, `'\n'`, `'\u{…}'` char literals and `'lifetime`s.
fn skip_char_or_lifetime(b: &[char], i: usize, out: &mut String) -> usize {
    if b.get(i + 1) == Some(&'\\') {
        // escaped char literal: scan to the closing quote
        let mut j = i + 2;
        while j < b.len() && b[j] != '\'' {
            j += 1;
        }
        out.push(' ');
        j + 1
    } else if b.get(i + 2) == Some(&'\'') {
        // simple char literal (including '"' and '{')
        out.push(' ');
        i + 3
    } else {
        // a lifetime: drop the quote, keep scanning the identifier
        i + 1
    }
}

/// Does `hay` contain `needle` as a standalone word (not an identifier
/// substring)?
fn contains_word(hay: &str, needle: &str) -> bool {
    find_word(hay, needle).is_some()
}

fn find_word(hay: &str, needle: &str) -> Option<usize> {
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let pre_ok = start == 0 || {
            let c = bytes[start - 1] as char;
            !c.is_alphanumeric() && c != '_'
        };
        let post_ok = end >= bytes.len() || {
            let c = bytes[end] as char;
            !c.is_alphanumeric() && c != '_'
        };
        if pre_ok && post_ok {
            return Some(start);
        }
        from = end;
    }
    None
}

// ---------------------------------------------------------------------------
// rule 1: SAFETY discipline
// ---------------------------------------------------------------------------

/// Is `line` (raw) part of a comment/attribute run that may sit between
/// a `SAFETY:` justification and its `unsafe` site?
fn is_annotation_line(line: &str) -> bool {
    let t = line.trim_start();
    t.is_empty()
        || t.starts_with("//")
        || t.starts_with("#[")
        || t.starts_with("#![")
        || t.starts_with("*") // inner lines of `/* … */`
        || t == ")]"
        || t == "))]"
}

/// Every `unsafe` token in code must carry a `SAFETY:` comment on the
/// same line or in the contiguous annotation run directly above
/// (`/// # Safety` doc sections count).
pub fn lint_safety(relpath: &str, content: &str) -> Vec<Finding> {
    let raw: Vec<&str> = content.lines().collect();
    let mut scanner = Scanner::new();
    let stripped: Vec<String> = raw.iter().map(|l| scanner.strip(l)).collect();
    let mut findings = Vec::new();
    for (idx, code) in stripped.iter().enumerate() {
        if !contains_word(code, "unsafe") {
            continue;
        }
        if raw[idx].contains("SAFETY:") {
            continue;
        }
        let mut justified = false;
        let mut k = idx;
        while k > 0 && is_annotation_line(raw[k - 1]) {
            k -= 1;
            if raw[k].contains("SAFETY:") || raw[k].contains("# Safety") {
                justified = true;
                break;
            }
        }
        if !justified {
            findings.push(Finding {
                file: relpath.to_string(),
                line: idx + 1,
                rule: "safety",
                message: "`unsafe` without a `SAFETY:` comment (same line or the \
                          comment/attribute block directly above)"
                    .to_string(),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// rule 2: hot-path allocation ban
// ---------------------------------------------------------------------------

/// The plan-execute hot path: (file suffix, steady-state functions that
/// must not allocate or read the clock). Wrappers that legitimately
/// allocate (`pfp_relu_into`, scope-path dispatch, plan *compilation*)
/// are deliberately absent — this list is the contract for what runs
/// per-request after warmup.
pub const HOT_PATHS: &[(&str, &[&str])] = &[
    ("plan/mod.rs", &["execute", "store_activations"]),
    ("plan/workspace.rs", &["ensure"]),
    (
        "ops/dense.rs",
        &[
            "dense_rows_into",
            "dense_kernel_tiled_into",
            "dense_rows_packed_into",
            "dense_kernel_packed_tiled_into",
        ],
    ),
    (
        "ops/conv.rs",
        &[
            "im2col_rows_into",
            "col2im_planes_into",
            "conv_kernel_tiled_into",
            "conv_kernel_packed_tiled_into",
        ],
    ),
    // the mixed-precision conversion kernels run per step on the packed
    // execute path: widen/narrow must stay allocation-free like the
    // compute kernels they feed
    ("ops/simd.rs", &["widen_into", "narrow_into"]),
    ("util/half.rs", &["widen", "narrow"]),
    ("ops/relu.rs", &["pfp_relu_rows_into", "pfp_relu_tiled_into", "apply_epilogue"]),
    (
        "ops/maxpool.rs",
        &[
            "pfp_maxpool2_planes_into",
            "pfp_maxpool2_tiled_into",
            "det_maxpool2_planes_into",
            "det_maxpool2_tiled_into",
        ],
    ),
    ("util/threadpool.rs", &["run_tasks", "worker_loop"]),
    // the connection reactor's steady state: every request crosses
    // `Poller::wait` and the waker, and every inbound line crosses the
    // codec's scanner — none of them may allocate or read the clock
    // per event (`Events` is pre-sized at startup; the codec's `push`
    // owns the amortized buffer growth)
    ("coordinator/reactor.rs", &["wait", "wake"]),
    ("coordinator/codec.rs", &["next_line"]),
];

/// Tokens that allocate (or read the clock) and are banned from the
/// steady-state execute path.
const BANNED: &[&str] = &[
    "Vec::",
    "Box::new",
    ".to_vec(",
    ".collect(",
    ".collect::<",
    ".resize(",
    "format!",
    "vec!",
    "String::from",
    ".to_string(",
    "Instant::now",
];

/// The escape hatch for audited cold paths inside a hot function.
pub const ALLOW_ALLOC: &str = "lint: allow(alloc)";

/// Find the (start, end) line ranges (0-based, inclusive) of every `fn
/// <name>` body in already-stripped lines.
fn fn_body_ranges(stripped: &[String], name: &str) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0;
    while i < stripped.len() {
        let line = &stripped[i];
        let is_decl = find_word(line, "fn")
            .map(|pos| {
                let after = line[pos + 2..].trim_start();
                after.starts_with(name)
                    && after[name.len()..]
                        .chars()
                        .next()
                        .map(|c| c == '(' || c == '<' || c.is_whitespace())
                        .unwrap_or(false)
            })
            .unwrap_or(false);
        if !is_decl {
            i += 1;
            continue;
        }
        // walk forward to the opening brace, then to its close
        let mut depth = 0usize;
        let mut opened = false;
        let start = i;
        let mut j = i;
        'outer: while j < stripped.len() {
            for c in stripped[j].chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth = depth.saturating_sub(1);
                        if opened && depth == 0 {
                            break 'outer;
                        }
                    }
                    ';' if !opened => break 'outer, // trait method decl, no body
                    _ => {}
                }
            }
            j += 1;
        }
        if opened {
            ranges.push((start, j.min(stripped.len() - 1)));
        }
        i = j + 1;
    }
    ranges
}

/// Enforce the allocation/clock ban inside the configured hot functions
/// of `relpath` (no-op for files not in [`HOT_PATHS`]).
pub fn lint_hot_path(relpath: &str, content: &str) -> Vec<Finding> {
    let Some((_, fns)) =
        HOT_PATHS.iter().find(|(suffix, _)| relpath.ends_with(suffix))
    else {
        return Vec::new();
    };
    let raw: Vec<&str> = content.lines().collect();
    let mut scanner = Scanner::new();
    let stripped: Vec<String> = raw.iter().map(|l| scanner.strip(l)).collect();
    let mut findings = Vec::new();
    for &fn_name in fns.iter() {
        for (start, end) in fn_body_ranges(&stripped, fn_name) {
            for idx in start..=end {
                let escaped = raw[idx].contains(ALLOW_ALLOC)
                    || (idx > 0 && raw[idx - 1].contains(ALLOW_ALLOC));
                if escaped {
                    continue;
                }
                for tok in BANNED {
                    if stripped[idx].contains(tok) {
                        findings.push(Finding {
                            file: relpath.to_string(),
                            line: idx + 1,
                            rule: "hot-path-alloc",
                            message: format!(
                                "`{tok}` in hot function `{fn_name}` (zero \
                                 steady-state allocation contract); annotate an \
                                 audited cold path with `// {ALLOW_ALLOC} — reason`"
                            ),
                        });
                    }
                }
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// rule 3: version single-sourcing
// ---------------------------------------------------------------------------

/// After `Json::Num(`, is the argument a bare numeric literal (a
/// hardcoded version) rather than an expression over the constant?
fn num_call_with_literal(code: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find("Num(") {
        let rest = code[from + pos + 4..].trim_start();
        if rest.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false) {
            return true;
        }
        from += pos + 4;
    }
    false
}

/// Versioned-artifact consistency over the whole tree: each version
/// constant declared exactly once; version keys always emitted through
/// their constant, never a numeral.
pub fn lint_versions(files: &[(String, String)]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (const_name, key) in
        [("SCHEMA_VERSION", "\"__version__\""), ("PROTOCOL_VERSION", "\"v\"")]
    {
        let mut decls: Vec<(String, usize)> = Vec::new();
        for (relpath, content) in files {
            let mut scanner = Scanner::new();
            for (idx, raw) in content.lines().enumerate() {
                let code = scanner.strip(raw);
                if contains_word(&code, "const")
                    && contains_word(&code, const_name)
                    && code.contains('=')
                {
                    decls.push((relpath.clone(), idx + 1));
                }
                // a line that writes the version key with a hardcoded
                // numeral instead of the constant
                if raw.contains(key)
                    && num_call_with_literal(&code)
                    && !raw.contains(const_name)
                    && !raw.contains("lint: allow(version)")
                {
                    findings.push(Finding {
                        file: relpath.clone(),
                        line: idx + 1,
                        rule: "version",
                        message: format!(
                            "{key} emitted with a numeric literal; use {const_name}"
                        ),
                    });
                }
            }
        }
        if decls.len() != 1 {
            let at: Vec<String> =
                decls.iter().map(|(f, l)| format!("{f}:{l}")).collect();
            findings.push(Finding {
                file: decls
                    .first()
                    .map(|(f, _)| f.clone())
                    .unwrap_or_else(|| "rust/src".to_string()),
                line: decls.first().map(|(_, l)| *l).unwrap_or(0),
                rule: "version",
                message: format!(
                    "{const_name} must be declared exactly once (found {}: {at:?})",
                    decls.len()
                ),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// rule 4: bench-gate consistency
// ---------------------------------------------------------------------------

/// Every bench emitting `BENCH_*.json` must be named via `--bench
/// <stem>` somewhere in the CI workflow.
pub fn lint_bench_gate(bench_files: &[(String, String)], ci_yaml: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (relpath, content) in bench_files {
        let stem = Path::new(relpath)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(relpath);
        let mut emits_at = None;
        for (idx, raw) in content.lines().enumerate() {
            if let Some(pos) = raw.find("BENCH_") {
                if raw[pos..].contains(".json") {
                    emits_at = Some(idx + 1);
                    break;
                }
            }
        }
        if let Some(line) = emits_at {
            let gate = format!("--bench {stem}");
            if !ci_yaml.contains(&gate) {
                findings.push(Finding {
                    file: relpath.clone(),
                    line,
                    rule: "bench-gate",
                    message: format!(
                        "bench `{stem}` emits a BENCH_*.json perf artifact but is \
                         not named by `{gate}` in .github/workflows/ci.yml"
                    ),
                });
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// tree driver
// ---------------------------------------------------------------------------

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Run every rule over the repository at `root`. Returns all findings
/// (empty = the tree passes).
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let src = root.join("rust/src");
    let mut paths = Vec::new();
    walk_rs(&src, &mut paths)?;
    let files: Vec<(String, String)> = paths
        .iter()
        .map(|p| Ok((rel(root, p), fs::read_to_string(p)?)))
        .collect::<std::io::Result<_>>()?;

    let mut findings = Vec::new();
    for (relpath, content) in &files {
        findings.extend(lint_safety(relpath, content));
        findings.extend(lint_hot_path(relpath, content));
    }
    findings.extend(lint_versions(&files));

    let bench_dir = root.join("rust/benches");
    if bench_dir.is_dir() {
        let mut bench_paths = Vec::new();
        walk_rs(&bench_dir, &mut bench_paths)?;
        let bench_files: Vec<(String, String)> = bench_paths
            .iter()
            .map(|p| Ok((rel(root, p), fs::read_to_string(p)?)))
            .collect::<std::io::Result<_>>()?;
        let ci = fs::read_to_string(root.join(".github/workflows/ci.yml"))
            .unwrap_or_default();
        findings.extend(lint_bench_gate(&bench_files, &ci));
    }
    Ok(findings)
}

/// The repo root, resolved from the crate manifest dir (`rust/`).
pub fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives in <root>/rust")
        .to_path_buf()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_all(src: &str) -> Vec<String> {
        let mut sc = Scanner::new();
        src.lines().map(|l| sc.strip(l)).collect()
    }

    #[test]
    fn scanner_strips_strings_comments_and_char_literals() {
        let s = strip_all(
            "let a = \"unsafe in a string\"; // unsafe in a comment\n\
             let q = '\"'; let l: &'static str = x; /* unsafe\n\
             still comment */ let tail = 1;\n\
             let r = r#\"raw unsafe\"#;",
        );
        assert!(!s[0].contains("unsafe"), "{:?}", s[0]);
        assert!(s[0].contains("let a ="));
        assert!(!s[1].contains("unsafe"), "{:?}", s[1]);
        assert!(s[1].contains("static"), "lifetime must not open a char literal");
        assert!(s[2].contains("let tail"), "block comment must close");
        assert!(!s[2].contains("still"));
        assert!(!s[3].contains("unsafe"), "{:?}", s[3]);
    }

    #[test]
    fn safety_rule_accepts_justified_sites() {
        let src = "\
// SAFETY: the buffer outlives the call.
let x = unsafe { deref(p) };

/// # Safety
/// Caller guarantees `p` is valid.
#[inline]
pub unsafe fn deref(p: *const u8) -> u8 { *p }

let y = unsafe { deref(p) }; // SAFETY: p checked above
";
        assert_eq!(lint_safety("a.rs", src), vec![]);
    }

    #[test]
    fn removing_a_safety_comment_fails_the_lint() {
        let with = "// SAFETY: justified.\nlet x = unsafe { f() };\n";
        assert!(lint_safety("a.rs", with).is_empty());
        let without = "// plain comment.\nlet x = unsafe { f() };\n";
        let findings = lint_safety("a.rs", without);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, "safety");
        assert_eq!(findings[0].line, 2);
    }

    #[test]
    fn unsafe_in_strings_and_comments_is_ignored() {
        let src = "let s = \"unsafe\"; // unsafe unsafe\n/* unsafe */ let t = 1;\n";
        assert_eq!(lint_safety("a.rs", src), vec![]);
    }

    #[test]
    fn hot_path_rule_flags_alloc_in_named_fn_only() {
        let src = "\
pub fn execute(x: &[f32]) -> usize {
    let n = x.len();
    n
}

pub fn compile() -> Vec<f32> {
    Vec::new()
}
";
        assert_eq!(lint_hot_path("rust/src/plan/mod.rs", src), vec![]);
        let bad = src.replace("let n = x.len();", "let n = Vec::new().len();");
        let findings = lint_hot_path("rust/src/plan/mod.rs", &bad);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "hot-path-alloc");
        // not a hot file ⇒ no findings at all
        assert_eq!(lint_hot_path("rust/src/tuner/mod.rs", &bad), vec![]);
    }

    #[test]
    fn allow_alloc_escape_exempts_audited_lines() {
        let src = "\
pub fn execute(x: &mut Vec<f32>) {
    // lint: allow(alloc) — cold growth path, audited
    x.resize(4, 0.0);
}
";
        assert_eq!(lint_hot_path("rust/src/plan/mod.rs", src), vec![]);
        let unescaped = src.replace("// lint: allow(alloc) — cold growth path, audited", "");
        assert_eq!(lint_hot_path("rust/src/plan/mod.rs", &unescaped).len(), 1);
    }

    #[test]
    fn instant_now_is_banned_on_the_hot_path() {
        let src = "pub fn run_tasks(&self) {\n    let t = Instant::now();\n}\n";
        let findings = lint_hot_path("rust/src/util/threadpool.rs", src);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("Instant::now"));
    }

    #[test]
    fn version_rule_requires_single_declaration_and_constant_emission() {
        let dup = vec![
            ("a.rs".to_string(), "pub const SCHEMA_VERSION: u64 = 3;\n".to_string()),
            ("b.rs".to_string(), "pub const SCHEMA_VERSION: u64 = 4;\npub const PROTOCOL_VERSION: u64 = 1;\n".to_string()),
        ];
        let findings = lint_versions(&dup);
        assert!(
            findings.iter().any(|f| f.message.contains("exactly once")),
            "{findings:?}"
        );

        let hardcoded = vec![(
            "records.rs".to_string(),
            "pub const SCHEMA_VERSION: u64 = 3;\npub const PROTOCOL_VERSION: u64 = 1;\n\
             obj.insert(\"__version__\".into(), Json::Num(3.0));\n"
                .to_string(),
        )];
        let findings = lint_versions(&hardcoded);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("SCHEMA_VERSION"));

        let through_const = vec![(
            "records.rs".to_string(),
            "pub const SCHEMA_VERSION: u64 = 3;\npub const PROTOCOL_VERSION: u64 = 1;\n\
             obj.insert(\"__version__\".into(), Json::Num(SCHEMA_VERSION as f64));\n"
                .to_string(),
        )];
        assert_eq!(lint_versions(&through_const), vec![]);
    }

    #[test]
    fn bench_gate_rule_catches_unlisted_emitters() {
        let benches = vec![(
            "rust/benches/new_bench.rs".to_string(),
            "fs::write(\"BENCH_new.json\", line)?;\n".to_string(),
        )];
        let ci_without = "- run: cargo bench --no-run";
        let findings = lint_bench_gate(&benches, ci_without);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, "bench-gate");
        let ci_with = "- run: cargo bench --no-run --bench new_bench";
        assert_eq!(lint_bench_gate(&benches, ci_with), vec![]);
    }

    // ---- the acceptance-criteria demonstrations against the real tree ----

    #[test]
    fn real_tree_passes_every_rule() {
        let findings = lint_tree(&repo_root()).expect("tree must be readable");
        assert!(
            findings.is_empty(),
            "pfp-lint found {} violation(s):\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn injecting_vec_new_into_plan_execute_fails() {
        let path = repo_root().join("rust/src/plan/mod.rs");
        let content = fs::read_to_string(path).expect("plan/mod.rs must exist");
        assert_eq!(
            lint_hot_path("rust/src/plan/mod.rs", &content),
            vec![],
            "the real execute path must be clean"
        );
        // `ws.ensure(` is the unique call inside `execute`'s body
        assert_eq!(content.matches("ws.ensure(").count(), 1);
        let sabotaged = content.replace(
            "ws.ensure(",
            "let _leak: Vec<f32> = Vec::new();\n        ws.ensure(",
        );
        let findings = lint_hot_path("rust/src/plan/mod.rs", &sabotaged);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("Vec::"));
    }

    #[test]
    fn deleting_any_real_safety_comment_fails() {
        let path = repo_root().join("rust/src/util/threadpool.rs");
        let content = fs::read_to_string(path).expect("threadpool.rs must exist");
        assert_eq!(lint_safety("rust/src/util/threadpool.rs", &content), vec![]);
        // neuter every SAFETY justification: each unsafe site must now trip
        let sabotaged = content.replace("SAFETY:", "NOTE:");
        assert_ne!(content, sabotaged, "threadpool.rs must contain SAFETY comments");
        let findings = lint_safety("rust/src/util/threadpool.rs", &sabotaged);
        assert!(!findings.is_empty());
    }
}
