//! Minimal NPY/NPZ reader (ndarray-npy is not in the offline crate set).
//!
//! Supports what `numpy.savez{,_compressed}` emits for this repo's
//! artifacts: little-endian `f32` / `i32` / `i64` C-contiguous arrays,
//! NPY format 1.0/2.0, stored or deflated zip members.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// A loaded array: f32 data (integer types are converted) + shape.
#[derive(Clone, Debug)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
    /// original dtype descriptor, e.g. "<f4"
    pub dtype: String,
}

impl NpyArray {
    pub fn into_tensor(self) -> Result<Tensor> {
        Tensor::new(self.shape, self.data)
    }
}

/// Parse one `.npy` payload.
pub fn parse_npy(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        return Err(Error::Npz("not an NPY payload".into()));
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        ),
        2 | 3 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12usize,
        ),
        v => return Err(Error::Npz(format!("unsupported NPY version {v}"))),
    };
    let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])
        .map_err(|_| Error::Npz("bad NPY header encoding".into()))?;

    let dtype = extract_quoted(header, "descr")
        .ok_or_else(|| Error::Npz(format!("missing descr in header: {header}")))?;
    let fortran = header.contains("'fortran_order': True");
    if fortran {
        return Err(Error::Npz("fortran-order arrays not supported".into()));
    }
    let shape = extract_shape(header)
        .ok_or_else(|| Error::Npz(format!("missing shape in header: {header}")))?;
    let n: usize = shape.iter().product();
    let payload = &bytes[header_start + header_len..];

    let data = match dtype.as_str() {
        "<f4" => {
            if payload.len() < n * 4 {
                return Err(Error::Npz("truncated f4 payload".into()));
            }
            payload[..n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        "<i4" => payload[..n * 4]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
            .collect(),
        "<i8" => payload[..n * 8]
            .chunks_exact(8)
            .map(|c| {
                i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
            })
            .collect(),
        "<f8" => payload[..n * 8]
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
            })
            .collect(),
        d => return Err(Error::Npz(format!("unsupported dtype {d}"))),
    };
    Ok(NpyArray { shape, data, dtype })
}

fn extract_quoted(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)? + pat.len();
    let rest = &header[at..];
    let q0 = rest.find('\'')? + 1;
    let q1 = rest[q0..].find('\'')? + q0;
    Some(rest[q0..q1].to_string())
}

fn extract_shape(header: &str) -> Option<Vec<usize>> {
    let at = header.find("'shape':")? + "'shape':".len();
    let rest = &header[at..];
    let p0 = rest.find('(')? + 1;
    let p1 = rest[p0..].find(')')? + p0;
    let inner = &rest[p0..p1];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let t = part.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse().ok()?);
    }
    Some(out)
}

/// An NPZ archive loaded fully into memory.
pub struct Npz {
    arrays: BTreeMap<String, NpyArray>,
}

impl Npz {
    pub fn open(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .map_err(|e| Error::Npz(format!("open {}: {e}", path.display())))?;
        let mut zip = zip::ZipArchive::new(file)?;
        let mut arrays = BTreeMap::new();
        for i in 0..zip.len() {
            let mut entry = zip.by_index(i)?;
            let name = entry
                .name()
                .strip_suffix(".npy")
                .unwrap_or(entry.name())
                .to_string();
            let mut buf = Vec::with_capacity(entry.size() as usize);
            entry.read_to_end(&mut buf)?;
            arrays.insert(name, parse_npy(&buf)?);
        }
        Ok(Self { arrays })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.arrays.keys().map(|s| s.as_str())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.arrays.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Result<&NpyArray> {
        self.arrays
            .get(name)
            .ok_or_else(|| Error::Npz(format!("missing array '{name}'")))
    }

    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        self.get(name).cloned()?.into_tensor()
    }

    /// 1-D integer labels as i32.
    pub fn labels(&self, name: &str) -> Result<Vec<i32>> {
        Ok(self.get(name)?.data.iter().map(|&v| v as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_npy_f32(shape: &[usize], data: &[f32]) -> Vec<u8> {
        let shape_str = match shape.len() {
            1 => format!("({},)", shape[0]),
            _ => format!(
                "({})",
                shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
        );
        let total = 10 + header.len();
        let pad = (64 - total % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');
        // fix padding so total is aligned; rewrite length
        let mut out = Vec::new();
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parse_f32_npy() {
        let bytes = make_npy_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, vec![2, 3]);
        assert_eq!(arr.data, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(arr.dtype, "<f4");
    }

    #[test]
    fn parse_scalar_shape() {
        let bytes = make_npy_f32(&[], &[7.0]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, Vec::<usize>::new());
        assert_eq!(arr.data, vec![7.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_npy(b"not numpy").is_err());
    }

    #[test]
    fn reads_real_npz_when_artifacts_exist() {
        // Integration-grade check against the python-written archive.
        let dir = crate::artifacts_dir();
        let path = dir.join("weights_mlp.npz");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let npz = Npz::open(&path).unwrap();
        let w = npz.tensor("l0_w_mu").unwrap();
        assert_eq!(w.shape(), &[100, 784]);
        let sig = npz.tensor("l0_w_sigma").unwrap();
        assert!(sig.data().iter().all(|&s| s > 0.0));
    }
}
