//! Minimal NPY/NPZ reader (ndarray-npy is not in the offline crate set).
//!
//! Supports what `numpy.savez{,_compressed}` emits for this repo's
//! artifacts: little-endian `f32` / `i32` / `i64` C-contiguous arrays,
//! NPY format 1.0/2.0, stored or deflated zip members.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// A loaded array: f32 data (integer types are converted) + shape.
#[derive(Clone, Debug)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
    /// original dtype descriptor, e.g. "<f4"
    pub dtype: String,
}

impl NpyArray {
    pub fn into_tensor(self) -> Result<Tensor> {
        Tensor::new(self.shape, self.data)
    }
}

/// Parsed NPY header: shape, dtype descriptor, and the byte offset of the
/// raw data within the `.npy` payload.
#[derive(Clone, Debug)]
pub struct NpyHeader {
    pub shape: Vec<usize>,
    pub dtype: String,
    pub data_off: usize,
}

/// Parse just the header of one `.npy` payload (no data copy).
pub fn parse_npy_header(bytes: &[u8]) -> Result<NpyHeader> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        return Err(Error::Npz("not an NPY payload".into()));
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        ),
        2 | 3 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12usize,
        ),
        v => return Err(Error::Npz(format!("unsupported NPY version {v}"))),
    };
    let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])
        .map_err(|_| Error::Npz("bad NPY header encoding".into()))?;

    let dtype = extract_quoted(header, "descr")
        .ok_or_else(|| Error::Npz(format!("missing descr in header: {header}")))?;
    let fortran = header.contains("'fortran_order': True");
    if fortran {
        return Err(Error::Npz("fortran-order arrays not supported".into()));
    }
    let shape = extract_shape(header)
        .ok_or_else(|| Error::Npz(format!("missing shape in header: {header}")))?;
    Ok(NpyHeader { shape, dtype, data_off: header_start + header_len })
}

/// Parse one `.npy` payload.
pub fn parse_npy(bytes: &[u8]) -> Result<NpyArray> {
    let hdr = parse_npy_header(bytes)?;
    let NpyHeader { shape, dtype, data_off } = hdr;
    let n: usize = shape.iter().product();
    let payload = &bytes[data_off..];

    let data = match dtype.as_str() {
        "<f4" => {
            if payload.len() < n * 4 {
                return Err(Error::Npz("truncated f4 payload".into()));
            }
            payload[..n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        "<i4" => payload[..n * 4]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
            .collect(),
        "<i8" => payload[..n * 8]
            .chunks_exact(8)
            .map(|c| {
                i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
            })
            .collect(),
        "<f8" => payload[..n * 8]
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
            })
            .collect(),
        d => return Err(Error::Npz(format!("unsupported dtype {d}"))),
    };
    Ok(NpyArray { shape, data, dtype })
}

fn extract_quoted(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)? + pat.len();
    let rest = &header[at..];
    let q0 = rest.find('\'')? + 1;
    let q1 = rest[q0..].find('\'')? + q0;
    Some(rest[q0..q1].to_string())
}

fn extract_shape(header: &str) -> Option<Vec<usize>> {
    let at = header.find("'shape':")? + "'shape':".len();
    let rest = &header[at..];
    let p0 = rest.find('(')? + 1;
    let p1 = rest[p0..].find(')')? + p0;
    let inner = &rest[p0..p1];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let t = part.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse().ok()?);
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// minimal ZIP container parsing (the `zip` crate is not in the offline
// crate set). `numpy.savez` writes *stored* (uncompressed) members, which
// is all the artifact pipeline produces; deflated members
// (`savez_compressed`) are rejected with a clear error.
// ---------------------------------------------------------------------------

fn le_u16(b: &[u8], at: usize) -> Option<u16> {
    Some(u16::from_le_bytes([*b.get(at)?, *b.get(at + 1)?]))
}

fn le_u32(b: &[u8], at: usize) -> Option<u32> {
    Some(u32::from_le_bytes([
        *b.get(at)?,
        *b.get(at + 1)?,
        *b.get(at + 2)?,
        *b.get(at + 3)?,
    ]))
}

const EOCD_SIG: u32 = 0x0605_4b50;
const CENTRAL_SIG: u32 = 0x0201_4b50;
const LOCAL_SIG: u32 = 0x0403_4b50;

/// Parse a ZIP archive's central directory and return the (name, payload)
/// pairs of its stored members.
fn zip_stored_members(bytes: &[u8]) -> Result<Vec<(String, &[u8])>> {
    Ok(zip_member_ranges(bytes)?
        .into_iter()
        .map(|(name, range)| (name, &bytes[range]))
        .collect())
}

/// Like [`zip_stored_members`] but returns byte ranges into the archive
/// instead of borrowed slices — what the mmap-backed store needs to keep
/// absolute offsets for alignment checks.
fn zip_member_ranges(bytes: &[u8]) -> Result<Vec<(String, std::ops::Range<usize>)>> {
    // EOCD record: scan backwards over the (possibly present) archive
    // comment; the record itself is 22 bytes.
    let eocd = (0..=bytes.len().saturating_sub(22))
        .rev()
        .find(|&i| le_u32(bytes, i) == Some(EOCD_SIG))
        .ok_or_else(|| Error::Npz("not a zip archive (no end-of-central-directory)".into()))?;
    let n_entries = le_u16(bytes, eocd + 10)
        .ok_or_else(|| Error::Npz("truncated EOCD".into()))? as usize;
    let mut at = le_u32(bytes, eocd + 16)
        .ok_or_else(|| Error::Npz("truncated EOCD".into()))? as usize;

    let mut out = Vec::with_capacity(n_entries);
    for _ in 0..n_entries {
        if le_u32(bytes, at) != Some(CENTRAL_SIG) {
            return Err(Error::Npz("bad central directory entry".into()));
        }
        let field = |off: usize| -> Result<usize> {
            le_u16(bytes, at + off)
                .map(|v| v as usize)
                .ok_or_else(|| Error::Npz("truncated central directory".into()))
        };
        let field32 = |off: usize| -> Result<usize> {
            le_u32(bytes, at + off)
                .map(|v| v as usize)
                .ok_or_else(|| Error::Npz("truncated central directory".into()))
        };
        let method = field(10)?;
        let csize = field32(20)?;
        let name_len = field(28)?;
        let extra_len = field(30)?;
        let comment_len = field(32)?;
        let local_off = field32(42)?;
        let name_bytes = bytes
            .get(at + 46..at + 46 + name_len)
            .ok_or_else(|| Error::Npz("truncated member name".into()))?;
        let name = String::from_utf8_lossy(name_bytes).into_owned();
        if method != 0 {
            return Err(Error::Npz(format!(
                "member '{name}' is compressed (method {method}); only stored \
                 members are supported — write artifacts with np.savez, not \
                 np.savez_compressed"
            )));
        }
        // local header: sizes can lag behind the central directory when a
        // data descriptor is used, so take lengths from the central record
        if le_u32(bytes, local_off) != Some(LOCAL_SIG) {
            return Err(Error::Npz(format!("member '{name}': bad local header")));
        }
        let lname = le_u16(bytes, local_off + 26)
            .ok_or_else(|| Error::Npz("truncated local header".into()))?
            as usize;
        let lextra = le_u16(bytes, local_off + 28)
            .ok_or_else(|| Error::Npz("truncated local header".into()))?
            as usize;
        let start = local_off + 30 + lname + lextra;
        if bytes.get(start..start + csize).is_none() {
            return Err(Error::Npz(format!("member '{name}': truncated payload")));
        }
        out.push((name, start..start + csize));
        at += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

/// An NPZ archive loaded fully into memory.
pub struct Npz {
    arrays: BTreeMap<String, NpyArray>,
}

impl Npz {
    pub fn open(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Npz(format!("open {}: {e}", path.display())))?;
        let mut arrays = BTreeMap::new();
        for (member, payload) in zip_stored_members(&bytes)? {
            let name = member.strip_suffix(".npy").unwrap_or(&member).to_string();
            arrays.insert(name, parse_npy(payload)?);
        }
        Ok(Self { arrays })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.arrays.keys().map(|s| s.as_str())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.arrays.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Result<&NpyArray> {
        self.arrays
            .get(name)
            .ok_or_else(|| Error::Npz(format!("missing array '{name}'")))
    }

    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        self.get(name).cloned()?.into_tensor()
    }

    /// 1-D integer labels as i32.
    pub fn labels(&self, name: &str) -> Result<Vec<i32>> {
        Ok(self.get(name)?.data.iter().map(|&v| v as i32).collect())
    }
}

// ---------------------------------------------------------------------------
// mmap-backed NPZ store
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit hash — the registry's cheap content checksum for weight
/// archives (no crypto needed, just change detection surfaced in `models`).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct MappedMember {
    header: NpyHeader,
    /// absolute byte range of the `.npy` payload within the archive
    range: std::ops::Range<usize>,
    /// the `<f4` data window is 4-byte aligned in the mapping and the
    /// target is little-endian → eligible for zero-copy reinterpretation
    zero_copy: bool,
}

/// An NPZ archive backed by a shared memory mapping. Aligned
/// little-endian `<f4` members become zero-copy [`Tensor::mapped`] views;
/// everything else (misaligned payloads — the usual case for
/// `numpy.savez` output, see [`repack_aligned`] — or non-f32 dtypes)
/// falls back to the same copying decode as [`Npz`], bit-identical either
/// way.
pub struct MappedNpz {
    region: Arc<MappedFile>,
    members: BTreeMap<String, MappedMember>,
    checksum: u64,
}

use std::sync::Arc;

use crate::util::mmap::MappedFile;

impl MappedNpz {
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, true)
    }

    /// `use_mmap: false` forces the heap fallback (`--no-mmap`); member
    /// decoding logic is identical.
    pub fn open_with(path: &Path, use_mmap: bool) -> Result<Self> {
        let region = Arc::new(MappedFile::open_with(path, use_mmap)?);
        let bytes = region.bytes();
        let checksum = fnv1a(bytes);
        let base = bytes.as_ptr() as usize;
        let mut members = BTreeMap::new();
        for (member, range) in zip_member_ranges(bytes)? {
            let name = member.strip_suffix(".npy").unwrap_or(&member).to_string();
            let header = parse_npy_header(&bytes[range.clone()])?;
            let data_addr = base + range.start + header.data_off;
            let zero_copy = header.dtype == "<f4"
                && cfg!(target_endian = "little")
                && data_addr % std::mem::align_of::<f32>() == 0;
            members.insert(name, MappedMember { header, range, zero_copy });
        }
        Ok(Self { region, members, checksum })
    }

    /// FNV-1a of the whole archive.
    pub fn checksum(&self) -> u64 {
        self.checksum
    }

    /// Whether the file is held by a live mmap (vs the heap fallback).
    pub fn is_mapped(&self) -> bool {
        self.region.is_mapped()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.members.keys().map(|s| s.as_str())
    }

    /// Members served zero-copy straight out of the mapping.
    pub fn zero_copy_members(&self) -> Vec<&str> {
        self.members
            .iter()
            .filter(|(_, m)| m.zero_copy)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// Members that go through the copying decode path.
    pub fn copied_members(&self) -> Vec<&str> {
        self.members
            .iter()
            .filter(|(_, m)| !m.zero_copy)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let m = self
            .members
            .get(name)
            .ok_or_else(|| Error::Npz(format!("missing array '{name}'")))?;
        if m.zero_copy {
            let off = m.range.start + m.header.data_off;
            if let Some(t) =
                Tensor::mapped(m.header.shape.clone(), self.region.clone(), off)
            {
                return Ok(t);
            }
        }
        parse_npy(&self.region.bytes()[m.range.clone()])?.into_tensor()
    }
}

// ---------------------------------------------------------------------------
// aligned stored-zip writer + repack
// ---------------------------------------------------------------------------

/// Serialize one f32 array as a `.npy` payload whose header is padded so
/// the data starts at a multiple of 64 bytes from the payload start —
/// numpy's own convention (`numpy.lib.format` pads to
/// `ARRAY_ALIGN = 64`).
pub fn write_npy_f32(shape: &[usize], data: &[f32]) -> Vec<u8> {
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header =
        format!("{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}");
    // total = magic(8) + len(2) + header + '\n', padded to 64
    let total = 10 + header.len() + 1;
    let pad = (64 - total % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut out = Vec::with_capacity(10 + header.len() + data.len() * 4);
    out.extend_from_slice(b"\x93NUMPY\x01\x00");
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    debug_assert_eq!(out.len() % 64, data.len() * 4 % 64);
    out
}

/// Write a stored (uncompressed) zip whose member payloads start at
/// 64-byte-aligned archive offsets, using local-header extra-field
/// padding. Combined with [`write_npy_f32`]'s 64-padded headers, every
/// f32 data window lands 64-byte aligned — the condition for
/// [`MappedNpz`]'s zero-copy path.
pub fn write_aligned_stored_zip(path: &Path, members: &[(String, Vec<u8>)]) -> Result<()> {
    const ALIGN: usize = 64;
    let mut out: Vec<u8> = Vec::new();
    let mut centrals: Vec<Vec<u8>> = Vec::new();
    for (name, payload) in members {
        let local_off = out.len();
        // the member's *data* (past the npy header, when it parses as
        // npy) must land on an ALIGN boundary: payload starts at
        // local_off + 30 + name + extra; pick extra so payload_start +
        // anchor is ALIGN-aligned. An extra field needs >= 4 bytes for
        // its (id, size) header, so bump short pads by one alignment
        // unit.
        let anchor = parse_npy_header(payload).map(|h| h.data_off).unwrap_or(0);
        let base = local_off + 30 + name.len() + anchor;
        let mut pad = (ALIGN - base % ALIGN) % ALIGN;
        if pad > 0 && pad < 4 {
            pad += ALIGN;
        }
        let mut extra = Vec::new();
        if pad > 0 {
            extra.extend_from_slice(&0x5050_u16.to_le_bytes()); // "PP" pad id
            extra.extend_from_slice(&((pad - 4) as u16).to_le_bytes());
            extra.resize(pad, 0);
        }
        out.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        out.extend_from_slice(&[20, 0, 0, 0, 0, 0]); // version, flags, method=0
        out.extend_from_slice(&[0, 0, 0, 0]); // mod time/date
        out.extend_from_slice(&0u32.to_le_bytes()); // crc (unchecked by this reader)
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(&(extra.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&extra);
        debug_assert_eq!((out.len() + anchor) % ALIGN, 0);
        out.extend_from_slice(payload);

        let mut c = Vec::new();
        c.extend_from_slice(&CENTRAL_SIG.to_le_bytes());
        c.extend_from_slice(&[20, 0, 20, 0, 0, 0, 0, 0]);
        c.extend_from_slice(&[0, 0, 0, 0]);
        c.extend_from_slice(&0u32.to_le_bytes());
        c.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        c.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        c.extend_from_slice(&(name.len() as u16).to_le_bytes());
        c.extend_from_slice(&0u16.to_le_bytes()); // extra (central)
        c.extend_from_slice(&0u16.to_le_bytes()); // comment
        c.extend_from_slice(&0u16.to_le_bytes()); // disk
        c.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
        c.extend_from_slice(&0u32.to_le_bytes()); // external attrs
        c.extend_from_slice(&(local_off as u32).to_le_bytes());
        c.extend_from_slice(name.as_bytes());
        centrals.push(c);
    }
    let cd_off = out.len() as u32;
    for c in &centrals {
        out.extend_from_slice(c);
    }
    let cd_len = out.len() as u32 - cd_off;
    out.extend_from_slice(&EOCD_SIG.to_le_bytes());
    out.extend_from_slice(&[0, 0, 0, 0]);
    out.extend_from_slice(&(members.len() as u16).to_le_bytes());
    out.extend_from_slice(&(members.len() as u16).to_le_bytes());
    out.extend_from_slice(&cd_len.to_le_bytes());
    out.extend_from_slice(&cd_off.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    std::fs::write(path, &out)
        .map_err(|e| Error::Npz(format!("write {}: {e}", path.display())))?;
    Ok(())
}

/// Save f32 tensors as an aligned NPZ every member of which qualifies for
/// [`MappedNpz`]'s zero-copy path. Used by the registry tests and by
/// [`repack_aligned`].
pub fn save_npz(path: &Path, entries: &[(&str, &Tensor)]) -> Result<()> {
    let members: Vec<(String, Vec<u8>)> = entries
        .iter()
        .map(|(name, t)| {
            (format!("{name}.npy"), write_npy_f32(t.shape(), t.data()))
        })
        .collect();
    write_aligned_stored_zip(path, &members)
}

/// Repack a stored NPZ so every member payload starts 64-byte aligned
/// (member bytes preserved verbatim when already 64-padded `.npy`, else
/// re-serialized). `numpy.savez` output is misaligned by its zip layout;
/// run weights through this once to unlock genuine zero-copy serving.
pub fn repack_aligned(src: &Path, dst: &Path) -> Result<()> {
    let bytes = std::fs::read(src)
        .map_err(|e| Error::Npz(format!("open {}: {e}", src.display())))?;
    let members: Vec<(String, Vec<u8>)> = zip_stored_members(&bytes)?
        .into_iter()
        .map(|(name, payload)| (name, payload.to_vec()))
        .collect();
    write_aligned_stored_zip(dst, &members)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_npy_f32(shape: &[usize], data: &[f32]) -> Vec<u8> {
        let shape_str = match shape.len() {
            1 => format!("({},)", shape[0]),
            _ => format!(
                "({})",
                shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
        );
        let total = 10 + header.len();
        let pad = (64 - total % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');
        // fix padding so total is aligned; rewrite length
        let mut out = Vec::new();
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn parse_f32_npy() {
        let bytes = make_npy_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, vec![2, 3]);
        assert_eq!(arr.data, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(arr.dtype, "<f4");
    }

    #[test]
    fn parse_scalar_shape() {
        let bytes = make_npy_f32(&[], &[7.0]);
        let arr = parse_npy(&bytes).unwrap();
        assert_eq!(arr.shape, Vec::<usize>::new());
        assert_eq!(arr.data, vec![7.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_npy(b"not numpy").is_err());
    }

    /// Assemble a minimal stored-member zip archive (local headers +
    /// central directory + EOCD), byte-compatible with `numpy.savez`.
    fn make_stored_zip(members: &[(&str, &[u8])]) -> Vec<u8> {
        let mut out = Vec::new();
        let mut centrals = Vec::new();
        for (name, payload) in members {
            let local_off = out.len() as u32;
            // local file header
            out.extend_from_slice(&0x0403_4b50u32.to_le_bytes());
            out.extend_from_slice(&[20, 0, 0, 0, 0, 0]); // version, flags, method=0
            out.extend_from_slice(&[0, 0, 0, 0]); // mod time/date
            out.extend_from_slice(&0u32.to_le_bytes()); // crc (unchecked)
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(&0u16.to_le_bytes()); // extra len
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(payload);

            // matching central directory record
            let mut c = Vec::new();
            c.extend_from_slice(&0x0201_4b50u32.to_le_bytes());
            c.extend_from_slice(&[20, 0, 20, 0, 0, 0, 0, 0]); // versions, flags, method=0
            c.extend_from_slice(&[0, 0, 0, 0]); // mod time/date
            c.extend_from_slice(&0u32.to_le_bytes()); // crc
            c.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            c.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            c.extend_from_slice(&(name.len() as u16).to_le_bytes());
            c.extend_from_slice(&0u16.to_le_bytes()); // extra
            c.extend_from_slice(&0u16.to_le_bytes()); // comment
            c.extend_from_slice(&0u16.to_le_bytes()); // disk
            c.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
            c.extend_from_slice(&0u32.to_le_bytes()); // external attrs
            c.extend_from_slice(&local_off.to_le_bytes());
            c.extend_from_slice(name.as_bytes());
            centrals.push(c);
        }
        let cd_off = out.len() as u32;
        for c in &centrals {
            out.extend_from_slice(c);
        }
        let cd_len = out.len() as u32 - cd_off;
        // EOCD
        out.extend_from_slice(&0x0605_4b50u32.to_le_bytes());
        out.extend_from_slice(&[0, 0, 0, 0]); // disk numbers
        out.extend_from_slice(&(members.len() as u16).to_le_bytes());
        out.extend_from_slice(&(members.len() as u16).to_le_bytes());
        out.extend_from_slice(&cd_len.to_le_bytes());
        out.extend_from_slice(&cd_off.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // comment len
        out
    }

    #[test]
    fn stored_zip_roundtrip() {
        let a = make_npy_f32(&[2, 2], &[1., 2., 3., 4.]);
        let b = make_npy_f32(&[3], &[5., 6., 7.]);
        let zip = make_stored_zip(&[("a.npy", &a), ("b.npy", &b)]);
        let members = zip_stored_members(&zip).unwrap();
        assert_eq!(members.len(), 2);
        assert_eq!(members[0].0, "a.npy");
        let arr = parse_npy(members[0].1).unwrap();
        assert_eq!(arr.shape, vec![2, 2]);
        assert_eq!(arr.data, vec![1., 2., 3., 4.]);
        let arr_b = parse_npy(members[1].1).unwrap();
        assert_eq!(arr_b.data, vec![5., 6., 7.]);
    }

    #[test]
    fn compressed_member_rejected_with_hint() {
        let a = make_npy_f32(&[1], &[1.0]);
        let mut zip = make_stored_zip(&[("a.npy", &a)]);
        // flip the central-directory method field (offset 10 into the
        // record) to 8 (deflate)
        let cd_off = zip.len() - 22 - (46 + "a.npy".len());
        zip[cd_off + 10] = 8;
        let err = zip_stored_members(&zip).unwrap_err();
        assert!(err.to_string().contains("savez_compressed"), "{err}");
    }

    #[test]
    fn garbage_zip_rejected() {
        assert!(zip_stored_members(b"PK but not really").is_err());
        assert!(zip_stored_members(b"").is_err());
    }

    #[test]
    fn reads_real_npz_when_artifacts_exist() {
        // Integration-grade check against the python-written archive.
        let dir = crate::artifacts_dir();
        let path = dir.join("weights_mlp.npz");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let npz = Npz::open(&path).unwrap();
        let w = npz.tensor("l0_w_mu").unwrap();
        assert_eq!(w.shape(), &[100, 784]);
        let sig = npz.tensor("l0_w_sigma").unwrap();
        assert!(sig.data().iter().all(|&s| s > 0.0));
    }

    // ---- mmap-backed store ----------------------------------------------

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pfp_npz_{}_{name}", std::process::id()))
    }

    #[test]
    fn aligned_npz_serves_zero_copy_and_matches_vec_loader() {
        let a = Tensor::new(vec![3, 5], (0..15).map(|i| i as f32 * 0.5).collect()).unwrap();
        let b = Tensor::from_vec(vec![-1.0, 2.5, 7.0]);
        let path = tmp("aligned.npz");
        save_npz(&path, &[("a", &a), ("b", &b)]).unwrap();

        let mapped = MappedNpz::open(&path).unwrap();
        // every member qualifies for zero-copy in an aligned archive
        assert_eq!(mapped.copied_members().len(), 0, "{:?}", mapped.copied_members());
        assert_eq!(mapped.zero_copy_members().len(), 2);
        let ta = mapped.tensor("a").unwrap();
        if mapped.is_mapped() {
            assert!(ta.is_mapped(), "aligned member should be served zero-copy");
        }
        // bit-identical to the read-into-Vec loader
        let vec_npz = Npz::open(&path).unwrap();
        assert_eq!(ta, vec_npz.tensor("a").unwrap());
        assert_eq!(mapped.tensor("b").unwrap(), vec_npz.tensor("b").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn misaligned_npz_falls_back_to_copy_bit_identical() {
        // the legacy test helper emits numpy-savez-style misaligned
        // members (data offset ≡ 1 mod 4 for these names)
        let a = make_npy_f32(&[2, 2], &[1.5, -2.5, 3.25, 4.0]);
        let zip = make_stored_zip(&[("w.npy", &a)]);
        let path = tmp("misaligned.npz");
        std::fs::write(&path, &zip).unwrap();

        let mapped = MappedNpz::open(&path).unwrap();
        assert_eq!(mapped.zero_copy_members().len(), 0);
        assert_eq!(mapped.copied_members(), vec!["w"]);
        let t = mapped.tensor("w").unwrap();
        assert!(!t.is_mapped());
        assert_eq!(t, Npz::open(&path).unwrap().tensor("w").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn repack_aligned_unlocks_zero_copy() {
        let a = make_npy_f32(&[4], &[1.0, 2.0, 3.0, 4.0]);
        let zip = make_stored_zip(&[("x.npy", &a)]);
        let src = tmp("repack_src.npz");
        let dst = tmp("repack_dst.npz");
        std::fs::write(&src, &zip).unwrap();
        assert_eq!(MappedNpz::open(&src).unwrap().zero_copy_members().len(), 0);

        repack_aligned(&src, &dst).unwrap();
        let mapped = MappedNpz::open(&dst).unwrap();
        assert_eq!(mapped.zero_copy_members(), vec!["x"]);
        let t = mapped.tensor("x").unwrap();
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t, Npz::open(&src).unwrap().tensor("x").unwrap());
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn no_mmap_flag_forces_heap_and_stays_identical() {
        let a = Tensor::from_vec(vec![9.0, 8.0, 7.0]);
        let path = tmp("nommap.npz");
        save_npz(&path, &[("a", &a)]).unwrap();
        let heap = MappedNpz::open_with(&path, false).unwrap();
        assert!(!heap.is_mapped());
        let mapped = MappedNpz::open(&path).unwrap();
        assert_eq!(heap.tensor("a").unwrap(), mapped.tensor("a").unwrap());
        assert_eq!(heap.checksum(), mapped.checksum());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checksum_detects_content_change() {
        let p1 = tmp("sum1.npz");
        let p2 = tmp("sum2.npz");
        save_npz(&p1, &[("a", &Tensor::from_vec(vec![1.0]))]).unwrap();
        save_npz(&p2, &[("a", &Tensor::from_vec(vec![2.0]))]).unwrap();
        let c1 = MappedNpz::open(&p1).unwrap().checksum();
        let c2 = MappedNpz::open(&p2).unwrap().checksum();
        assert_ne!(c1, c2);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn mmap_matches_vec_loader_on_golden_npz() {
        // acceptance criterion: mmap-backed loading is bit-identical to
        // the Vec-based loader on the python-trained golden archive.
        let dir = crate::artifacts_dir();
        let path = dir.join("weights_mlp.npz");
        if !path.exists() {
            eprintln!("skipping: {} not built", path.display());
            return;
        }
        let vec_npz = Npz::open(&path).unwrap();
        let mapped = MappedNpz::open(&path).unwrap();
        let names: Vec<String> = vec_npz.names().map(|s| s.to_string()).collect();
        assert!(!names.is_empty());
        for name in &names {
            let a = vec_npz.tensor(name).unwrap();
            let b = mapped.tensor(name).unwrap();
            assert_eq!(a, b, "member {name} differs between loaders");
        }
    }
}
