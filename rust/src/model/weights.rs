//! Posterior weight store: the SVI-trained mean-field Gaussians exported
//! by `python/compile/train.py` (`weights_{arch}.npz`), plus the derived
//! views the operators need:
//!
//! * the paper's **calibration factor** `c` is applied here once:
//!   `w_var = c * sigma^2`;
//! * `w_e2 = mu^2 + w_var` is **precomputed** for all non-first compute
//!   layers (the paper's "weight variance information can be stored
//!   directly as second raw moments" optimization — Section 5);
//! * the first layer keeps its variances (Eq. 13 needs them).

use std::path::Path;

use crate::error::Result;
use crate::tensor::Tensor;

use super::npz::Npz;
use super::Arch;

/// Per-compute-layer posterior + derived tensors.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub w_mu: Tensor,
    pub w_sigma: Tensor,
    /// calibrated variance: c * sigma^2
    pub w_var: Tensor,
    /// precomputed second raw moment: mu^2 + w_var
    pub w_e2: Tensor,
    pub b_mu: Tensor,
    pub b_sigma: Tensor,
    pub b_var: Tensor,
}

impl LayerWeights {
    pub fn from_posterior(
        w_mu: Tensor,
        w_sigma: Tensor,
        b_mu: Tensor,
        b_sigma: Tensor,
        calib: f32,
    ) -> Self {
        let w_var = w_sigma.map(|s| calib * s * s);
        let w_e2 = w_mu.zip(&w_var, |m, v| m * m + v).unwrap();
        let b_var = b_sigma.map(|s| calib * s * s);
        Self { w_mu, w_sigma, w_var, w_e2, b_mu, b_sigma, b_var }
    }

    pub fn n_params(&self) -> usize {
        self.w_mu.len() + self.b_mu.len()
    }
}

/// Quantize one weight tensor to `prec` storage bits (round-to-nearest-
/// even, matching the F16C/AVX2 hardware narrowing bit for bit). This is
/// the mixed-precision conversion applied **once** when a plan binds a
/// non-f32 schedule — the mmap'd f32 NPZ members stay untouched; the
/// packed copy lives in the compiled plan (and is counted in the registry
/// metadata as `packed_tensors`). Returns `None` for f32: the plan keeps
/// borrowing the original tensor and no copy exists at all.
pub fn pack_tensor(
    t: &Tensor,
    prec: crate::util::half::Precision,
) -> Option<std::sync::Arc<Vec<u16>>> {
    use crate::util::half::{narrow, Precision};
    if prec == Precision::F32 {
        return None;
    }
    Some(std::sync::Arc::new(
        t.data().iter().map(|&x| narrow(prec, x)).collect(),
    ))
}

/// All compute-layer weights of one architecture.
#[derive(Clone, Debug)]
pub struct PosteriorWeights {
    pub arch_name: String,
    pub calibration_factor: f32,
    pub layers: Vec<LayerWeights>,
}

/// A posterior loaded through the mmap-backed store, plus the registry
/// metadata the loader derives along the way.
#[derive(Clone, Debug)]
pub struct LoadedWeights {
    pub weights: PosteriorWeights,
    /// FNV-1a of the archive bytes (change detection in `models`).
    pub checksum: u64,
    /// file is held by a live mmap (vs the heap fallback)
    pub mapped: bool,
    /// members served zero-copy straight out of the mapping
    pub zero_copy_members: usize,
    /// members decoded through the copy fallback
    pub copied_members: usize,
}

impl PosteriorWeights {
    /// Load `weights_{arch}.npz` and apply the calibration factor.
    pub fn load(dir: &Path, arch: &Arch, calib: f32) -> Result<Self> {
        let npz = Npz::open(&dir.join(format!("weights_{}.npz", arch.name)))?;
        let mut layers = Vec::new();
        for (i, _) in arch.compute_layers().iter().enumerate() {
            layers.push(LayerWeights::from_posterior(
                npz.tensor(&format!("l{i}_w_mu"))?,
                npz.tensor(&format!("l{i}_w_sigma"))?,
                npz.tensor(&format!("l{i}_b_mu"))?,
                npz.tensor(&format!("l{i}_b_sigma"))?,
                calib,
            ));
        }
        Ok(Self {
            arch_name: arch.name.clone(),
            calibration_factor: calib,
            layers,
        })
    }

    /// Load an arbitrary weight archive through [`MappedNpz`]: aligned
    /// `<f4` members stay zero-copy views into the mapping (the derived
    /// `w_var`/`w_e2` tensors are always owned), everything else decodes
    /// through the bit-identical copy fallback. `use_mmap: false` forces
    /// the heap path (`--no-mmap`).
    pub fn load_mapped(
        path: &Path,
        arch: &Arch,
        calib: f32,
        use_mmap: bool,
    ) -> Result<LoadedWeights> {
        let npz = super::npz::MappedNpz::open_with(path, use_mmap)?;
        let mut layers = Vec::new();
        for (i, _) in arch.compute_layers().iter().enumerate() {
            layers.push(LayerWeights::from_posterior(
                npz.tensor(&format!("l{i}_w_mu"))?,
                npz.tensor(&format!("l{i}_w_sigma"))?,
                npz.tensor(&format!("l{i}_b_mu"))?,
                npz.tensor(&format!("l{i}_b_sigma"))?,
                calib,
            ));
        }
        Ok(LoadedWeights {
            weights: PosteriorWeights {
                arch_name: arch.name.clone(),
                calibration_factor: calib,
                layers,
            },
            checksum: npz.checksum(),
            mapped: npz.is_mapped(),
            zero_copy_members: npz.zero_copy_members().len(),
            copied_members: npz.copied_members().len(),
        })
    }

    /// Write this posterior as an aligned NPZ ([`save_npz`]-format) that
    /// [`load_mapped`](Self::load_mapped) can serve zero-copy. Note the
    /// raw `sigma` tensors are stored (calibration is re-applied on
    /// load).
    pub fn save_npz(&self, path: &Path) -> Result<()> {
        let mut entries = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            entries.push((format!("l{i}_w_mu"), &l.w_mu));
            entries.push((format!("l{i}_w_sigma"), &l.w_sigma));
            entries.push((format!("l{i}_b_mu"), &l.b_mu));
            entries.push((format!("l{i}_b_sigma"), &l.b_sigma));
        }
        let borrowed: Vec<(&str, &Tensor)> =
            entries.iter().map(|(n, t)| (n.as_str(), *t)).collect();
        super::npz::save_npz(path, &borrowed)
    }

    /// Re-apply a different calibration factor (for the sweep).
    pub fn recalibrate(&self, calib: f32) -> Self {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                LayerWeights::from_posterior(
                    l.w_mu.clone(),
                    l.w_sigma.clone(),
                    l.b_mu.clone(),
                    l.b_sigma.clone(),
                    calib,
                )
            })
            .collect();
        Self {
            arch_name: self.arch_name.clone(),
            calibration_factor: calib,
            layers,
        }
    }

    /// Synthetic random posterior (tests / benches without artifacts).
    pub fn synthetic(arch: &Arch, seed: u64) -> Self {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(seed);
        let mut layers = Vec::new();
        for spec in arch.compute_layers() {
            let (wshape, bshape, fan_in) = match spec {
                super::LayerSpec::Dense { d_in, d_out } => {
                    (vec![*d_out, *d_in], vec![*d_out], *d_in)
                }
                super::LayerSpec::Conv { in_ch, out_ch, k } => (
                    vec![*out_ch, *in_ch, *k, *k],
                    vec![*out_ch],
                    in_ch * k * k,
                ),
                _ => unreachable!(),
            };
            let std = (1.0 / fan_in as f32).sqrt();
            let wn: usize = wshape.iter().product();
            let bn = bshape[0];
            let mut w = vec![0.0f32; wn];
            rng.fill_normal(&mut w, 0.0, std);
            let mut ws = vec![0.0f32; wn];
            for v in ws.iter_mut() {
                *v = (0.3 * std * rng.uniform() as f32).max(1e-4);
            }
            let mut b = vec![0.0f32; bn];
            rng.fill_normal(&mut b, 0.0, 0.01);
            let bs = vec![0.01f32; bn];
            layers.push(LayerWeights::from_posterior(
                Tensor::new(wshape.clone(), w).unwrap(),
                Tensor::new(wshape, ws).unwrap(),
                Tensor::new(bshape.clone(), b).unwrap(),
                Tensor::new(bshape, bs).unwrap(),
                1.0,
            ));
        }
        Self {
            arch_name: arch.name.clone(),
            calibration_factor: 1.0,
            layers,
        }
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    #[test]
    fn synthetic_shapes_match_arch() {
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 1);
        assert_eq!(w.layers.len(), 5);
        assert_eq!(w.layers[0].w_mu.shape(), &[6, 1, 5, 5]);
        assert_eq!(w.layers[4].w_mu.shape(), &[10, 84]);
        assert!(w.n_params() > 60_000 / 2);
    }

    #[test]
    fn pack_tensor_is_elementwise_narrow() {
        use crate::util::half::{quantize, widen, Precision};
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 5);
        let t = &w.layers[0].w_mu;
        assert!(pack_tensor(t, Precision::F32).is_none(), "f32 never copies");
        for prec in [Precision::F16, Precision::Bf16] {
            let packed = pack_tensor(t, prec).unwrap();
            assert_eq!(packed.len(), t.len());
            for (bits, &x) in packed.iter().zip(t.data()) {
                // bit-exact vs the scalar reference conversion, and the
                // widened value is exactly the quantized weight the
                // packed kernels accumulate
                assert_eq!(widen(prec, *bits), quantize(prec, x));
            }
        }
    }

    #[test]
    fn calibration_scales_variance() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 2);
        let w2 = w.recalibrate(0.25);
        for (a, b) in w.layers.iter().zip(&w2.layers) {
            for (va, vb) in a.w_var.data().iter().zip(b.w_var.data()) {
                assert!((vb - 0.25 * va).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn e2_consistent_with_var() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 3);
        let l = &w.layers[0];
        for i in 0..16 {
            let want = l.w_mu.data()[i].powi(2) + l.w_var.data()[i];
            assert!((l.w_e2.data()[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn loads_trained_weights_when_present() {
        let dir = crate::artifacts_dir();
        if !dir.join("weights_mlp.npz").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let arch = Arch::mlp();
        let w = PosteriorWeights::load(&dir, &arch, 0.3).unwrap();
        assert_eq!(w.layers.len(), 3);
        assert_eq!(w.layers[0].w_mu.shape(), &[100, 784]);
        assert!((w.calibration_factor - 0.3).abs() < 1e-9);
    }

    #[test]
    fn save_and_load_mapped_roundtrip_bit_identical() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 7);
        let path = std::env::temp_dir()
            .join(format!("pfp_weights_rt_{}.npz", std::process::id()));
        w.save_npz(&path).unwrap();

        let loaded = PosteriorWeights::load_mapped(&path, &arch, 1.0, true).unwrap();
        assert_eq!(loaded.copied_members, 0, "aligned archive should be all zero-copy");
        assert_eq!(loaded.zero_copy_members, 4 * arch.compute_layers().len());
        for (a, b) in w.layers.iter().zip(&loaded.weights.layers) {
            assert_eq!(a.w_mu, b.w_mu);
            assert_eq!(a.w_sigma, b.w_sigma);
            assert_eq!(a.w_var, b.w_var);
            assert_eq!(a.w_e2, b.w_e2);
            assert_eq!(a.b_mu, b.b_mu);
        }

        // --no-mmap heap path: same bytes, same checksum, same tensors
        let heap = PosteriorWeights::load_mapped(&path, &arch, 1.0, false).unwrap();
        assert!(!heap.mapped);
        assert_eq!(heap.checksum, loaded.checksum);
        for (a, b) in heap.weights.layers.iter().zip(&loaded.weights.layers) {
            assert_eq!(a.w_mu, b.w_mu);
            assert_eq!(a.w_e2, b.w_e2);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_mapped_matches_vec_loader_on_golden_npz() {
        let dir = crate::artifacts_dir();
        let path = dir.join("weights_mlp.npz");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let arch = Arch::mlp();
        let vec_w = PosteriorWeights::load(&dir, &arch, 0.3).unwrap();
        let mapped = PosteriorWeights::load_mapped(&path, &arch, 0.3, true).unwrap();
        for (a, b) in vec_w.layers.iter().zip(&mapped.weights.layers) {
            assert_eq!(a.w_mu, b.w_mu);
            assert_eq!(a.w_sigma, b.w_sigma);
            assert_eq!(a.w_var, b.w_var);
            assert_eq!(a.w_e2, b.w_e2);
            assert_eq!(a.b_mu, b.b_mu);
            assert_eq!(a.b_var, b.b_var);
        }
    }
}
