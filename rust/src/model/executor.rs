//! Native graph executors: PFP (single probabilistic pass), deterministic,
//! and SVI (N sampled passes).
//!
//! The PFP executor implements the paper's representation discipline
//! exactly like `python/compile/model.py::pfp_forward` (the goldens
//! cross-check this): compute layers consume E[x^2] / produce variances,
//! ReLU consumes variances / produces E[x^2], max-pool is variance to
//! variance, and conversions are inserted (and *profiled*, as the paper's
//! "tooling" overhead) where representations disagree. The first compute
//! layer uses the Eq. 13 deterministic-input kernels.

use std::sync::Arc;

use crate::ops::conv::{pfp_conv2d_first_in, pfp_conv2d_joint_in, ConvArgs};
use crate::ops::dense::{pfp_dense_first_in, pfp_dense_joint_in, DenseArgs};
use crate::ops::det::{det_conv2d, det_dense, det_relu};
use crate::ops::maxpool::{
    det_maxpool2, pfp_maxpool2_vectorized_in, pfp_maxpool_generic,
};
use crate::ops::relu::pfp_relu_in;
use crate::ops::svi::sample_tensor;
use crate::ops::Schedule;
use crate::profiling::Profiler;
use crate::tensor::{ProbTensor, Rep, Tensor};
use crate::util::rng::SplitMix64;
use crate::util::threadpool::{self, ThreadPool};

use super::{Arch, LayerSpec, PosteriorWeights};

/// Per-operator-class schedule selection for a network, plus the shared
/// persistent worker pool every parallel operator dispatches onto.
#[derive(Clone, Debug)]
pub struct Schedules {
    pub dense: Schedule,
    pub conv: Schedule,
    /// vectorized k=2 pool (true) vs generic reduction (false) — Table 3.
    pub vectorized_pool: bool,
    pub relu_threads: usize,
    /// Worker tasks for the vectorized max-pool (1 = serial — Table 3's
    /// hand-vectorized row; >1 reproduces the "automatic schedule" row).
    pub maxpool_threads: usize,
    /// Persistent worker-pool handle. Defaults to the process-wide pool;
    /// the serving coordinator injects one shared handle per `Service` so
    /// every model lane and request reuses the same workers.
    pub pool: Arc<ThreadPool>,
}

impl Schedules {
    /// Untuned baseline (Table 2 row 1 / Table 3 "Generic, no tuning").
    pub fn baseline() -> Self {
        Self {
            dense: Schedule::baseline(),
            conv: Schedule::baseline(),
            vectorized_pool: false,
            relu_threads: 1,
            maxpool_threads: 1,
            pool: threadpool::global().clone(),
        }
    }

    /// Tuned configuration (what the tuner converges to on this host).
    pub fn tuned(threads: usize) -> Self {
        Self {
            dense: Schedule::tuned(threads),
            conv: Schedule::tuned(threads),
            vectorized_pool: true,
            relu_threads: 1,
            maxpool_threads: 1,
            pool: threadpool::global().clone(),
        }
    }

    /// Replace the worker-pool handle (the serving path shares one pool
    /// across all lanes).
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = pool;
        self
    }
}

impl Default for Schedules {
    fn default() -> Self {
        Self::tuned(1)
    }
}

/// Single-probabilistic-forward-pass executor.
pub struct PfpExecutor {
    pub arch: Arch,
    pub weights: PosteriorWeights,
    pub schedules: Schedules,
    pub profiler: Profiler,
}

impl PfpExecutor {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules) -> Self {
        assert_eq!(arch.compute_layers().len(), weights.layers.len());
        Self { arch, weights, schedules, profiler: Profiler::new(false) }
    }

    pub fn with_profiling(mut self) -> Self {
        self.profiler = Profiler::new(true);
        self
    }

    /// Run one probabilistic forward pass:
    /// input `[B, ...input_shape]` -> (mu `[B, classes]`, var `[B, classes]`).
    pub fn forward(&mut self, x: &Tensor) -> (Tensor, Tensor) {
        self.profiler.begin_pass();
        let labels = self.arch.layer_labels();
        let mut compute_idx = 0usize;
        let mut state: Option<ProbTensor> = None; // None until first compute layer
        let mut det_input: Option<Tensor> = Some(reshape_input(&self.arch, x));

        // The executor walks the layer list; the first compute layer takes
        // the raw deterministic input (Eq. 13 kernels).
        for (li, layer) in self.arch.layers.iter().enumerate() {
            let label = &labels[li];
            match layer {
                LayerSpec::Dense { .. } => {
                    let w = &self.weights.layers[compute_idx];
                    compute_idx += 1;
                    let sched = self.schedules.dense;
                    let pool = Arc::clone(&self.schedules.pool);
                    let next = if let Some(prob) = state.take() {
                        let prob = convert_rep(&mut self.profiler, prob, Rep::E2);
                        let prob = prob.flatten_2d();
                        let (mu, var) = self.profiler.record(label, "dense", || {
                            pfp_dense_joint_in(
                                &pool,
                                &DenseArgs {
                                    x_mu: &prob.mu,
                                    x_aux: &prob.aux,
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_e2,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        });
                        ProbTensor::new(mu, var, Rep::Var)
                    } else {
                        let x = det_input.take().expect("input consumed twice");
                        let x = x.flatten_2d();
                        let x_sq = x.squared();
                        let (mu, var) = self.profiler.record(label, "dense", || {
                            pfp_dense_first_in(
                                &pool,
                                &DenseArgs {
                                    x_mu: &x,
                                    x_aux: &x_sq,
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_var,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        });
                        ProbTensor::new(mu, var, Rep::Var)
                    };
                    state = Some(next);
                }
                LayerSpec::Conv { .. } => {
                    let w = &self.weights.layers[compute_idx];
                    compute_idx += 1;
                    let sched = self.schedules.conv;
                    let pool = Arc::clone(&self.schedules.pool);
                    let next = if let Some(prob) = state.take() {
                        let prob = convert_rep(&mut self.profiler, prob, Rep::E2);
                        self.profiler.record(label, "conv2d", || {
                            pfp_conv2d_joint_in(
                                &pool,
                                &prob,
                                &ConvArgs {
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_e2,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        })
                    } else {
                        let x = det_input.take().expect("input consumed twice");
                        self.profiler.record(label, "conv2d", || {
                            pfp_conv2d_first_in(
                                &pool,
                                &x,
                                &ConvArgs {
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_var,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        })
                    };
                    state = Some(next);
                }
                LayerSpec::Relu => {
                    let prob = state.take().expect("ReLU before first compute layer");
                    let prob = convert_rep(&mut self.profiler, prob, Rep::Var);
                    let threads = self.schedules.relu_threads;
                    let pool = Arc::clone(&self.schedules.pool);
                    state = Some(
                        self.profiler
                            .record(label, "relu", || pfp_relu_in(&pool, prob, threads)),
                    );
                }
                LayerSpec::MaxPool2 => {
                    let prob = state.take().expect("pool before first compute layer");
                    let prob = convert_rep(&mut self.profiler, prob, Rep::Var);
                    let vectorized = self.schedules.vectorized_pool;
                    let threads = self.schedules.maxpool_threads;
                    let pool = Arc::clone(&self.schedules.pool);
                    state = Some(self.profiler.record(label, "maxpool", || {
                        if vectorized {
                            pfp_maxpool2_vectorized_in(&pool, &prob, threads)
                        } else {
                            pfp_maxpool_generic(&prob, 2, 2)
                        }
                    }));
                }
                LayerSpec::Flatten => {
                    if let Some(prob) = state.take() {
                        state = Some(prob.flatten_2d());
                    } else if let Some(x) = det_input.take() {
                        det_input = Some(x.flatten_2d());
                    }
                }
            }
        }
        let out = state.expect("network produced no output").into_var();
        (out.mu, out.aux)
    }

}

/// Representation conversion, profiled as the paper's "tooling" overhead.
fn convert_rep(profiler: &mut Profiler, prob: ProbTensor, rep: Rep) -> ProbTensor {
    if prob.rep == rep {
        return prob;
    }
    profiler.record("Convert", "convert", || prob.to_rep(rep).0)
}

fn reshape_input(arch: &Arch, x: &Tensor) -> Tensor {
    let batch = x.dim(0);
    let mut shape = vec![batch];
    shape.extend_from_slice(&arch.input_shape);
    x.clone().reshape(shape).expect("input shape mismatch")
}

/// Deterministic executor (posterior means).
pub struct DetExecutor {
    pub arch: Arch,
    pub weights: PosteriorWeights,
    pub schedules: Schedules,
}

impl DetExecutor {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules) -> Self {
        Self { arch, weights, schedules }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let weights: Vec<(&Tensor, &Tensor)> = self
            .weights
            .layers
            .iter()
            .map(|l| (&l.w_mu, &l.b_mu))
            .collect();
        forward_det(&self.arch, &weights, x, &self.schedules)
    }
}

/// Shared deterministic forward used by both `DetExecutor` and the SVI
/// sampled passes.
fn forward_det(
    arch: &Arch,
    weights: &[(&Tensor, &Tensor)],
    x: &Tensor,
    schedules: &Schedules,
) -> Tensor {
    let mut h = reshape_input(arch, x);
    let mut ci = 0;
    for layer in &arch.layers {
        h = match layer {
            LayerSpec::Dense { .. } => {
                let (w, b) = weights[ci];
                ci += 1;
                det_dense(&h.flatten_2d(), w, Some(b.data()), &schedules.dense)
            }
            LayerSpec::Conv { .. } => {
                let (w, b) = weights[ci];
                ci += 1;
                det_conv2d(&h, w, Some(b.data()), &schedules.conv)
            }
            LayerSpec::Relu => det_relu(&h),
            LayerSpec::MaxPool2 => det_maxpool2(&h),
            LayerSpec::Flatten => h.flatten_2d(),
        };
    }
    h
}

/// SVI executor: N posterior samples, N deterministic passes.
pub struct SviExecutor {
    pub arch: Arch,
    pub weights: PosteriorWeights,
    pub schedules: Schedules,
    rng: SplitMix64,
}

impl SviExecutor {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules, seed: u64) -> Self {
        Self { arch, weights, schedules, rng: SplitMix64::new(seed) }
    }

    /// One predictive sample: draw a full weight set (part of the measured
    /// cost, as in the paper's Pyro baseline) and run a standard pass.
    pub fn forward_sample(&mut self, x: &Tensor) -> Tensor {
        let sampled: Vec<(Tensor, Tensor)> = self
            .weights
            .layers
            .iter()
            .map(|l| {
                (
                    sample_tensor(&l.w_mu, &l.w_sigma, &mut self.rng),
                    sample_tensor(&l.b_mu, &l.b_sigma, &mut self.rng),
                )
            })
            .collect();
        let refs: Vec<(&Tensor, &Tensor)> = sampled.iter().map(|(w, b)| (w, b)).collect();
        forward_det(&self.arch, &refs, x, &self.schedules)
    }

    /// N predictive samples -> logits `[n][B, classes]`.
    pub fn forward_n(&mut self, x: &Tensor, n: usize) -> Vec<Tensor> {
        (0..n).map(|_| self.forward_sample(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;
    use crate::util::prop::Gen;

    fn input(arch: &Arch, batch: usize, seed: u64) -> Tensor {
        let mut g = Gen::new(seed);
        let n = batch * arch.input_len();
        let data: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let mut shape = vec![batch];
        shape.extend_from_slice(&arch.input_shape);
        Tensor::new(shape, data).unwrap()
    }

    #[test]
    fn mlp_pfp_forward_shapes() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        let x = input(&arch, 4, 0);
        let (mu, var) = ex.forward(&x);
        assert_eq!(mu.shape(), &[4, 10]);
        assert_eq!(var.shape(), &[4, 10]);
        assert!(var.data().iter().all(|&v| v >= 0.0));
        assert!(mu.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lenet_pfp_forward_shapes() {
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 2);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        let x = input(&arch, 2, 1);
        let (mu, var) = ex.forward(&x);
        assert_eq!(mu.shape(), &[2, 10]);
        assert!(var.data().iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn baseline_and_tuned_schedules_agree() {
        // The schedule knobs must not change the math. Pool implementation
        // is held fixed (vectorized) because generic-vs-vectorized pooling
        // is a (slightly) different approximation, not a schedule knob.
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 3);
            let x = input(&arch, 2, 2);
            let mut base = Schedules::baseline();
            base.vectorized_pool = true;
            let (mu_a, var_a) =
                PfpExecutor::new(arch.clone(), w.clone(), base).forward(&x);
            let (mu_b, var_b) =
                PfpExecutor::new(arch.clone(), w, Schedules::tuned(2)).forward(&x);
            assert!(mu_a.allclose(&mu_b, 1e-4, 1e-4), "{} mu", arch.name);
            assert!(var_a.allclose(&var_b, 2e-3, 2e-3), "{} var", arch.name);
        }
    }

    #[test]
    fn pool_implementations_stay_close_through_network() {
        // generic vs vectorized pool: different association order, same
        // approximated quantity — logits must stay close, not identical.
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 3);
        let x = input(&arch, 2, 2);
        let (mu_a, _) =
            PfpExecutor::new(arch.clone(), w.clone(), Schedules::baseline()).forward(&x);
        let (mu_b, _) =
            PfpExecutor::new(arch.clone(), w, Schedules::tuned(1)).forward(&x);
        assert!(mu_a.max_abs_diff(&mu_b) < 0.1, "pool divergence too large");
    }

    #[test]
    fn zero_sigma_pfp_mean_matches_det() {
        let arch = Arch::mlp();
        let mut w = PosteriorWeights::synthetic(&arch, 4);
        for l in w.layers.iter_mut() {
            *l = LayerWeightsZero::zeroed(l);
        }
        let x = input(&arch, 3, 3);
        let (mu, var) = PfpExecutor::new(arch.clone(), w.clone(), Schedules::default())
            .forward(&x);
        let det = DetExecutor::new(arch, w, Schedules::default()).forward(&x);
        assert!(mu.allclose(&det, 2e-3, 2e-3));
        assert!(var.data().iter().all(|&v| v < 1e-3));
    }

    struct LayerWeightsZero;
    impl LayerWeightsZero {
        fn zeroed(l: &crate::model::LayerWeights) -> crate::model::LayerWeights {
            crate::model::LayerWeights::from_posterior(
                l.w_mu.clone(),
                l.w_sigma.map(|_| 1e-8),
                l.b_mu.clone(),
                l.b_sigma.map(|_| 1e-8),
                1.0,
            )
        }
    }

    #[test]
    fn svi_samples_scatter_around_pfp_mean() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 5);
        let x = input(&arch, 2, 4);
        let (mu, _) =
            PfpExecutor::new(arch.clone(), w.clone(), Schedules::default()).forward(&x);
        let mut svi = SviExecutor::new(arch, w, Schedules::default(), 7);
        let samples = svi.forward_n(&x, 64);
        // empirical mean of SVI logits approximates the PFP mean
        let mut emp = vec![0.0f32; mu.len()];
        for s in &samples {
            for (e, v) in emp.iter_mut().zip(s.data()) {
                *e += v / samples.len() as f32;
            }
        }
        let emp_t = Tensor::new(mu.shape().to_vec(), emp).unwrap();
        let diff = emp_t.max_abs_diff(&mu);
        assert!(diff < 0.5, "SVI empirical mean too far from PFP mean: {diff}");
    }

    #[test]
    fn profiler_covers_all_layers() {
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 6);
        let mut ex =
            PfpExecutor::new(arch.clone(), w, Schedules::default()).with_profiling();
        let x = input(&arch, 1, 5);
        let _ = ex.forward(&x);
        let prof = ex.profiler.take();
        let layers = prof.by_layer();
        // 5 compute + 4 relu + 2 pool (+ conversions)
        assert!(layers.len() >= 11, "got {} rows", layers.len());
        let types = prof.by_op_type();
        let names: Vec<&str> = types.iter().map(|r| r.label.as_str()).collect();
        for want in ["dense", "conv2d", "relu", "maxpool"] {
            assert!(names.contains(&want), "missing op type {want}");
        }
    }
}
