//! Native graph executors: PFP (single probabilistic pass), deterministic,
//! and SVI (N sampled passes).
//!
//! The PFP executor implements the paper's representation discipline
//! exactly like `python/compile/model.py::pfp_forward` (the goldens
//! cross-check this): compute layers consume E[x^2] / produce variances,
//! ReLU consumes variances / produces E[x^2], max-pool is variance to
//! variance, and conversions are inserted (and *profiled*, as the paper's
//! "tooling" overhead) where representations disagree. The first compute
//! layer uses the Eq. 13 deterministic-input kernels.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::Result;

use crate::ops::conv::{pfp_conv2d_first_in, pfp_conv2d_joint_in, ConvArgs};
use crate::plan::{CompiledPlan, PlanMode, Workspace};
use crate::ops::dense::{pfp_dense_first_in, pfp_dense_joint_in, DenseArgs};
use crate::ops::det::{det_conv2d, det_dense, det_relu};
use crate::ops::maxpool::{
    det_maxpool2, pfp_maxpool2_vectorized_in, pfp_maxpool_generic,
};
use crate::ops::relu::pfp_relu_in;
use crate::ops::simd::Isa;
use crate::ops::svi::sample_tensor;
use crate::ops::Schedule;
use crate::util::half::Precision;
use crate::profiling::Profiler;
use crate::tensor::{ProbTensor, Rep, Tensor};
use crate::util::rng::SplitMix64;
use crate::util::threadpool::{self, ThreadPool};

use super::{Arch, LayerSpec, PosteriorWeights};

/// Plan-lowering fusion policy (the serve/tune `--fuse on|off|auto`
/// flag). Governs whether `CompiledPlan::compile` collapses a
/// dense/conv step followed by a moment-matched ReLU (and an absorbable
/// representation `Convert`) into one fused step whose kernel epilogue
/// applies the elementwise chain on the cache-hot output tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusePolicy {
    /// Never fuse — every plan lowers exactly as before PR 8, and stays
    /// bit-identical to the interpreted walk.
    Off,
    /// Fuse every fusable pattern regardless of the per-step schedule's
    /// `fuse` knob.
    On,
    /// Defer to each compute step's bound schedule: fuse where the
    /// (tuner-searched) `fuse` knob is on. With no tuning records this
    /// behaves like `Off`, since the stock schedules carry `fuse: false`.
    Auto,
}

/// Per-operator-class schedule selection for a network, a per-layer
/// override table (the paper tunes per operator *workload*, not per
/// operator class), plus the shared persistent worker pool every parallel
/// operator dispatches onto.
#[derive(Clone, Debug)]
pub struct Schedules {
    pub dense: Schedule,
    pub conv: Schedule,
    /// Per-compute-layer schedule overrides, indexed by compute-layer
    /// position (the order of `Arch::compute_layers` /
    /// `PosteriorWeights::layers`). `None` (or a short vector) falls back
    /// to the op-class schedule above. The tuner populates this table by
    /// measuring each layer's actual shape; the compiled plan binds one
    /// entry per compute step.
    pub per_layer: Vec<Option<Schedule>>,
    /// vectorized k=2 pool (true) vs generic reduction (false) — Table 3.
    pub vectorized_pool: bool,
    pub relu_threads: usize,
    /// Worker tasks for the vectorized max-pool (1 = serial — Table 3's
    /// hand-vectorized row; >1 reproduces the "automatic schedule" row).
    pub maxpool_threads: usize,
    /// Plan-wide parallelism override for the compiled-plan path: when
    /// > 0, every *partitionable* step of a lowered plan is split into
    /// this many tile tasks at compile time (dense rows, conv
    /// patch-rows/planes, relu elements, vectorized-pool planes),
    /// regardless of the per-step schedule's `threads` or the
    /// relu/maxpool thread knobs — conversion steps and the generic
    /// (Table-3 baseline) pool stay serial by design. 0 (default) defers
    /// to those per-step knobs. Threaded through
    /// `pfp serve --plan-threads` / `pfp tune --plan-threads`; row
    /// partitioning keeps planned-parallel output bit-identical to
    /// planned-serial.
    pub plan_threads: usize,
    /// ISA policy override (the serve/tune `--isa scalar|native` flag):
    /// `Some(isa)` forces every bound schedule — compute steps *and* the
    /// elementwise ReLU/pool kernels — onto that ISA; `None` (default)
    /// lets each schedule's own `isa` knob decide, with the elementwise
    /// ops defaulting to `Native` (runtime-detected, scalar fallback, and
    /// `PFP_FORCE_SCALAR=1` caps everything at the detector level
    /// regardless).
    pub isa_override: Option<Isa>,
    /// Storage-precision policy override (the serve/tune `--precision
    /// f32|f16|bf16` flag): `Some(p)` forces every bound schedule's
    /// `precision` knob — posterior weights and inter-layer activations
    /// store at `p`, with all accumulation staying in f32; `None`
    /// (default) lets each schedule's own (tuner-searched) knob decide.
    /// Only the compiled-plan path implements packed storage; the
    /// interpreted walk ignores the knob and always runs f32 (it is the
    /// bit-exact reference).
    pub precision_override: Option<Precision>,
    /// Independent storage precision for the *variance path* (the Eq.
    /// 12/13 aux weight operand and the aux activation buffer): `Some(p)`
    /// splits the roles so the certification harness can localize which
    /// moment breaks the uncertainty budget first; `None` (default) makes
    /// the variance path follow the mean path's precision.
    pub var_precision: Option<Precision>,
    /// Elementwise-chain fusion policy for plan lowering (see
    /// [`FusePolicy`]). `Auto` (the constructor default) defers to each
    /// bound schedule's `fuse` knob, so plans only fuse where the tuner
    /// measured it to win; `On`/`Off` force the decision plan-wide.
    pub fuse: FusePolicy,
    /// Persistent worker-pool handle. Defaults to the process-wide pool;
    /// the serving coordinator injects one shared handle per `Service` so
    /// every model lane and request reuses the same workers.
    pub pool: Arc<ThreadPool>,
    /// Persisted tuning records carried along so the executors can
    /// re-resolve the schedule tables **per plan batch size** at
    /// cold-compile time ([`Schedules::for_batch`]) — the paper binds one
    /// tuned executable per mini-batch size, not one table for all
    /// buckets. `None` = use the tables above as-is for every batch.
    pub records: Option<Arc<crate::tuner::TuningRecords>>,
}

impl Schedules {
    /// Untuned baseline (Table 2 row 1 / Table 3 "Generic, no tuning").
    pub fn baseline() -> Self {
        Self {
            dense: Schedule::baseline(),
            conv: Schedule::baseline(),
            per_layer: Vec::new(),
            vectorized_pool: false,
            relu_threads: 1,
            maxpool_threads: 1,
            plan_threads: 0,
            isa_override: None,
            precision_override: None,
            var_precision: None,
            fuse: FusePolicy::Auto,
            pool: threadpool::global().clone(),
            records: None,
        }
    }

    /// Tuned configuration (what the tuner converges to on this host).
    pub fn tuned(threads: usize) -> Self {
        Self {
            dense: Schedule::tuned(threads),
            conv: Schedule::tuned(threads),
            per_layer: Vec::new(),
            vectorized_pool: true,
            relu_threads: 1,
            maxpool_threads: 1,
            plan_threads: 0,
            isa_override: None,
            precision_override: None,
            var_precision: None,
            fuse: FusePolicy::Auto,
            pool: threadpool::global().clone(),
            records: None,
        }
    }

    /// Replace the worker-pool handle (the serving path shares one pool
    /// across all lanes).
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = pool;
        self
    }

    /// Set the plan-wide tile-task count (see
    /// [`Schedules::plan_threads`]); 0 defers to per-step knobs.
    pub fn with_plan_threads(mut self, plan_threads: usize) -> Self {
        self.plan_threads = plan_threads;
        self
    }

    /// Set (or clear) the ISA policy override (see
    /// [`Schedules::isa_override`]).
    pub fn with_isa_override(mut self, isa: Option<Isa>) -> Self {
        self.isa_override = isa;
        self
    }

    /// Set (or clear) the storage-precision policy override (see
    /// [`Schedules::precision_override`]).
    pub fn with_precision_override(mut self, p: Option<Precision>) -> Self {
        self.precision_override = p;
        self
    }

    /// Set (or clear) the independent variance-path storage precision
    /// (see [`Schedules::var_precision`]).
    pub fn with_var_precision(mut self, p: Option<Precision>) -> Self {
        self.var_precision = p;
        self
    }

    /// Set the fusion policy (see [`FusePolicy`]).
    pub fn with_fuse(mut self, fuse: FusePolicy) -> Self {
        self.fuse = fuse;
        self
    }

    /// Whether a compute step bound to `sched` should absorb a following
    /// elementwise chain: the plan-wide policy, with `Auto` deferring to
    /// the schedule's own (tuner-searched) `fuse` knob.
    pub fn step_fuses(&self, sched: &Schedule) -> bool {
        match self.fuse {
            FusePolicy::Off => false,
            FusePolicy::On => true,
            FusePolicy::Auto => sched.fuse,
        }
    }

    /// The ISA the elementwise moment-matching kernels (ReLU, vectorized
    /// max-pool) bind: the override when set, else `Native` — the
    /// erf/exp-dominated ops always want the vector math unless the
    /// operator explicitly opts out.
    pub fn elementwise_isa(&self) -> Isa {
        self.isa_override.unwrap_or(Isa::Native)
    }

    /// The op-class schedule for a layer spec.
    pub fn class_schedule(&self, spec: &LayerSpec) -> Schedule {
        match spec {
            LayerSpec::Conv { .. } => self.conv,
            _ => self.dense,
        }
    }

    /// Effective schedule for compute layer `compute_idx`: the per-layer
    /// override when present, else the op-class schedule — with the ISA
    /// policy override applied either way (both the compiled plan and the
    /// interpreted walk resolve through here, so the two paths always
    /// bind the same ISA and stay bit-identical).
    pub fn layer_schedule(&self, compute_idx: usize, spec: &LayerSpec) -> Schedule {
        let s = self
            .per_layer
            .get(compute_idx)
            .copied()
            .flatten()
            .unwrap_or_else(|| self.class_schedule(spec));
        let s = match self.isa_override {
            Some(isa) => s.with_isa(isa),
            None => s,
        };
        match self.precision_override {
            Some(p) => s.with_precision(p),
            None => s,
        }
    }

    /// Set a per-layer override (builder form), growing the table as
    /// needed.
    pub fn with_layer_schedule(mut self, compute_idx: usize, sched: Schedule) -> Self {
        if self.per_layer.len() <= compute_idx {
            self.per_layer.resize(compute_idx + 1, None);
        }
        self.per_layer[compute_idx] = Some(sched);
        self
    }

    /// Resolve schedules for `arch` at `batch` from persisted tuning
    /// records: op-class schedules from the class keys, per-layer
    /// overrides from the layer keys (`dense/<arch>/L<i>/b<batch>`),
    /// nearest recorded batch either way. `base` supplies everything not
    /// recorded (and the pool handle). The records handle is kept on the
    /// result so executors re-resolve per plan batch size
    /// ([`Schedules::for_batch`]).
    pub fn from_records(
        records: Arc<crate::tuner::TuningRecords>,
        arch: &Arch,
        batch: usize,
        mut base: Schedules,
    ) -> Schedules {
        base.dense = records.lookup("dense", &arch.name, batch, base.dense);
        base.conv = records.lookup("conv", &arch.name, batch, base.conv);
        base.per_layer = arch
            .compute_layers()
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let op = match spec {
                    LayerSpec::Conv { .. } => "conv",
                    _ => "dense",
                };
                let class = base.class_schedule(spec);
                let s = records.lookup_layer(op, &arch.name, i, batch, class);
                if s == class {
                    None
                } else {
                    Some(s)
                }
            })
            .collect();
        base.records = Some(records);
        base
    }

    /// The schedules a plan for `batch` should bind: when tuning records
    /// are carried, re-resolve the tables against that batch (the paper's
    /// per-mini-batch-size executables); otherwise use `self` as-is.
    pub fn for_batch(&self, arch: &Arch, batch: usize) -> Schedules {
        match &self.records {
            Some(r) => Self::from_records(Arc::clone(r), arch, batch, self.clone()),
            None => self.clone(),
        }
    }
}

impl Default for Schedules {
    fn default() -> Self {
        Self::tuned(1)
    }
}

/// Order-independent [`Schedules`] construction — the replacement for the
/// accreted `with_*` chains whose meaning depended on call order (most
/// notably `Schedules::from_records`, which had to be the *outermost*
/// call or the records were resolved against stale tables).
///
/// Knob timing, for the record:
///
/// * **plan-time** knobs are baked into each compiled plan at cold
///   compile: `plan_threads` (tile partitioning), `isa_override` (kernel
///   selection), the per-layer schedule tables that `records` resolve to,
///   and `vectorized_pool`. Changing them only affects plans compiled
///   afterwards.
/// * **bind-time** knobs are looked up on every dispatch: `pool` (which
///   workers run the tiles) and the `records` *handle itself* (re-resolved
///   per batch size by [`Schedules::for_batch`] at each cold compile —
///   which is why `build()` can attach it in any order).
#[derive(Clone)]
pub struct SchedulesBuilder {
    threads: usize,
    baseline: bool,
    pool: Option<Arc<ThreadPool>>,
    plan_threads: usize,
    isa_override: Option<Isa>,
    precision_override: Option<Precision>,
    var_precision: Option<Precision>,
    fuse: FusePolicy,
    records: Option<Arc<crate::tuner::TuningRecords>>,
    vectorized_pool: Option<bool>,
}

impl SchedulesBuilder {
    /// Start from the tuned defaults for `threads` workers.
    pub fn tuned(threads: usize) -> Self {
        Self {
            threads,
            baseline: false,
            pool: None,
            plan_threads: 0,
            isa_override: None,
            precision_override: None,
            var_precision: None,
            fuse: FusePolicy::Auto,
            records: None,
            vectorized_pool: None,
        }
    }

    /// Start from the untuned baseline (Table 2 row 1).
    pub fn baseline() -> Self {
        Self { baseline: true, ..Self::tuned(1) }
    }

    /// Share a worker pool (bind-time; defaults to the process-wide pool).
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Plan-wide tile-task count (plan-time; 0 defers to per-step knobs).
    pub fn plan_threads(mut self, plan_threads: usize) -> Self {
        self.plan_threads = plan_threads;
        self
    }

    /// ISA policy override (plan-time; `None` lets each schedule decide).
    pub fn isa_override(mut self, isa: Option<Isa>) -> Self {
        self.isa_override = isa;
        self
    }

    /// Storage-precision policy override (plan-time; `None` lets each
    /// schedule's tuner-searched `precision` knob decide).
    pub fn precision_override(mut self, p: Option<Precision>) -> Self {
        self.precision_override = p;
        self
    }

    /// Independent variance-path storage precision (plan-time; `None`
    /// makes the variance path follow the mean path).
    pub fn var_precision(mut self, p: Option<Precision>) -> Self {
        self.var_precision = p;
        self
    }

    /// Fusion policy (plan-time; `Auto` lets each schedule's `fuse` knob
    /// decide — see [`FusePolicy`]).
    pub fn fuse(mut self, fuse: FusePolicy) -> Self {
        self.fuse = fuse;
        self
    }

    /// Attach persisted tuning records. Resolution is **lazy**: each cold
    /// compile re-resolves the tables for its own batch size
    /// ([`Schedules::for_batch`]), so this composes with every other knob
    /// regardless of call order.
    pub fn records(mut self, records: Option<Arc<crate::tuner::TuningRecords>>) -> Self {
        self.records = records;
        self
    }

    /// Force the vectorized (true) or generic (false) k=2 max-pool.
    pub fn vectorized_pool(mut self, on: bool) -> Self {
        self.vectorized_pool = Some(on);
        self
    }

    pub fn build(self) -> Schedules {
        let mut s = if self.baseline {
            Schedules::baseline()
        } else {
            Schedules::tuned(self.threads)
        };
        if let Some(pool) = self.pool {
            s.pool = pool;
        }
        s.plan_threads = self.plan_threads;
        s.isa_override = self.isa_override;
        s.precision_override = self.precision_override;
        s.var_precision = self.var_precision;
        s.fuse = self.fuse;
        if let Some(v) = self.vectorized_pool {
            s.vectorized_pool = v;
        }
        s.records = self.records;
        s
    }

    /// Build and eagerly resolve the schedule tables for one
    /// (arch, batch) — what `pfp serve` historically did against
    /// `max_batch`. The records handle stays attached either way, so
    /// other batch sizes still re-resolve at their own cold compiles.
    pub fn build_for(self, arch: &Arch, batch: usize) -> Schedules {
        let s = self.build();
        s.for_batch(arch, batch)
    }
}

impl Schedules {
    /// Entry point for [`SchedulesBuilder`].
    pub fn builder(threads: usize) -> SchedulesBuilder {
        SchedulesBuilder::tuned(threads)
    }
}

/// One cached compiled plan + its reusable workspace.
struct PlanEntry {
    plan: CompiledPlan,
    ws: Workspace,
    last_used: u64,
}

/// Upper bound on cached plans per executor. The serving path is bounded
/// anyway (at most `max_batch` distinct bucket sizes); this bounds
/// long-lived library callers feeding arbitrary batch sizes, each of
/// which would otherwise pin a plan + workspace forever.
const PLAN_CACHE_CAP: usize = 32;

/// Process-wide LRU clock shared by every plan cache. A global clock (vs
/// the old per-cache tick) makes `last_used` stamps comparable *across*
/// executors, which is what the registry's cross-model memory-budget
/// eviction orders by.
static PLAN_CLOCK: AtomicU64 = AtomicU64::new(1);

fn plan_clock_tick() -> u64 {
    PLAN_CLOCK.fetch_add(1, Ordering::Relaxed)
}

/// Bounded batch-size -> compiled-plan cache with least-recently-used
/// eviction.
#[derive(Default)]
struct PlanCache {
    map: HashMap<usize, PlanEntry>,
    /// Cold compiles (one per batch size first seen, plus recompiles
    /// after eviction).
    compiles: u64,
    /// Plans evicted at the cap or by the registry's memory budget —
    /// visible thrash across batch buckets (surfaced as the
    /// `plan_cache_evictions` serving metric).
    evictions: u64,
}

impl PlanCache {
    /// Fetch (or `build` and insert, evicting the LRU plan at the cap)
    /// the entry for `batch`. Returns the entry and whether this was a
    /// cold compile.
    fn get_or_insert_with(
        &mut self,
        batch: usize,
        build: impl FnOnce() -> PlanEntry,
    ) -> (&mut PlanEntry, bool) {
        let mut cold = false;
        if !self.map.contains_key(&batch) {
            if self.map.len() >= PLAN_CACHE_CAP {
                if let Some(evict) =
                    self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(b, _)| *b)
                {
                    self.map.remove(&evict);
                    self.evictions += 1;
                }
            }
            self.map.insert(batch, build());
            self.compiles += 1;
            cold = true;
        }
        let entry = self.map.get_mut(&batch).unwrap();
        entry.last_used = plan_clock_tick();
        (entry, cold)
    }

    fn batches(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.map.keys().copied().collect();
        b.sort_unstable();
        b
    }

    /// Resident footprint: every cached plan's workspace, in bytes.
    fn bytes(&self) -> usize {
        self.map.values().map(|e| e.ws.total_floats() * 4).sum()
    }

    /// Packed (u16-storage) weight tensors across every resident plan —
    /// the registry's mixed-precision metadata column. Zero for all-f32
    /// plans.
    fn packed_tensors(&self) -> usize {
        self.map.values().map(|e| e.plan.packed_tensors()).sum()
    }

    /// The least-recently-used entry as `(batch, last_used)` — the
    /// registry compares these stamps across models (they share
    /// [`PLAN_CLOCK`]).
    fn lru(&self) -> Option<(usize, u64)> {
        self.map
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(b, e)| (*b, e.last_used))
    }

    /// Drop the plan for `batch` (counted as an eviction when present).
    fn evict(&mut self, batch: usize) -> bool {
        if self.map.remove(&batch).is_some() {
            self.evictions += 1;
            true
        } else {
            false
        }
    }
}

/// The one object-safe surface every servable executor exposes: the
/// registry, [`NativePfpBackend`](crate::coordinator::NativePfpBackend)
/// and the future selective-prediction router all dispatch through
/// `Box<dyn Executor>` instead of branching on the concrete
/// [`PfpExecutor`] / [`DetExecutor`] types.
///
/// `forward` is the probabilistic contract `(mu, var)`; deterministic
/// executors return zero variance. The remaining methods are plan-cache
/// accessors: compile/eviction counters for metrics, and the
/// bytes/LRU/evict triple the registry's cross-model memory budget
/// drives.
pub trait Executor: Send {
    fn arch(&self) -> &Arch;
    /// One forward pass: input `[B, ...input_shape]` ->
    /// `(mu [B, classes], var [B, classes])`.
    fn forward(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)>;
    /// Cold plan compiles so far.
    fn plan_compiles(&self) -> u64;
    /// Plans evicted (cap or memory budget) so far.
    fn plan_evictions(&self) -> u64;
    /// Batch sizes with a resident compiled plan.
    fn cached_batches(&self) -> Vec<usize>;
    /// Resident plan-cache footprint in bytes (workspace arenas).
    fn plan_bytes(&self) -> usize;
    /// Weight tensors the resident plans converted to packed u16 storage
    /// (f16/bf16 mixed precision); zero when everything stores f32.
    fn packed_weight_tensors(&self) -> usize;
    /// Least-recently-used resident plan as `(batch, global LRU stamp)`.
    fn lru_plan(&self) -> Option<(usize, u64)>;
    /// Drop the plan for `batch`; returns whether one was resident.
    fn evict_plan(&mut self, batch: usize) -> bool;
}

/// Single-probabilistic-forward-pass executor.
///
/// A thin wrapper over the lowering layer: `forward` compiles the network
/// into a [`CompiledPlan`] for the request's batch size on first sight
/// (a *cold compile*, counted by [`PfpExecutor::plan_compiles`]), caches
/// it keyed by batch size, and thereafter just executes — the paper's
/// per-mini-batch-size compiled executables. The pre-plan interpretive
/// walk survives as [`PfpExecutor::forward_interpreted`] for parity tests
/// and the plan-vs-interpreter benchmark.
pub struct PfpExecutor {
    pub arch: Arch,
    pub weights: Arc<PosteriorWeights>,
    pub schedules: Schedules,
    pub profiler: Profiler,
    plans: PlanCache,
}

impl PfpExecutor {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules) -> Self {
        assert_eq!(arch.compute_layers().len(), weights.layers.len());
        Self {
            arch,
            weights: Arc::new(weights),
            schedules,
            profiler: Profiler::new(false),
            plans: PlanCache::default(),
        }
    }

    pub fn with_profiling(mut self) -> Self {
        self.profiler = Profiler::new(true);
        self
    }

    /// Cold plan compiles so far (one per distinct batch size seen).
    pub fn plan_compiles(&self) -> u64 {
        self.plans.compiles
    }

    /// Plans evicted from the bounded LRU cache so far. A moving value at
    /// steady state means the served batch-size working set exceeds the
    /// cache cap and buckets are recompiling (cache thrash) — surfaced as
    /// the `plan_cache_evictions` serving metric.
    pub fn plan_evictions(&self) -> u64 {
        self.plans.evictions
    }

    /// Batch sizes with a cached plan (at most [`PLAN_CACHE_CAP`]).
    pub fn cached_batches(&self) -> Vec<usize> {
        self.plans.batches()
    }

    /// Run one probabilistic forward pass through the compiled plan for
    /// this batch size (compiling and caching it on first sight):
    /// input `[B, ...input_shape]` -> (mu `[B, classes]`, var `[B, classes]`).
    pub fn forward(&mut self, x: &Tensor) -> (Tensor, Tensor) {
        self.profiler.begin_pass();
        let batch = x.dim(0);
        let arch = &self.arch;
        let weights = &self.weights;
        let schedules = &self.schedules;
        let (entry, _cold) = self.plans.get_or_insert_with(batch, || {
            let schedules = schedules.for_batch(arch, batch);
            let plan = CompiledPlan::compile(
                arch,
                Arc::clone(weights),
                &schedules,
                batch,
                PlanMode::Pfp,
            )
            .expect("plan lowering failed");
            let ws = plan.workspace();
            PlanEntry { plan, ws, last_used: 0 }
        });
        let (rows, cols) = entry.plan.out_shape();
        let (mu, var) = entry.plan.execute(x.data(), &mut entry.ws, &mut self.profiler);
        (
            Tensor::new(vec![rows, cols], mu.to_vec()).unwrap(),
            Tensor::new(vec![rows, cols], var.to_vec()).unwrap(),
        )
    }

    /// The pre-lowering interpretive forward pass: re-walks `arch.layers`
    /// every call, re-decides conversions at runtime, and allocates fresh
    /// tensors per layer. Kept as the reference implementation —
    /// `CompiledPlan::execute` must match it bit-for-bit (with serial
    /// schedules) — and as the benchmark baseline.
    pub fn forward_interpreted(&mut self, x: &Tensor) -> (Tensor, Tensor) {
        self.profiler.begin_pass();
        let labels = self.arch.layer_labels();
        let mut compute_idx = 0usize;
        let mut state: Option<ProbTensor> = None; // None until first compute layer
        let mut det_input: Option<Tensor> = Some(reshape_input(&self.arch, x));

        // The executor walks the layer list; the first compute layer takes
        // the raw deterministic input (Eq. 13 kernels).
        for (li, layer) in self.arch.layers.iter().enumerate() {
            let label = &labels[li];
            match layer {
                LayerSpec::Dense { .. } => {
                    let w = &self.weights.layers[compute_idx];
                    let sched = self.schedules.layer_schedule(compute_idx, layer);
                    compute_idx += 1;
                    let pool = Arc::clone(&self.schedules.pool);
                    let next = if let Some(prob) = state.take() {
                        let prob = convert_rep(&mut self.profiler, prob, Rep::E2, label);
                        let prob = prob.flatten_2d();
                        let (mu, var) = self.profiler.record(label, "dense", || {
                            pfp_dense_joint_in(
                                &pool,
                                &DenseArgs {
                                    x_mu: &prob.mu,
                                    x_aux: &prob.aux,
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_e2,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        });
                        ProbTensor::new(mu, var, Rep::Var)
                    } else {
                        let x = det_input.take().expect("input consumed twice");
                        let x = x.flatten_2d();
                        let x_sq = x.squared();
                        let (mu, var) = self.profiler.record(label, "dense", || {
                            pfp_dense_first_in(
                                &pool,
                                &DenseArgs {
                                    x_mu: &x,
                                    x_aux: &x_sq,
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_var,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        });
                        ProbTensor::new(mu, var, Rep::Var)
                    };
                    state = Some(next);
                }
                LayerSpec::Conv { .. } => {
                    let w = &self.weights.layers[compute_idx];
                    let sched = self.schedules.layer_schedule(compute_idx, layer);
                    compute_idx += 1;
                    let pool = Arc::clone(&self.schedules.pool);
                    let next = if let Some(prob) = state.take() {
                        let prob = convert_rep(&mut self.profiler, prob, Rep::E2, label);
                        self.profiler.record(label, "conv2d", || {
                            pfp_conv2d_joint_in(
                                &pool,
                                &prob,
                                &ConvArgs {
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_e2,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        })
                    } else {
                        let x = det_input.take().expect("input consumed twice");
                        self.profiler.record(label, "conv2d", || {
                            pfp_conv2d_first_in(
                                &pool,
                                &x,
                                &ConvArgs {
                                    w_mu: &w.w_mu,
                                    w_aux: &w.w_var,
                                    b_mu: Some(w.b_mu.data()),
                                    b_var: Some(w.b_var.data()),
                                },
                                &sched,
                            )
                        })
                    };
                    state = Some(next);
                }
                LayerSpec::Relu => {
                    let prob = state.take().expect("ReLU before first compute layer");
                    let prob = convert_rep(&mut self.profiler, prob, Rep::Var, label);
                    let threads = self.schedules.relu_threads;
                    let isa = self.schedules.elementwise_isa();
                    let pool = Arc::clone(&self.schedules.pool);
                    state = Some(
                        self.profiler
                            .record(label, "relu", || pfp_relu_in(&pool, prob, threads, isa)),
                    );
                }
                LayerSpec::MaxPool2 => {
                    let prob = state.take().expect("pool before first compute layer");
                    let prob = convert_rep(&mut self.profiler, prob, Rep::Var, label);
                    let vectorized = self.schedules.vectorized_pool;
                    let threads = self.schedules.maxpool_threads;
                    let isa = self.schedules.elementwise_isa();
                    let pool = Arc::clone(&self.schedules.pool);
                    state = Some(self.profiler.record(label, "maxpool", || {
                        if vectorized {
                            pfp_maxpool2_vectorized_in(&pool, &prob, threads, isa)
                        } else {
                            pfp_maxpool_generic(&prob, 2, 2)
                        }
                    }));
                }
                LayerSpec::Flatten => {
                    if let Some(prob) = state.take() {
                        state = Some(prob.flatten_2d());
                    } else if let Some(x) = det_input.take() {
                        det_input = Some(x.flatten_2d());
                    }
                }
            }
        }
        let out = state.expect("network produced no output").into_var();
        (out.mu, out.aux)
    }

}

impl Executor for PfpExecutor {
    fn arch(&self) -> &Arch {
        &self.arch
    }

    fn forward(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        Ok(PfpExecutor::forward(self, x))
    }

    fn plan_compiles(&self) -> u64 {
        self.plans.compiles
    }

    fn plan_evictions(&self) -> u64 {
        self.plans.evictions
    }

    fn cached_batches(&self) -> Vec<usize> {
        self.plans.batches()
    }

    fn plan_bytes(&self) -> usize {
        self.plans.bytes()
    }

    fn packed_weight_tensors(&self) -> usize {
        self.plans.packed_tensors()
    }

    fn lru_plan(&self) -> Option<(usize, u64)> {
        self.plans.lru()
    }

    fn evict_plan(&mut self, batch: usize) -> bool {
        self.plans.evict(batch)
    }
}

/// Representation conversion, profiled as the paper's "tooling" overhead
/// and attributed to the layer it feeds (`Convert@<layer>`, matching the
/// compiled plan's explicit conversion steps) so the Table 4 per-layer
/// profile shows *where* the overhead lands; the aggregate `convert`
/// op-type row is unchanged.
fn convert_rep(profiler: &mut Profiler, prob: ProbTensor, rep: Rep, at: &str) -> ProbTensor {
    if prob.rep == rep {
        return prob;
    }
    if !profiler.enabled() {
        // skip the label allocation on unprofiled passes (this path is
        // the benchmark baseline — keep it honest)
        return prob.to_rep(rep).0;
    }
    profiler.record(&format!("Convert@{at}"), "convert", || prob.to_rep(rep).0)
}

fn reshape_input(arch: &Arch, x: &Tensor) -> Tensor {
    let batch = x.dim(0);
    let mut shape = vec![batch];
    shape.extend_from_slice(&arch.input_shape);
    x.clone().reshape(shape).expect("input shape mismatch")
}

/// Deterministic executor (posterior means).
///
/// Same thin-wrapper shape as [`PfpExecutor`]: compiles a
/// [`PlanMode::Det`] plan per batch size (mean-only kernels, in-place
/// ReLU, no representation conversions) and caches it. Interior
/// mutability keeps the historical `&self` forward signature.
pub struct DetExecutor {
    pub arch: Arch,
    pub weights: Arc<PosteriorWeights>,
    pub schedules: Schedules,
    plans: Mutex<PlanCache>,
}

impl DetExecutor {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules) -> Self {
        assert_eq!(arch.compute_layers().len(), weights.layers.len());
        Self {
            arch,
            weights: Arc::new(weights),
            schedules,
            plans: Mutex::new(PlanCache::default()),
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let batch = x.dim(0);
        let mut plans = self.plans.lock().unwrap();
        let (entry, _) = plans.get_or_insert_with(batch, || {
            let schedules = self.schedules.for_batch(&self.arch, batch);
            let plan = CompiledPlan::compile(
                &self.arch,
                Arc::clone(&self.weights),
                &schedules,
                batch,
                PlanMode::Det,
            )
            .expect("det plan lowering failed");
            let ws = plan.workspace();
            PlanEntry { plan, ws, last_used: 0 }
        });
        let (rows, cols) = entry.plan.out_shape();
        let mut off = Profiler::new(false);
        let (mu, _) = entry.plan.execute(x.data(), &mut entry.ws, &mut off);
        Tensor::new(vec![rows, cols], mu.to_vec()).unwrap()
    }
}

impl Executor for DetExecutor {
    fn arch(&self) -> &Arch {
        &self.arch
    }

    /// Deterministic executors fulfil the probabilistic contract with
    /// zero predictive variance.
    fn forward(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let mu = DetExecutor::forward(self, x);
        let var = Tensor::zeros(mu.shape().to_vec());
        Ok((mu, var))
    }

    fn plan_compiles(&self) -> u64 {
        self.plans.lock().unwrap().compiles
    }

    fn plan_evictions(&self) -> u64 {
        self.plans.lock().unwrap().evictions
    }

    fn cached_batches(&self) -> Vec<usize> {
        self.plans.lock().unwrap().batches()
    }

    fn plan_bytes(&self) -> usize {
        self.plans.lock().unwrap().bytes()
    }

    fn packed_weight_tensors(&self) -> usize {
        self.plans.lock().unwrap().packed_tensors()
    }

    fn lru_plan(&self) -> Option<(usize, u64)> {
        self.plans.lock().unwrap().lru()
    }

    fn evict_plan(&mut self, batch: usize) -> bool {
        self.plans.lock().unwrap().evict(batch)
    }
}

/// Shared deterministic forward used by both `DetExecutor` and the SVI
/// sampled passes.
fn forward_det(
    arch: &Arch,
    weights: &[(&Tensor, &Tensor)],
    x: &Tensor,
    schedules: &Schedules,
) -> Tensor {
    let mut h = reshape_input(arch, x);
    let mut ci = 0;
    for layer in &arch.layers {
        h = match layer {
            LayerSpec::Dense { .. } => {
                let (w, b) = weights[ci];
                let sched = schedules.layer_schedule(ci, layer);
                ci += 1;
                det_dense(&h.flatten_2d(), w, Some(b.data()), &sched)
            }
            LayerSpec::Conv { .. } => {
                let (w, b) = weights[ci];
                let sched = schedules.layer_schedule(ci, layer);
                ci += 1;
                det_conv2d(&h, w, Some(b.data()), &sched)
            }
            LayerSpec::Relu => det_relu(&h),
            LayerSpec::MaxPool2 => det_maxpool2(&h),
            LayerSpec::Flatten => h.flatten_2d(),
        };
    }
    h
}

/// SVI executor: N posterior samples, N deterministic passes.
pub struct SviExecutor {
    pub arch: Arch,
    pub weights: PosteriorWeights,
    pub schedules: Schedules,
    rng: SplitMix64,
}

impl SviExecutor {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules, seed: u64) -> Self {
        Self { arch, weights, schedules, rng: SplitMix64::new(seed) }
    }

    /// One predictive sample: draw a full weight set (part of the measured
    /// cost, as in the paper's Pyro baseline) and run a standard pass.
    pub fn forward_sample(&mut self, x: &Tensor) -> Tensor {
        let sampled: Vec<(Tensor, Tensor)> = self
            .weights
            .layers
            .iter()
            .map(|l| {
                (
                    sample_tensor(&l.w_mu, &l.w_sigma, &mut self.rng),
                    sample_tensor(&l.b_mu, &l.b_sigma, &mut self.rng),
                )
            })
            .collect();
        let refs: Vec<(&Tensor, &Tensor)> = sampled.iter().map(|(w, b)| (w, b)).collect();
        forward_det(&self.arch, &refs, x, &self.schedules)
    }

    /// N predictive samples -> logits `[n][B, classes]`.
    pub fn forward_n(&mut self, x: &Tensor, n: usize) -> Vec<Tensor> {
        (0..n).map(|_| self.forward_sample(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;
    use crate::util::prop::Gen;

    fn input(arch: &Arch, batch: usize, seed: u64) -> Tensor {
        let mut g = Gen::new(seed);
        let n = batch * arch.input_len();
        let data: Vec<f32> = (0..n).map(|_| g.f32_in(0.0, 1.0)).collect();
        let mut shape = vec![batch];
        shape.extend_from_slice(&arch.input_shape);
        Tensor::new(shape, data).unwrap()
    }

    #[test]
    fn plan_forward_matches_interpreter_bitwise() {
        // The compiled plan runs the same kernels in the same order with
        // the same serial schedules: outputs must be bit-identical to the
        // interpretive walk, not merely close.
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 11);
            let x = input(&arch, 3, 7);
            let (mu_i, var_i) = PfpExecutor::new(arch.clone(), w.clone(), Schedules::tuned(1))
                .forward_interpreted(&x);
            let (mu_p, var_p) =
                PfpExecutor::new(arch.clone(), w, Schedules::tuned(1)).forward(&x);
            assert_eq!(mu_i.data(), mu_p.data(), "{} mu", arch.name);
            assert_eq!(var_i.data(), var_p.data(), "{} var", arch.name);
        }
    }

    #[test]
    fn plans_cached_per_batch_size() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 12);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        for batch in [1usize, 4, 1, 4, 1] {
            let _ = ex.forward(&input(&arch, batch, batch as u64));
        }
        assert_eq!(ex.plan_compiles(), 2, "one cold compile per batch size");
        assert_eq!(ex.cached_batches(), vec![1, 4]);
    }

    #[test]
    fn per_layer_overrides_agree_with_uniform() {
        // overrides change the loop nest, not the math
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 13);
            let x = input(&arch, 2, 9);
            let uniform = Schedules::tuned(1);
            let mut over = Schedules::tuned(1)
                .with_layer_schedule(0, Schedule::tuned(1).with_unroll(4))
                .with_layer_schedule(1, Schedule::tiled(8, 32));
            over = over.with_layer_schedule(
                arch.compute_layers().len() - 1,
                Schedule::baseline().with_order(crate::ops::schedule::LoopOrder::Mnk),
            );
            let (mu_u, var_u) =
                PfpExecutor::new(arch.clone(), w.clone(), uniform).forward(&x);
            let (mu_o, var_o) = PfpExecutor::new(arch.clone(), w, over).forward(&x);
            assert!(mu_u.allclose(&mu_o, 1e-4, 1e-4), "{} mu", arch.name);
            assert!(var_u.allclose(&var_o, 2e-3, 2e-3), "{} var", arch.name);
        }
    }

    #[test]
    fn plan_cache_is_bounded_with_lru_eviction() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 14);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        for batch in 1..=(PLAN_CACHE_CAP + 4) {
            let _ = ex.forward(&input(&arch, batch, batch as u64));
        }
        assert_eq!(ex.cached_batches().len(), PLAN_CACHE_CAP);
        assert_eq!(ex.plan_compiles(), (PLAN_CACHE_CAP + 4) as u64);
        // eviction is counted, not silent: 4 batches past the cap
        assert_eq!(ex.plan_evictions(), 4);
        // the oldest batch sizes were evicted, the newest retained
        assert!(!ex.cached_batches().contains(&1));
        assert!(ex.cached_batches().contains(&(PLAN_CACHE_CAP + 4)));
        // re-seeing an evicted size recompiles (cold) exactly once more,
        // evicting one more victim
        let _ = ex.forward(&input(&arch, 1, 1));
        assert_eq!(ex.plan_compiles(), (PLAN_CACHE_CAP + 5) as u64);
        assert_eq!(ex.plan_evictions(), 5);
    }

    #[test]
    fn plan_cache_under_cap_never_evicts() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 15);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        for batch in [1usize, 2, 3, 1, 2, 3] {
            let _ = ex.forward(&input(&arch, batch, batch as u64));
        }
        assert_eq!(ex.plan_evictions(), 0);
    }

    #[test]
    fn planned_parallel_forward_bitwise_matches_interpreter() {
        // plan_threads only changes where work runs (row partitions), so
        // the planned-parallel path must match the serial interpreter
        // bit for bit — the tentpole determinism guarantee, through the
        // executor API.
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 16);
            let x = input(&arch, 3, 8);
            let (mu_i, var_i) = PfpExecutor::new(arch.clone(), w.clone(), Schedules::tuned(1))
                .forward_interpreted(&x);
            for t in [2usize, 4] {
                let (mu_p, var_p) = PfpExecutor::new(
                    arch.clone(),
                    w.clone(),
                    Schedules::tuned(1).with_plan_threads(t),
                )
                .forward(&x);
                assert_eq!(mu_i.data(), mu_p.data(), "{} t={t} mu", arch.name);
                assert_eq!(var_i.data(), var_p.data(), "{} t={t} var", arch.name);
            }
        }
    }

    #[test]
    fn for_batch_rebinds_records_per_batch_size() {
        // serve resolves once at max_batch, but the carried records must
        // re-bind each cold-compiled bucket to its own tuned table
        let arch = Arch::mlp();
        let mut r = crate::tuner::TuningRecords::default();
        let b1 = Schedule::tuned(1).with_unroll(2);
        let b64 = Schedule::tuned(1).with_unroll(4);
        r.insert(crate::tuner::TuningRecords::layer_key("dense", "mlp", 0, 1), b1, 0.1);
        r.insert(crate::tuner::TuningRecords::layer_key("dense", "mlp", 0, 64), b64, 0.2);
        let s = Schedules::from_records(Arc::new(r), &arch, 64, Schedules::tuned(1));
        assert_eq!(s.per_layer[0], Some(b64));
        let s1 = s.for_batch(&arch, 1);
        assert_eq!(s1.per_layer[0], Some(b1), "bucket 1 must bind its own record");
        // without records, for_batch is the identity
        let plain = Schedules::tuned(1).for_batch(&arch, 1);
        assert!(plain.per_layer.is_empty());
    }

    #[test]
    fn isa_override_rebinds_every_schedule() {
        use crate::ops::simd::Isa;
        let arch = Arch::mlp();
        let s = Schedules::tuned(1).with_isa_override(Some(Isa::Scalar));
        // tuned schedules carry Native; the override must win everywhere
        for (i, spec) in arch.compute_layers().iter().enumerate() {
            assert_eq!(s.layer_schedule(i, spec).isa, Isa::Scalar);
        }
        assert_eq!(s.elementwise_isa(), Isa::Scalar);
        // and per-layer overrides are re-pinned too
        let s = s.with_layer_schedule(0, Schedule::tuned(1));
        assert_eq!(s.layer_schedule(0, arch.compute_layers()[0]).isa, Isa::Scalar);
        // no override: schedules keep their own knob, elementwise is Native
        let plain = Schedules::tuned(1);
        assert_eq!(plain.layer_schedule(0, arch.compute_layers()[0]).isa, Isa::Native);
        assert_eq!(plain.elementwise_isa(), Isa::Native);
    }

    #[test]
    fn precision_override_rebinds_every_schedule() {
        // the serve/tune --precision flag: like the ISA override, it must
        // win over every bound schedule, per-layer overrides included
        let arch = Arch::mlp();
        let s = Schedules::tuned(1).with_precision_override(Some(Precision::F16));
        for (i, spec) in arch.compute_layers().iter().enumerate() {
            assert_eq!(s.layer_schedule(i, spec).precision, Precision::F16);
        }
        let s = s.with_layer_schedule(0, Schedule::tuned(1));
        assert_eq!(s.layer_schedule(0, arch.compute_layers()[0]).precision, Precision::F16);
        // no override: schedules keep their own knob (stock = f32)
        let plain = Schedules::tuned(1);
        assert_eq!(
            plain.layer_schedule(0, arch.compute_layers()[0]).precision,
            Precision::F32
        );
        // builder carries both precision knobs
        let b = SchedulesBuilder::tuned(1)
            .precision_override(Some(Precision::Bf16))
            .var_precision(Some(Precision::F32))
            .build();
        assert_eq!(b.precision_override, Some(Precision::Bf16));
        assert_eq!(b.var_precision, Some(Precision::F32));
    }

    #[test]
    fn packed_forward_is_finite_and_tracks_f32() {
        // end-to-end through the executor: a packed (f16/bf16) forward
        // pass stays finite, keeps variances non-negative, counts its
        // packed tensors, and lands close to the f32 reference — the
        // metric-level budget is integration_precision_cert's job.
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 33);
            let x = input(&arch, 2, 23);
            let mut f32_ex = PfpExecutor::new(arch.clone(), w.clone(), Schedules::tuned(1));
            let (mu_f, var_f) = f32_ex.forward(&x);
            assert_eq!(Executor::packed_weight_tensors(&f32_ex), 0, "f32 packs nothing");
            for p in [Precision::F16, Precision::Bf16] {
                let mut ex = PfpExecutor::new(
                    arch.clone(),
                    w.clone(),
                    Schedules::tuned(1).with_precision_override(Some(p)),
                );
                let (mu, var) = ex.forward(&x);
                assert!(
                    Executor::packed_weight_tensors(&ex) > 0,
                    "{} {p} must pack weight tensors",
                    arch.name
                );
                assert!(mu.data().iter().all(|v| v.is_finite()), "{} {p}", arch.name);
                assert!(var.data().iter().all(|&v| v >= 0.0 && v.is_finite()));
                // storage quantization is a small perturbation, not a
                // rewrite: logits stay within a coarse envelope of f32
                assert!(
                    mu.max_abs_diff(&mu_f) < 0.5,
                    "{} {p} mu drifted {}",
                    arch.name,
                    mu.max_abs_diff(&mu_f)
                );
                assert!(var.max_abs_diff(&var_f) < 0.5, "{} {p} var", arch.name);
            }
        }
    }

    #[test]
    fn scalar_isa_forward_matches_native_closely() {
        // the cross-ISA tolerance contract through the whole executor:
        // <= 1e-4 relative (trivially equal when detection reports scalar)
        use crate::ops::simd::Isa;
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 21);
            let x = input(&arch, 2, 14);
            let (mu_n, var_n) =
                PfpExecutor::new(arch.clone(), w.clone(), Schedules::tuned(1)).forward(&x);
            let (mu_s, var_s) = PfpExecutor::new(
                arch.clone(),
                w,
                Schedules::tuned(1).with_isa_override(Some(Isa::Scalar)),
            )
            .forward(&x);
            assert!(mu_n.allclose(&mu_s, 1e-4, 1e-4), "{} mu", arch.name);
            assert!(var_n.allclose(&var_s, 1e-3, 1e-4), "{} var", arch.name);
        }
    }

    #[test]
    fn mlp_pfp_forward_shapes() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        let x = input(&arch, 4, 0);
        let (mu, var) = ex.forward(&x);
        assert_eq!(mu.shape(), &[4, 10]);
        assert_eq!(var.shape(), &[4, 10]);
        assert!(var.data().iter().all(|&v| v >= 0.0));
        assert!(mu.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lenet_pfp_forward_shapes() {
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 2);
        let mut ex = PfpExecutor::new(arch.clone(), w, Schedules::default());
        let x = input(&arch, 2, 1);
        let (mu, var) = ex.forward(&x);
        assert_eq!(mu.shape(), &[2, 10]);
        assert!(var.data().iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn baseline_and_tuned_schedules_agree() {
        // The schedule knobs must not change the math. Pool implementation
        // is held fixed (vectorized) because generic-vs-vectorized pooling
        // is a (slightly) different approximation, not a schedule knob.
        for arch in [Arch::mlp(), Arch::lenet()] {
            let w = PosteriorWeights::synthetic(&arch, 3);
            let x = input(&arch, 2, 2);
            let mut base = Schedules::baseline();
            base.vectorized_pool = true;
            let (mu_a, var_a) =
                PfpExecutor::new(arch.clone(), w.clone(), base).forward(&x);
            let (mu_b, var_b) =
                PfpExecutor::new(arch.clone(), w, Schedules::tuned(2)).forward(&x);
            assert!(mu_a.allclose(&mu_b, 1e-4, 1e-4), "{} mu", arch.name);
            assert!(var_a.allclose(&var_b, 2e-3, 2e-3), "{} var", arch.name);
        }
    }

    #[test]
    fn pool_implementations_stay_close_through_network() {
        // generic vs vectorized pool: different association order, same
        // approximated quantity — logits must stay close, not identical.
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 3);
        let x = input(&arch, 2, 2);
        let (mu_a, _) =
            PfpExecutor::new(arch.clone(), w.clone(), Schedules::baseline()).forward(&x);
        let (mu_b, _) =
            PfpExecutor::new(arch.clone(), w, Schedules::tuned(1)).forward(&x);
        assert!(mu_a.max_abs_diff(&mu_b) < 0.1, "pool divergence too large");
    }

    #[test]
    fn zero_sigma_pfp_mean_matches_det() {
        let arch = Arch::mlp();
        let mut w = PosteriorWeights::synthetic(&arch, 4);
        for l in w.layers.iter_mut() {
            *l = LayerWeightsZero::zeroed(l);
        }
        let x = input(&arch, 3, 3);
        let (mu, var) = PfpExecutor::new(arch.clone(), w.clone(), Schedules::default())
            .forward(&x);
        let det = DetExecutor::new(arch, w, Schedules::default()).forward(&x);
        assert!(mu.allclose(&det, 2e-3, 2e-3));
        assert!(var.data().iter().all(|&v| v < 1e-3));
    }

    struct LayerWeightsZero;
    impl LayerWeightsZero {
        fn zeroed(l: &crate::model::LayerWeights) -> crate::model::LayerWeights {
            crate::model::LayerWeights::from_posterior(
                l.w_mu.clone(),
                l.w_sigma.map(|_| 1e-8),
                l.b_mu.clone(),
                l.b_sigma.map(|_| 1e-8),
                1.0,
            )
        }
    }

    #[test]
    fn svi_samples_scatter_around_pfp_mean() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 5);
        let x = input(&arch, 2, 4);
        let (mu, _) =
            PfpExecutor::new(arch.clone(), w.clone(), Schedules::default()).forward(&x);
        let mut svi = SviExecutor::new(arch, w, Schedules::default(), 7);
        let samples = svi.forward_n(&x, 64);
        // empirical mean of SVI logits approximates the PFP mean
        let mut emp = vec![0.0f32; mu.len()];
        for s in &samples {
            for (e, v) in emp.iter_mut().zip(s.data()) {
                *e += v / samples.len() as f32;
            }
        }
        let emp_t = Tensor::new(mu.shape().to_vec(), emp).unwrap();
        let diff = emp_t.max_abs_diff(&mu);
        assert!(diff < 0.5, "SVI empirical mean too far from PFP mean: {diff}");
    }

    #[test]
    fn executor_trait_unifies_pfp_and_det() {
        // both concrete executors behind one Box<dyn Executor>, same
        // dispatch surface; det reports zero variance.
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 31);
        let x = input(&arch, 2, 19);
        let mut execs: Vec<Box<dyn Executor>> = vec![
            Box::new(PfpExecutor::new(arch.clone(), w.clone(), Schedules::tuned(1))),
            Box::new(DetExecutor::new(arch.clone(), w, Schedules::tuned(1))),
        ];
        for ex in execs.iter_mut() {
            assert_eq!(ex.arch().name, "mlp");
            let (mu, var) = ex.forward(&x).unwrap();
            assert_eq!(mu.shape(), &[2, 10]);
            assert_eq!(var.shape(), &[2, 10]);
            assert_eq!(ex.plan_compiles(), 1);
            assert_eq!(ex.cached_batches(), vec![2]);
            assert!(ex.plan_bytes() > 0, "workspace bytes must be accounted");
            let (batch, stamp) = ex.lru_plan().unwrap();
            assert_eq!(batch, 2);
            assert!(stamp > 0);
        }
        let det_var = execs[1].forward(&x).unwrap().1;
        assert!(det_var.data().iter().all(|&v| v == 0.0));
        // targeted eviction is counted and frees the footprint
        assert!(execs[0].evict_plan(2));
        assert!(!execs[0].evict_plan(2));
        assert_eq!(execs[0].plan_evictions(), 1);
        assert_eq!(execs[0].plan_bytes(), 0);
    }

    #[test]
    fn global_plan_clock_orders_across_executors() {
        // LRU stamps from two different executors must be comparable —
        // the cross-model eviction ordering the registry relies on.
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 32);
        let mut a = PfpExecutor::new(arch.clone(), w.clone(), Schedules::tuned(1));
        let mut b = PfpExecutor::new(arch.clone(), w, Schedules::tuned(1));
        let x = input(&arch, 1, 3);
        let _ = a.forward(&x);
        let _ = b.forward(&x);
        let sa = Executor::lru_plan(&a).unwrap().1;
        let sb = Executor::lru_plan(&b).unwrap().1;
        assert!(sb > sa, "second touch must carry a later global stamp");
        let _ = a.forward(&x);
        assert!(Executor::lru_plan(&a).unwrap().1 > sb);
    }

    #[test]
    fn builder_is_order_independent() {
        use crate::ops::simd::Isa;
        // the with_* hazard: from_records had to be outermost. The
        // builder attaches records lazily, so knob order cannot matter.
        let mut r = crate::tuner::TuningRecords::default();
        let tuned = Schedule::tuned(1).with_unroll(4);
        r.insert(crate::tuner::TuningRecords::layer_key("dense", "mlp", 0, 8), tuned, 0.1);
        let records = Arc::new(r);
        let arch = Arch::mlp();

        let a = SchedulesBuilder::tuned(2)
            .records(Some(Arc::clone(&records)))
            .plan_threads(3)
            .isa_override(Some(Isa::Scalar))
            .build();
        let b = SchedulesBuilder::tuned(2)
            .isa_override(Some(Isa::Scalar))
            .plan_threads(3)
            .records(Some(Arc::clone(&records)))
            .build();
        for s in [&a, &b] {
            assert_eq!(s.plan_threads, 3);
            assert_eq!(s.isa_override, Some(Isa::Scalar));
            assert!(s.records.is_some());
            // lazy: tables resolve at cold compile via for_batch
            let resolved = s.for_batch(&arch, 8);
            assert_eq!(
                resolved.layer_schedule(0, arch.compute_layers()[0]),
                tuned.with_isa(Isa::Scalar),
                "records must resolve under the ISA override regardless of order"
            );
        }
        // eager form matches what serve used to do
        let eager = SchedulesBuilder::tuned(2)
            .records(Some(records))
            .build_for(&arch, 8);
        assert_eq!(eager.per_layer[0], Some(tuned));
    }

    #[test]
    fn profiler_covers_all_layers() {
        let arch = Arch::lenet();
        let w = PosteriorWeights::synthetic(&arch, 6);
        let mut ex =
            PfpExecutor::new(arch.clone(), w, Schedules::default()).with_profiling();
        let x = input(&arch, 1, 5);
        let _ = ex.forward(&x);
        let prof = ex.profiler.take();
        let layers = prof.by_layer();
        // 5 compute + 4 relu + 2 pool (+ conversions)
        assert!(layers.len() >= 11, "got {} rows", layers.len());
        let types = prof.by_op_type();
        let names: Vec<&str> = types.iter().map(|r| r.label.as_str()).collect();
        for want in ["dense", "conv2d", "relu", "maxpool"] {
            assert!(names.contains(&want), "missing op type {want}");
        }
    }
}
