//! Model layer: architecture specs, posterior weight store, and the
//! native graph executor (PFP / deterministic / SVI).
//!
//! Architecture specs mirror `python/compile/model.py::ARCHS` exactly; the
//! integration tests cross-check the native executor against the JAX
//! goldens in `artifacts/goldens.npz`.

pub mod executor;
pub mod npz;
pub mod weights;

pub use executor::{
    DetExecutor, Executor, FusePolicy, PfpExecutor, Schedules, SchedulesBuilder, SviExecutor,
};
pub use weights::{pack_tensor, LayerWeights, LoadedWeights, PosteriorWeights};

use crate::error::{Error, Result};

/// One layer of an architecture.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    Dense { d_in: usize, d_out: usize },
    Conv { in_ch: usize, out_ch: usize, k: usize },
    Relu,
    MaxPool2,
    Flatten,
}

impl LayerSpec {
    pub fn is_compute(&self) -> bool {
        matches!(self, LayerSpec::Dense { .. } | LayerSpec::Conv { .. })
    }

    /// Operator-type label for Fig. 6 / Table 4 grouping.
    pub fn op_type(&self) -> &'static str {
        match self {
            LayerSpec::Dense { .. } => "dense",
            LayerSpec::Conv { .. } => "conv2d",
            LayerSpec::Relu => "relu",
            LayerSpec::MaxPool2 => "maxpool",
            LayerSpec::Flatten => "flatten",
        }
    }
}

/// A full architecture: layer list + input shape (without batch dim).
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<LayerSpec>,
}

impl Arch {
    /// The paper's 3-layer MLP: 784-100-100-10.
    pub fn mlp() -> Self {
        Self {
            name: "mlp".into(),
            input_shape: vec![784],
            layers: vec![
                LayerSpec::Dense { d_in: 784, d_out: 100 },
                LayerSpec::Relu,
                LayerSpec::Dense { d_in: 100, d_out: 100 },
                LayerSpec::Relu,
                LayerSpec::Dense { d_in: 100, d_out: 10 },
            ],
        }
    }

    /// LeNet-5 on 28x28 (VALID convs): 6@5x5 / pool / 16@5x5 / pool /
    /// 256-120-84-10.
    pub fn lenet() -> Self {
        Self {
            name: "lenet".into(),
            input_shape: vec![1, 28, 28],
            layers: vec![
                LayerSpec::Conv { in_ch: 1, out_ch: 6, k: 5 },
                LayerSpec::Relu,
                LayerSpec::MaxPool2,
                LayerSpec::Conv { in_ch: 6, out_ch: 16, k: 5 },
                LayerSpec::Relu,
                LayerSpec::MaxPool2,
                LayerSpec::Flatten,
                LayerSpec::Dense { d_in: 256, d_out: 120 },
                LayerSpec::Relu,
                LayerSpec::Dense { d_in: 120, d_out: 84 },
                LayerSpec::Relu,
                LayerSpec::Dense { d_in: 84, d_out: 10 },
            ],
        }
    }

    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "mlp" => Ok(Self::mlp()),
            "lenet" => Ok(Self::lenet()),
            other => Err(Error::Config(format!("unknown architecture '{other}'"))),
        }
    }

    /// Compute layers (dense/conv) in order.
    pub fn compute_layers(&self) -> Vec<&LayerSpec> {
        self.layers.iter().filter(|l| l.is_compute()).collect()
    }

    /// Per-layer human labels matching Table 4 ("Dense 1", "Conv2d 2", ...).
    pub fn layer_labels(&self) -> Vec<String> {
        let mut counts: std::collections::HashMap<&str, usize> = Default::default();
        self.layers
            .iter()
            .map(|l| {
                let t = l.op_type();
                let c = counts.entry(t).or_insert(0);
                *c += 1;
                format!("{} {}", pretty(t), c)
            })
            .collect()
    }

    /// Number of classes (output width of the last dense layer).
    pub fn num_classes(&self) -> usize {
        for l in self.layers.iter().rev() {
            if let LayerSpec::Dense { d_out, .. } = l {
                return *d_out;
            }
        }
        0
    }

    /// Flat input feature count.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

fn pretty(t: &str) -> &'static str {
    match t {
        "dense" => "Dense",
        "conv2d" => "Conv2d",
        "relu" => "ReLU",
        "maxpool" => "Max Pool",
        _ => "Flatten",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_spec_matches_python() {
        let a = Arch::mlp();
        assert_eq!(a.layers.len(), 5);
        assert_eq!(a.compute_layers().len(), 3);
        assert_eq!(a.num_classes(), 10);
        assert_eq!(a.input_len(), 784);
    }

    #[test]
    fn lenet_spec_matches_python() {
        let a = Arch::lenet();
        assert_eq!(a.compute_layers().len(), 5);
        assert_eq!(a.num_classes(), 10);
        // 4 ReLUs, 2 pools — the Table 4 inventory
        assert_eq!(a.layers.iter().filter(|l| matches!(l, LayerSpec::Relu)).count(), 4);
        assert_eq!(
            a.layers.iter().filter(|l| matches!(l, LayerSpec::MaxPool2)).count(),
            2
        );
    }

    #[test]
    fn labels_enumerate_per_type() {
        let labels = Arch::lenet().layer_labels();
        assert_eq!(labels[0], "Conv2d 1");
        assert_eq!(labels[3], "Conv2d 2");
        assert!(labels.contains(&"Dense 3".to_string()));
    }

    #[test]
    fn unknown_arch_errors() {
        assert!(Arch::by_name("resnet").is_err());
    }
}
