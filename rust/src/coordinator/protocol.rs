//! Wire protocol: line-delimited JSON over TCP, versioned.
//!
//! Every message travels in a uniform envelope carrying the protocol
//! version:
//!
//!   {"v": 1, "id": 7, "model": "mlp", "input": [784 floats]}
//!   {"v": 1, "cmd": "metrics"} | {"v":1,"cmd":"ping"} | {"v":1,"cmd":"shutdown"}
//!   {"v": 1, "cmd": "hello", "pipeline": true}
//!   {"v": 1, "cmd": "load", "model": "mlp2", "path": "weights_mlp.npz",
//!    "arch": "mlp", "calib": 0.3}
//!   {"v": 1, "cmd": "swap", "model": "mlp2", "path": "weights_mlp_v2.npz"}
//!   {"v": 1, "cmd": "unload", "model": "mlp2"}
//!   {"v": 1, "cmd": "models"}
//!
//! Responses (v1):
//!   {"v": 1, "id": 7, "version": 2, "pred": 3, "mu": [...], "var": [...],
//!    "total": 0.41, "sme": 0.33, "mi": 0.08, "ood": false,
//!    "queue_us": 120, "infer_us": 850}
//!   {"v": 1, "id": 7, "error": "queue full"}
//!
//! `version` is the registry model version that computed the prediction —
//! the observable half of the hot-swap guarantee (in-flight requests keep
//! reporting the pre-swap version; legacy non-registry lanes omit it).
//!
//! **v0 compatibility**: messages without `"v"` are accepted as legacy v0
//! and answered without an envelope, exactly as before this protocol
//! existed — except that the first v0 reply on a connection carries a
//! one-time `"deprecated"` warning field. Messages with an unknown
//! version are rejected outright. [`Envelope::parse`] is the single
//! parse path for both generations (the old free-standing
//! [`parse_inbound`] survives as a deprecated shim).
//!
//! Pipelining semantics are unchanged from the unversioned protocol: a
//! `hello` handshake opts into `pipeline_depth` requests in flight with
//! completion-order responses tagged by `id`; connections that never
//! send `hello` get strict one-in-flight in-order service.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// The current wire protocol version.
pub const PROTOCOL_VERSION: u64 = 1;

/// The protocol generation a message arrived under (and its reply must
/// be serialized under).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProtoVersion {
    /// Legacy unversioned messages (no `"v"` field). Deprecated.
    #[default]
    V0,
    V1,
}

impl ProtoVersion {
    pub fn as_u64(self) -> u64 {
        match self {
            ProtoVersion::V0 => 0,
            ProtoVersion::V1 => 1,
        }
    }
}

/// The one-time warning attached to the first v0 reply on a connection.
pub const V0_DEPRECATION: &str =
    "unversioned protocol (v0) is deprecated; send {\"v\":1,...} envelopes";

/// A client inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub input: Vec<f32>,
}

/// Control commands.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Metrics,
    Ping,
    Shutdown,
    /// Pipelining handshake: `pipeline: false` pins the connection to one
    /// request in flight; `true` (the default) requests the server's full
    /// configured depth.
    Hello { pipeline: bool },
    /// Admin: publish a new model from a weight archive. `arch` defaults
    /// to the model name; `calib` to the server's configured factor.
    Load {
        model: String,
        path: String,
        arch: Option<String>,
        calib: Option<f64>,
    },
    /// Admin: atomically publish the next version of a loaded model.
    /// In-flight requests finish on the old version.
    Swap {
        model: String,
        path: String,
        arch: Option<String>,
        calib: Option<f64>,
    },
    /// Admin: remove a model (in-flight requests drain first).
    Unload { model: String },
    /// Admin: list registered models with version/checksum/plan-cache
    /// metadata.
    Models,
}

/// A parsed inbound message body.
#[derive(Clone, Debug)]
pub enum Inbound {
    Infer(Request),
    Control(Command),
}

/// A parsed inbound message: body + the protocol generation it arrived
/// under. This is the single parse path for every wire message.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub proto: ProtoVersion,
    pub body: Inbound,
}

impl Envelope {
    pub fn parse(line: &str) -> Result<Envelope> {
        let v = Json::parse(line)?;
        let proto = match v.get("v") {
            None => ProtoVersion::V0,
            Some(j) => match j.as_f64() {
                Some(x) if x == PROTOCOL_VERSION as f64 => ProtoVersion::V1,
                Some(x) => {
                    return Err(Error::Coordinator(format!(
                        "unknown protocol version {x} (this server speaks v{PROTOCOL_VERSION})"
                    )))
                }
                None => {
                    return Err(Error::Coordinator(
                        "protocol version 'v' must be a number".into(),
                    ))
                }
            },
        };
        let body = if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
            let model = || -> Result<String> { Ok(v.str_field("model")?.to_string()) };
            let path = || -> Result<String> { Ok(v.str_field("path")?.to_string()) };
            let arch = v.get("arch").and_then(Json::as_str).map(String::from);
            let calib = v.get("calib").and_then(Json::as_f64);
            Inbound::Control(match cmd {
                "metrics" => Command::Metrics,
                "ping" => Command::Ping,
                "shutdown" => Command::Shutdown,
                "hello" => Command::Hello {
                    pipeline: v.get("pipeline").and_then(Json::as_bool).unwrap_or(true),
                },
                "load" => Command::Load { model: model()?, path: path()?, arch, calib },
                "swap" => Command::Swap { model: model()?, path: path()?, arch, calib },
                "unload" => Command::Unload { model: model()? },
                "models" => Command::Models,
                c => return Err(Error::Coordinator(format!("unknown command '{c}'"))),
            })
        } else {
            let id = v.num_field("id")? as u64;
            let model = v.str_field("model")?.to_string();
            let input = v
                .get("input")
                .ok_or_else(|| Error::Coordinator("missing input".into()))?
                .to_f32_vec()?;
            Inbound::Infer(Request { id, model, input })
        };
        Ok(Envelope { proto, body })
    }

    /// Stamp `body` with the envelope fields for `proto`: v1 gains
    /// `"v":1`; v0 stays bare (legacy shape). `warning`, when present,
    /// is attached as a `"deprecated"` field either way — the server
    /// sends it once per v0 connection.
    pub fn seal(body: Json, proto: ProtoVersion, warning: Option<&str>) -> Json {
        let mut map = match body {
            Json::Obj(m) => m,
            other => {
                let mut m = std::collections::BTreeMap::new();
                m.insert("body".to_string(), other);
                m
            }
        };
        if proto == ProtoVersion::V1 {
            map.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
        }
        if let Some(w) = warning {
            map.insert("deprecated".to_string(), Json::Str(w.to_string()));
        }
        Json::Obj(map)
    }
}

/// Legacy single-shot parse (pre-envelope). Use [`Envelope::parse`],
/// which also reports the protocol generation the reply must carry.
#[deprecated(note = "use Envelope::parse; it returns the protocol version too")]
pub fn parse_inbound(line: &str) -> Result<Inbound> {
    Ok(Envelope::parse(line)?.body)
}

/// One prediction with uncertainty decomposition.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub pred: i32,
    pub mu: Vec<f32>,
    pub var: Vec<f32>,
    pub total: f64,
    pub sme: f64,
    pub mi: f64,
    pub ood: bool,
}

/// A server response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: std::result::Result<Prediction, String>,
    pub queue_us: u64,
    pub infer_us: u64,
    /// Protocol generation of the request this answers (the reply is
    /// serialized in kind).
    pub proto: ProtoVersion,
    /// Registry model version that served the request; 0 on legacy
    /// (non-registry) lanes, serialized as `"version"` when nonzero.
    pub model_version: u64,
}

impl Response {
    /// A front-end-generated error response (depth overrun, load shed,
    /// submit failure) carrying no timing and no model version.
    pub fn error(id: u64, msg: impl Into<String>, proto: ProtoVersion) -> Self {
        Self {
            id,
            result: Err(msg.into()),
            queue_us: 0,
            infer_us: 0,
            proto,
            model_version: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        let body = match &self.result {
            Ok(p) => {
                let mut fields = vec![
                    ("id", Json::Num(self.id as f64)),
                    ("pred", Json::Num(p.pred as f64)),
                    ("mu", Json::arr_f32(&p.mu)),
                    ("var", Json::arr_f32(&p.var)),
                    ("total", Json::Num(p.total)),
                    ("sme", Json::Num(p.sme)),
                    ("mi", Json::Num(p.mi)),
                    ("ood", Json::Bool(p.ood)),
                    ("queue_us", Json::Num(self.queue_us as f64)),
                    ("infer_us", Json::Num(self.infer_us as f64)),
                ];
                if self.model_version > 0 {
                    fields.push(("version", Json::Num(self.model_version as f64)));
                }
                Json::obj(fields)
            }
            Err(e) => Json::obj(vec![
                ("id", Json::Num(self.id as f64)),
                ("error", Json::Str(e.clone())),
            ]),
        };
        Envelope::seal(body, self.proto, None)
    }

    pub fn parse(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let proto = match v.get("v").and_then(Json::as_f64) {
            Some(x) if x == 1.0 => ProtoVersion::V1,
            _ => ProtoVersion::V0,
        };
        let model_version = v.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let id = v.num_field("id")? as u64;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            return Ok(Response {
                id,
                result: Err(err.to_string()),
                queue_us: 0,
                infer_us: 0,
                proto,
                model_version,
            });
        }
        Ok(Response {
            id,
            result: Ok(Prediction {
                pred: v.num_field("pred")? as i32,
                mu: v.get("mu").map(|m| m.to_f32_vec()).transpose()?.unwrap_or_default(),
                var: v.get("var").map(|m| m.to_f32_vec()).transpose()?.unwrap_or_default(),
                total: v.num_field("total")?,
                sme: v.num_field("sme")?,
                mi: v.num_field("mi")?,
                ood: v.get("ood").and_then(Json::as_bool).unwrap_or(false),
            }),
            queue_us: v.get("queue_us").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            infer_us: v.get("infer_us").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            proto,
            model_version,
        })
    }
}

/// Serialize the server's `hello` handshake acknowledgement.
pub fn hello_json(pipeline: bool, pipeline_depth: usize, max_batch: usize) -> String {
    hello_json_proto(pipeline, pipeline_depth, max_batch, ProtoVersion::V0, None)
}

/// Versioned `hello` ack; `warning` carries the one-time v0 deprecation
/// notice.
pub fn hello_json_proto(
    pipeline: bool,
    pipeline_depth: usize,
    max_batch: usize,
    proto: ProtoVersion,
    warning: Option<&str>,
) -> String {
    Envelope::seal(
        Json::obj(vec![
            ("hello", Json::Bool(true)),
            ("pipeline", Json::Bool(pipeline)),
            ("pipeline_depth", Json::Num(pipeline_depth as f64)),
            ("max_batch", Json::Num(max_batch as f64)),
        ]),
        proto,
        warning,
    )
    .dump()
}

/// Serialize a legacy (v0) inference request.
pub fn request_json(id: u64, model: &str, input: &[f32]) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("model", Json::Str(model.to_string())),
        ("input", Json::arr_f32(input)),
    ])
    .dump()
}

/// Serialize a v1-envelope inference request.
pub fn request_json_v1(id: u64, model: &str, input: &[f32]) -> String {
    Envelope::seal(
        Json::parse(&request_json(id, model, input)).expect("request is valid json"),
        ProtoVersion::V1,
        None,
    )
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(line: &str) -> Envelope {
        Envelope::parse(line).unwrap()
    }

    #[test]
    fn v0_request_roundtrip_with_legacy_proto() {
        let line = request_json(7, "mlp", &[0.1, 0.2]);
        let env = parse(&line);
        assert_eq!(env.proto, ProtoVersion::V0);
        match env.body {
            Inbound::Infer(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.model, "mlp");
                assert_eq!(r.input.len(), 2);
            }
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn v1_request_roundtrip() {
        let line = request_json_v1(9, "mlp", &[0.5]);
        assert!(line.contains("\"v\":1"), "{line}");
        let env = parse(&line);
        assert_eq!(env.proto, ProtoVersion::V1);
        match env.body {
            Inbound::Infer(r) => assert_eq!(r.id, 9),
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn unknown_version_rejected() {
        let err = Envelope::parse(r#"{"v":2,"cmd":"ping"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown protocol version"), "{err}");
        assert!(Envelope::parse(r#"{"v":"one","cmd":"ping"}"#).is_err());
    }

    #[test]
    fn control_commands_both_generations() {
        for (line, proto) in [
            (r#"{"cmd":"metrics"}"#, ProtoVersion::V0),
            (r#"{"v":1,"cmd":"metrics"}"#, ProtoVersion::V1),
        ] {
            let env = parse(line);
            assert_eq!(env.proto, proto);
            assert!(matches!(env.body, Inbound::Control(Command::Metrics)));
        }
        assert!(matches!(
            parse(r#"{"v":1,"cmd":"shutdown"}"#).body,
            Inbound::Control(Command::Shutdown)
        ));
        assert!(Envelope::parse(r#"{"cmd":"reboot"}"#).is_err());
    }

    #[test]
    fn admin_commands_parse() {
        let env = parse(
            r#"{"v":1,"cmd":"load","model":"m2","path":"w.npz","arch":"mlp","calib":0.3}"#,
        );
        match env.body {
            Inbound::Control(Command::Load { model, path, arch, calib }) => {
                assert_eq!(model, "m2");
                assert_eq!(path, "w.npz");
                assert_eq!(arch.as_deref(), Some("mlp"));
                assert!((calib.unwrap() - 0.3).abs() < 1e-9);
            }
            other => panic!("expected load, got {other:?}"),
        }
        match parse(r#"{"v":1,"cmd":"swap","model":"m2","path":"w2.npz"}"#).body {
            Inbound::Control(Command::Swap { model, path, arch, calib }) => {
                assert_eq!(model, "m2");
                assert_eq!(path, "w2.npz");
                assert!(arch.is_none() && calib.is_none());
            }
            other => panic!("expected swap, got {other:?}"),
        }
        assert!(matches!(
            parse(r#"{"v":1,"cmd":"unload","model":"m2"}"#).body,
            Inbound::Control(Command::Unload { .. })
        ));
        assert!(matches!(
            parse(r#"{"v":1,"cmd":"models"}"#).body,
            Inbound::Control(Command::Models)
        ));
        // load without a path is malformed
        assert!(Envelope::parse(r#"{"v":1,"cmd":"load","model":"m2"}"#).is_err());
    }

    #[test]
    fn hello_handshake() {
        let env = parse(r#"{"cmd":"hello","pipeline":true}"#);
        assert!(matches!(env.body, Inbound::Control(Command::Hello { pipeline: true })));
        assert!(matches!(
            parse(r#"{"cmd":"hello","pipeline":false}"#).body,
            Inbound::Control(Command::Hello { pipeline: false })
        ));
        // absent field defaults to pipelining on
        assert!(matches!(
            parse(r#"{"cmd":"hello"}"#).body,
            Inbound::Control(Command::Hello { pipeline: true })
        ));
        let ack = hello_json(true, 10, 10);
        let v = Json::parse(&ack).unwrap();
        assert_eq!(v.get("hello").and_then(Json::as_bool), Some(true));
        assert_eq!(v.num_field("pipeline_depth").unwrap(), 10.0);
        assert!(v.get("v").is_none(), "v0 ack stays bare");

        let ack1 = hello_json_proto(true, 10, 10, ProtoVersion::V1, None);
        let v1 = Json::parse(&ack1).unwrap();
        assert_eq!(v1.num_field("v").unwrap(), 1.0);
    }

    #[test]
    fn v0_ack_can_carry_one_time_deprecation_warning() {
        let ack = hello_json_proto(true, 4, 8, ProtoVersion::V0, Some(V0_DEPRECATION));
        let v = Json::parse(&ack).unwrap();
        assert!(v.get("v").is_none());
        assert!(v.str_field("deprecated").unwrap().contains("deprecated"));
    }

    #[test]
    fn response_roundtrip_v0_and_v1() {
        for (proto, model_version) in
            [(ProtoVersion::V0, 0u64), (ProtoVersion::V1, 3u64)]
        {
            let resp = Response {
                id: 3,
                result: Ok(Prediction {
                    pred: 5,
                    mu: vec![1.0, 2.0],
                    var: vec![0.1, 0.2],
                    total: 0.5,
                    sme: 0.4,
                    mi: 0.1,
                    ood: true,
                }),
                queue_us: 10,
                infer_us: 20,
                proto,
                model_version,
            };
            let line = resp.to_json().dump();
            if proto == ProtoVersion::V1 {
                assert!(line.contains("\"v\":1"), "{line}");
                assert!(line.contains("\"version\":3"), "{line}");
            } else {
                assert!(!line.contains("\"v\":"), "{line}");
            }
            let parsed = Response::parse(&line).unwrap();
            assert_eq!(parsed.id, 3);
            assert_eq!(parsed.proto, proto);
            assert_eq!(parsed.model_version, model_version);
            let p = parsed.result.unwrap();
            assert_eq!(p.pred, 5);
            assert!(p.ood);
            assert!((p.mi - 0.1).abs() < 1e-9);
        }
    }

    #[test]
    fn error_response() {
        let resp = Response {
            id: 9,
            result: Err("queue full".into()),
            queue_us: 0,
            infer_us: 0,
            proto: ProtoVersion::V1,
            model_version: 0,
        };
        let line = resp.to_json().dump();
        assert!(line.contains("\"v\":1"));
        let parsed = Response::parse(&line).unwrap();
        assert_eq!(parsed.result.unwrap_err(), "queue full");
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_parse_inbound_shim_still_works() {
        assert!(matches!(
            parse_inbound(r#"{"cmd":"ping"}"#).unwrap(),
            Inbound::Control(Command::Ping)
        ));
    }
}
