//! Wire protocol: line-delimited JSON over TCP.
//!
//! Requests:
//!   {"id": 7, "model": "mlp", "input": [784 floats]}
//!   {"cmd": "metrics"} | {"cmd": "ping"} | {"cmd": "shutdown"}
//!   {"cmd": "hello", "pipeline": true}
//!
//! Responses:
//!   {"id": 7, "pred": 3, "mu": [...], "var": [...],
//!    "total": 0.41, "sme": 0.33, "mi": 0.08, "ood": false,
//!    "queue_us": 120, "infer_us": 850}
//!   {"id": 7, "error": "queue full"}
//!   {"hello": true, "pipeline": true, "pipeline_depth": 10, "max_batch": 10}
//!
//! Pipelining: after a `{"cmd": "hello", "pipeline": true}` handshake a
//! connection may keep up to `pipeline_depth` inference requests in
//! flight without reading responses; responses come back tagged by `id`
//! in **completion order**, not submission order, and overrunning the
//! window yields an explicit `{"id": N, "error": "pipeline depth ..."}`
//! response. The handshake ack advertises the server's depth;
//! `"pipeline": false` opts back out. Connections that never send
//! `hello` are served with the legacy synchronous semantics — one
//! request in flight, strictly in-order replies, reader-side
//! backpressure — so old clients (lockstep *or* write-pipelining) behave
//! identically to the pre-pipelining server. A request refused before
//! reaching a model lane (unknown model, bad feature count, full queue)
//! also gets an explicit per-request error response `{"id": N, "error":
//! "..."}` so the client can match it to the request it sent.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// A client inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub input: Vec<f32>,
}

/// Control commands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Command {
    Metrics,
    Ping,
    Shutdown,
    /// Pipelining handshake: `pipeline: false` pins the connection to one
    /// request in flight; `true` (the default) requests the server's full
    /// configured depth.
    Hello { pipeline: bool },
}

/// A parsed inbound message.
#[derive(Clone, Debug)]
pub enum Inbound {
    Infer(Request),
    Control(Command),
}

pub fn parse_inbound(line: &str) -> Result<Inbound> {
    let v = Json::parse(line)?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return Ok(Inbound::Control(match cmd {
            "metrics" => Command::Metrics,
            "ping" => Command::Ping,
            "shutdown" => Command::Shutdown,
            "hello" => Command::Hello {
                pipeline: v.get("pipeline").and_then(Json::as_bool).unwrap_or(true),
            },
            c => return Err(Error::Coordinator(format!("unknown command '{c}'"))),
        }));
    }
    let id = v.num_field("id")? as u64;
    let model = v.str_field("model")?.to_string();
    let input = v
        .get("input")
        .ok_or_else(|| Error::Coordinator("missing input".into()))?
        .to_f32_vec()?;
    Ok(Inbound::Infer(Request { id, model, input }))
}

/// One prediction with uncertainty decomposition.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub pred: i32,
    pub mu: Vec<f32>,
    pub var: Vec<f32>,
    pub total: f64,
    pub sme: f64,
    pub mi: f64,
    pub ood: bool,
}

/// A server response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub result: std::result::Result<Prediction, String>,
    pub queue_us: u64,
    pub infer_us: u64,
}

impl Response {
    pub fn to_json(&self) -> Json {
        match &self.result {
            Ok(p) => Json::obj(vec![
                ("id", Json::Num(self.id as f64)),
                ("pred", Json::Num(p.pred as f64)),
                ("mu", Json::arr_f32(&p.mu)),
                ("var", Json::arr_f32(&p.var)),
                ("total", Json::Num(p.total)),
                ("sme", Json::Num(p.sme)),
                ("mi", Json::Num(p.mi)),
                ("ood", Json::Bool(p.ood)),
                ("queue_us", Json::Num(self.queue_us as f64)),
                ("infer_us", Json::Num(self.infer_us as f64)),
            ]),
            Err(e) => Json::obj(vec![
                ("id", Json::Num(self.id as f64)),
                ("error", Json::Str(e.clone())),
            ]),
        }
    }

    pub fn parse(line: &str) -> Result<Self> {
        let v = Json::parse(line)?;
        let id = v.num_field("id")? as u64;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            return Ok(Response {
                id,
                result: Err(err.to_string()),
                queue_us: 0,
                infer_us: 0,
            });
        }
        Ok(Response {
            id,
            result: Ok(Prediction {
                pred: v.num_field("pred")? as i32,
                mu: v.get("mu").map(|m| m.to_f32_vec()).transpose()?.unwrap_or_default(),
                var: v.get("var").map(|m| m.to_f32_vec()).transpose()?.unwrap_or_default(),
                total: v.num_field("total")?,
                sme: v.num_field("sme")?,
                mi: v.num_field("mi")?,
                ood: v.get("ood").and_then(Json::as_bool).unwrap_or(false),
            }),
            queue_us: v.get("queue_us").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            infer_us: v.get("infer_us").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        })
    }
}

/// Serialize the server's `hello` handshake acknowledgement.
pub fn hello_json(pipeline: bool, pipeline_depth: usize, max_batch: usize) -> String {
    Json::obj(vec![
        ("hello", Json::Bool(true)),
        ("pipeline", Json::Bool(pipeline)),
        ("pipeline_depth", Json::Num(pipeline_depth as f64)),
        ("max_batch", Json::Num(max_batch as f64)),
    ])
    .dump()
}

/// Serialize an inference request.
pub fn request_json(id: u64, model: &str, input: &[f32]) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("model", Json::Str(model.to_string())),
        ("input", Json::arr_f32(input)),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let line = request_json(7, "mlp", &[0.1, 0.2]);
        match parse_inbound(&line).unwrap() {
            Inbound::Infer(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.model, "mlp");
                assert_eq!(r.input.len(), 2);
            }
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn control_commands() {
        assert!(matches!(
            parse_inbound(r#"{"cmd":"metrics"}"#).unwrap(),
            Inbound::Control(Command::Metrics)
        ));
        assert!(matches!(
            parse_inbound(r#"{"cmd":"shutdown"}"#).unwrap(),
            Inbound::Control(Command::Shutdown)
        ));
        assert!(parse_inbound(r#"{"cmd":"reboot"}"#).is_err());
    }

    #[test]
    fn hello_handshake() {
        assert!(matches!(
            parse_inbound(r#"{"cmd":"hello","pipeline":true}"#).unwrap(),
            Inbound::Control(Command::Hello { pipeline: true })
        ));
        assert!(matches!(
            parse_inbound(r#"{"cmd":"hello","pipeline":false}"#).unwrap(),
            Inbound::Control(Command::Hello { pipeline: false })
        ));
        // absent field defaults to pipelining on
        assert!(matches!(
            parse_inbound(r#"{"cmd":"hello"}"#).unwrap(),
            Inbound::Control(Command::Hello { pipeline: true })
        ));
        let ack = hello_json(true, 10, 10);
        let v = crate::util::json::Json::parse(&ack).unwrap();
        assert_eq!(v.get("hello").and_then(Json::as_bool), Some(true));
        assert_eq!(v.num_field("pipeline_depth").unwrap(), 10.0);
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response {
            id: 3,
            result: Ok(Prediction {
                pred: 5,
                mu: vec![1.0, 2.0],
                var: vec![0.1, 0.2],
                total: 0.5,
                sme: 0.4,
                mi: 0.1,
                ood: true,
            }),
            queue_us: 10,
            infer_us: 20,
        };
        let parsed = Response::parse(&resp.to_json().dump()).unwrap();
        assert_eq!(parsed.id, 3);
        let p = parsed.result.unwrap();
        assert_eq!(p.pred, 5);
        assert!(p.ood);
        assert!((p.mi - 0.1).abs() < 1e-9);
    }

    #[test]
    fn error_response() {
        let resp = Response {
            id: 9,
            result: Err("queue full".into()),
            queue_us: 0,
            infer_us: 0,
        };
        let parsed = Response::parse(&resp.to_json().dump()).unwrap();
        assert_eq!(parsed.result.unwrap_err(), "queue full");
    }
}
