//! L3 coordinator: uncertainty-aware inference serving.
//!
//! The paper's deployment target is a low-latency embedded predictor; the
//! serving shape this repo gives it is a small inference server in the
//! vLLM-router mold:
//!
//! * [`protocol`] — line-delimited JSON wire format with a `hello`
//!   pipelining handshake and per-request error responses;
//! * [`batcher`] — per-model dynamic batching with a deadline (requests
//!   are coalesced up to `max_batch` or `max_wait`, mirroring the paper's
//!   per-mini-batch-size tuning: each bucket size maps to an executable
//!   tuned/compiled for that batch);
//! * [`metrics`] — latency histograms + counters (including an in-flight
//!   gauge and a per-connection pipeline-depth histogram), queryable
//!   in-band;
//! * [`server`] — event-driven TCP front end: a small fixed set of IO
//!   threads own every socket through a dependency-free epoll/kqueue
//!   [`reactor`], inbound bytes are framed by an incremental [`codec`],
//!   and responses flush through bounded per-connection output buffers
//!   driven by writability events (slow clients are back-pressured, then
//!   disconnected) — one client can keep `pipeline_depth` requests in
//!   flight and receive responses out of order (tagged by `id`), plus a
//!   worker thread per model;
//! * backends — native PFP operators or PJRT-compiled AOT artifacts, plus
//!   an SVI backend (N sampled passes) for baseline comparisons.
//!
//! Uncertainty post-processing happens here, after the single
//! probabilistic forward pass: Eq. 11 logit sampling, entropy / SME / MI,
//! and OOD flagging against a calibrated MI threshold.

pub mod batcher;
pub mod codec;
pub mod metrics;
pub mod protocol;
pub mod reactor;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use protocol::{Envelope, ProtoVersion, Request, Response, PROTOCOL_VERSION};
pub use server::{Reply, Server, ServerConfig, Service};

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::{Arch, PfpExecutor, PosteriorWeights, Schedules, SviExecutor};
use crate::runtime::{Engine, LoadedModel};
use crate::tensor::Tensor;
use crate::uncertainty;

/// An inference backend: batch of flattened inputs -> logit moments.
pub trait Backend: Send {
    /// `x: [B, features]` -> (mu `[B, K]`, var `[B, K]`).
    fn infer(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)>;
    fn name(&self) -> String;
    /// Called by [`Service::register`] so backends can publish their own
    /// counters (e.g. cold plan compiles). Default: no-op.
    fn attach_metrics(&mut self, _metrics: Arc<Metrics>) {}
}

/// Native-operator PFP backend.
///
/// Holds one compiled plan per dynamic-batcher bucket size (via the
/// executor's plan cache): the first batch of a given size pays a cold
/// compile — surfaced through the `plan_compiles` metric — and every
/// later batch of that size executes the cached plan with a reusable
/// zero-allocation workspace, realizing the paper's
/// bucket-to-compiled-executable mapping on the serving path.
pub struct NativePfpBackend {
    exec: PfpExecutor,
    metrics: Option<Arc<Metrics>>,
}

impl NativePfpBackend {
    pub fn new(arch: Arch, weights: PosteriorWeights, schedules: Schedules) -> Self {
        Self { exec: PfpExecutor::new(arch, weights, schedules), metrics: None }
    }

    /// Cold plan compiles so far (one per distinct batch size served).
    pub fn plan_compiles(&self) -> u64 {
        self.exec.plan_compiles()
    }

    /// Plans evicted from the bounded LRU cache so far (bucket working
    /// set exceeded the cap — cache thrash).
    pub fn plan_evictions(&self) -> u64 {
        self.exec.plan_evictions()
    }
}

impl Backend for NativePfpBackend {
    fn infer(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let before = self.exec.plan_compiles();
        let before_evict = self.exec.plan_evictions();
        let out = self.exec.forward(x);
        if let Some(m) = &self.metrics {
            let cold = self.exec.plan_compiles() - before;
            if cold > 0 {
                Metrics::add(&m.plan_compiles, cold);
            }
            let evicted = self.exec.plan_evictions() - before_evict;
            if evicted > 0 {
                Metrics::add(&m.plan_cache_evictions, evicted);
            }
        }
        Ok(out)
    }

    fn name(&self) -> String {
        format!("native-pfp/{}", self.exec.arch.name)
    }

    fn attach_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }
}

/// SVI baseline backend: N sampled deterministic passes, moments from the
/// empirical logit distribution.
pub struct SviBackend {
    exec: SviExecutor,
    pub n_samples: usize,
}

impl SviBackend {
    pub fn new(
        arch: Arch,
        weights: PosteriorWeights,
        schedules: Schedules,
        n_samples: usize,
        seed: u64,
    ) -> Self {
        Self { exec: SviExecutor::new(arch, weights, schedules, seed), n_samples }
    }
}

impl Backend for SviBackend {
    fn infer(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let samples = self.exec.forward_n(x, self.n_samples);
        let n = samples[0].len();
        let shape = samples[0].shape().to_vec();
        let mut mu = vec![0.0f32; n];
        let mut e2 = vec![0.0f32; n];
        for s in &samples {
            for i in 0..n {
                let v = s.data()[i];
                mu[i] += v / self.n_samples as f32;
                e2[i] += v * v / self.n_samples as f32;
            }
        }
        let var: Vec<f32> = mu
            .iter()
            .zip(&e2)
            .map(|(m, e)| (e - m * m).max(0.0))
            .collect();
        Ok((
            Tensor::new(shape.clone(), mu)?,
            Tensor::new(shape, var)?,
        ))
    }

    fn name(&self) -> String {
        format!("svi-{}/{}", self.n_samples, self.exec.arch.name)
    }
}

/// PJRT backend over AOT artifacts: picks the smallest compiled batch
/// bucket that fits, padding the batch dimension (the paper compiles one
/// tuned executable per mini-batch size).
pub struct XlaPfpBackend {
    models: Vec<Arc<LoadedModel>>, // sorted by batch asc
    arch: String,
}

impl XlaPfpBackend {
    pub fn new(engine: &Engine, arch: &str, weights: &PosteriorWeights) -> Result<Self> {
        let entries = engine.manifest.entries_for(arch, "pfp");
        if entries.is_empty() {
            return Err(Error::Manifest(format!("no pfp artifacts for {arch}")));
        }
        let names: Vec<String> = entries.iter().map(|e| e.name.clone()).collect();
        let mut models = Vec::new();
        for name in names {
            models.push(engine.load(&name, weights)?);
        }
        Ok(Self { models, arch: arch.to_string() })
    }

    fn pick(&self, batch: usize) -> &Arc<LoadedModel> {
        self.models
            .iter()
            .find(|m| m.batch() >= batch)
            .unwrap_or_else(|| self.models.last().unwrap())
    }
}

impl Backend for XlaPfpBackend {
    fn infer(&mut self, x: &Tensor) -> Result<(Tensor, Tensor)> {
        let batch = x.dim(0);
        let model = self.pick(batch).clone();
        let bucket = model.batch();
        if batch > bucket {
            // split oversized batches across bucket-sized calls
            let feat = x.len() / batch;
            let mut mu_all = Vec::with_capacity(batch * 10);
            let mut var_all = Vec::with_capacity(batch * 10);
            let mut done = 0;
            while done < batch {
                let take = bucket.min(batch - done);
                let mut chunk = x.data()[done * feat..(done + take) * feat].to_vec();
                chunk.resize(bucket * feat, 0.0);
                let outs = model.execute(&Tensor::new(vec![bucket, feat], chunk)?)?;
                let k = outs[0].cols();
                mu_all.extend_from_slice(&outs[0].data()[..take * k]);
                var_all.extend_from_slice(&outs[1].data()[..take * k]);
                done += take;
            }
            let k = mu_all.len() / batch;
            return Ok((
                Tensor::new(vec![batch, k], mu_all)?,
                Tensor::new(vec![batch, k], var_all)?,
            ));
        }
        // pad up to the bucket
        let feat = x.len() / batch;
        let mut padded = x.data().to_vec();
        padded.resize(bucket * feat, 0.0);
        let outs = model.execute(&Tensor::new(vec![bucket, feat], padded)?)?;
        let k = outs[0].cols();
        Ok((
            Tensor::new(vec![batch, k], outs[0].data()[..batch * k].to_vec())?,
            Tensor::new(vec![batch, k], outs[1].data()[..batch * k].to_vec())?,
        ))
    }

    fn name(&self) -> String {
        format!("xla-pfp/{}", self.arch)
    }
}

/// Post-process logit moments into a wire response payload.
pub fn postprocess(
    mu: &Tensor,
    var: &Tensor,
    samples: usize,
    ood_threshold: f64,
    seed: u64,
) -> Vec<protocol::Prediction> {
    let u = uncertainty::pfp_uncertainty(mu, var, samples, seed);
    let k = mu.cols();
    (0..mu.rows())
        .map(|i| {
            let row = &u.mean_p[i * k..(i + 1) * k];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            protocol::Prediction {
                pred: pred as i32,
                mu: mu.row(i).to_vec(),
                var: var.row(i).to_vec(),
                total: u.total[i],
                sme: u.sme[i],
                mi: u.mi[i],
                ood: u.mi[i] > ood_threshold,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    #[test]
    fn native_backend_shapes() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        let mut b = NativePfpBackend::new(arch, w, Schedules::default());
        let x = Tensor::new(vec![3, 784], vec![0.5; 3 * 784]).unwrap();
        let (mu, var) = b.infer(&x).unwrap();
        assert_eq!(mu.shape(), &[3, 10]);
        assert!(var.data().iter().all(|&v| v >= 0.0));
        assert!(b.name().contains("mlp"));
    }

    #[test]
    fn svi_backend_moments() {
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 2);
        let mut b = SviBackend::new(arch, w, Schedules::default(), 16, 3);
        let x = Tensor::new(vec![2, 784], vec![0.3; 2 * 784]).unwrap();
        let (mu, var) = b.infer(&x).unwrap();
        assert_eq!(mu.shape(), &[2, 10]);
        // sampled weights must produce non-degenerate logit variance
        assert!(var.data().iter().sum::<f32>() > 0.0);
    }

    #[test]
    fn postprocess_flags_fields() {
        let mu = Tensor::new(vec![2, 4], vec![3.0, 0.0, 0.0, 0.0, 0.1, 0.0, 0.0, 0.05])
            .unwrap();
        let var = Tensor::new(vec![2, 4], vec![0.01; 8]).unwrap();
        let preds = postprocess(&mu, &var, 30, 10.0, 1);
        assert_eq!(preds.len(), 2);
        assert_eq!(preds[0].pred, 0);
        assert!(!preds[0].ood); // tiny MI, huge threshold
        assert!(preds[0].total < preds[1].total); // confident row less uncertain
    }
}
