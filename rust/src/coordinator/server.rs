//! TCP server + model workers.
//!
//! Topology: a small **fixed set of IO threads** (default 2, see
//! [`ServerConfig::io_threads`]) own every socket through a
//! level-triggered [`reactor::Poller`] (epoll on Linux, kqueue on
//! macOS). There are no per-connection threads and no poll ticks:
//!
//! * the **listener** is registered with IO thread 0; accepted sockets
//!   are handed to the least-loaded IO thread via its wakeup pipe;
//! * each connection's inbound bytes run through an incremental
//!   [`codec::LineCodec`] (framing only — protocol semantics stay out of
//!   the event loop), and decoded envelopes (v1, or legacy v0 — see
//!   [`protocol`]) are `submit()`ed to the model's [`Batcher`] without
//!   blocking — after the `hello` handshake, up to `pipeline_depth`
//!   requests per connection may be in flight at once, so the dynamic
//!   batcher can coalesce a single client's burst into one
//!   probabilistic forward pass (the paper's Fig. 7 batching advantage,
//!   reachable from one socket); connections that never send `hello`
//!   keep the legacy one-at-a-time in-order semantics (the engine
//!   pauses reading at the window instead of blocking a thread);
//! * responses land in a bounded per-connection [`Outbox`] and are
//!   flushed by **writability events** — a peer that stops draining is
//!   back-pressured against its buffer cap and disconnected once it
//!   stalls past [`ServerConfig::write_stall`] (`conns_dropped_slow`),
//!   so a slow client can never wedge an IO thread in a blocking write.
//!
//! One worker thread per model lane drains its batcher, runs the lane on
//! the coalesced mini-batch, post-processes uncertainty and fans
//! responses back out to each request's [`Reply`]. Lanes come in two
//! kinds:
//!
//! * **static lanes** ([`Service::register`]) own a boxed [`Backend`] for
//!   the process lifetime — the xla / svi paths;
//! * **registry lanes** (opened by the admin `load` command or
//!   [`Service::attach_registry`]) resolve their executor per batch
//!   through the [`Registry`]: each request pins the then-active
//!   [`ModelVersion`] `Arc` at submit time, the batcher never mixes
//!   versions in one batch, and a `swap` cuts over atomically — in-flight
//!   requests finish on the version they pinned, new ones land on the new
//!   version, and the old executor (plans included) frees at refcount
//!   zero.
//!
//! Also usable in-process (no TCP) through [`Service::submit`] /
//! [`Service::infer_blocking`] — the integration tests and benches drive
//! it both ways.
//!
//! [`reactor::Poller`]: crate::coordinator::reactor::Poller
//! [`codec::LineCodec`]: crate::coordinator::codec::LineCodec

use std::collections::HashMap;
#[cfg(unix)]
use std::collections::HashSet;
#[cfg(unix)]
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig, WorkItem};
#[cfg(unix)]
use crate::coordinator::codec::{Line, LineCodec};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{
    self, Command, Envelope, Inbound, ProtoVersion, Response,
};
#[cfg(unix)]
use crate::coordinator::reactor::{Events, Poller};
use crate::coordinator::reactor::Waker;
use crate::coordinator::{postprocess, Backend};
use crate::error::{Error, Result};
use crate::model::Arch;
use crate::registry::{ModelSpec, ModelVersion, Registry};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::threadpool::{self, ThreadPool};

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub batcher: BatcherConfig,
    /// Eq. 11 logit samples for the uncertainty decomposition.
    pub logit_samples: usize,
    /// MI threshold above which a prediction is flagged OOD.
    pub ood_threshold: f64,
    /// Size of the service-owned persistent operator pool; 0 (default)
    /// shares the process-wide pool. Every model lane dispatches its
    /// parallel operators onto this one pool, so serving never pays
    /// per-request thread-spawn cost.
    pub pool_threads: usize,
    /// Accept-time admission limit: at most this many concurrent TCP
    /// connections; further sockets are refused with an error line.
    pub max_connections: usize,
    /// Maximum inference requests one connection may keep in flight after
    /// it opts in via the `hello` handshake (0 = follow
    /// `batcher.max_batch`, so a single pipelined client can fill a whole
    /// batch by itself). Requests past the limit get an immediate
    /// per-request error response; connections that never send `hello`
    /// are served one-at-a-time in order (legacy semantics).
    pub pipeline_depth: usize,
    /// Number of reactor IO threads that share all sockets (thread 0 also
    /// owns the listener). Clamped to ≥ 1. Connection counts in the tens
    /// of thousands are fine on the default of 2.
    pub io_threads: usize,
    /// Per-model admission quota: with a nonzero quota, a model lane
    /// holding this many in-flight requests sheds further submissions
    /// with an explicit load-shed error (`tenant_rejected` counter)
    /// instead of queueing without bound behind one noisy tenant.
    /// 0 disables the check.
    pub tenant_quota: usize,
    /// Cap on one connection's buffered outbound bytes. A peer that lets
    /// responses pile past this cap is counted slow and disconnected.
    pub max_outbuf_bytes: usize,
    /// How long one connection's flush may stay blocked on a full kernel
    /// buffer before the peer is declared slow and disconnected
    /// (`conns_dropped_slow` counter).
    pub write_stall: Duration,
    /// Longest accepted request line; longer lines are discarded without
    /// buffering and answered with an error (`lines_oversized` counter).
    pub max_line_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            batcher: BatcherConfig::default(),
            logit_samples: 30,
            ood_threshold: 0.25,
            pool_threads: 0,
            max_connections: 64,
            pipeline_depth: 0,
            io_threads: 2,
            tenant_quota: 0,
            max_outbuf_bytes: 256 * 1024,
            write_stall: Duration::from_secs(2),
            max_line_bytes: 1024 * 1024,
        }
    }
}

// ---------------------------------------------------------------------------
// Reply plumbing: how a lane worker reaches the requester
// ---------------------------------------------------------------------------

/// Where a lane worker delivers one request's [`Response`].
///
/// In-process callers (tests, benches, [`Service::submit`]) use the
/// channel form; the TCP front end uses the connection form, which
/// appends the serialized line to the connection's [`Outbox`] and wakes
/// the owning IO thread — no blocking writer thread anywhere.
#[derive(Clone)]
pub enum Reply {
    /// Deliver on an mpsc channel (in-process callers).
    Channel(Sender<Response>),
    /// Deliver into a reactor connection's outbound buffer.
    Conn(ConnReply),
}

impl Reply {
    pub fn send(&self, resp: Response) {
        match self {
            // a dropped receiver just means the caller stopped caring
            Reply::Channel(tx) => drop(tx.send(resp)),
            Reply::Conn(c) => c.send(resp),
        }
    }
}

/// Bounded per-connection outbound buffer.
///
/// All protocol writers (control acks, inference responses, rejection
/// lines) append here; only the owning IO thread flushes, and only when
/// the socket is writable. `cursor` marks how much of `buf` has already
/// hit the socket; consumed bytes compact away once they pass a
/// threshold, so steady-state flushing never memmoves.
struct OutInner {
    buf: Vec<u8>,
    cursor: usize,
    /// Socket failed (or connection closed): drop all future writes.
    dead: bool,
    /// The buffer cap was exceeded: the peer is not draining and must be
    /// disconnected as slow.
    overflowed: bool,
    /// When the oldest currently-blocked flush first hit `WouldBlock`;
    /// cleared only by a FULL drain, so a drip-feeding peer that never
    /// empties the buffer still trips the stall deadline.
    stall_since: Option<Instant>,
}

struct Outbox {
    cap: usize,
    inner: Mutex<OutInner>,
}

impl Outbox {
    fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1024),
            inner: Mutex::new(OutInner {
                buf: Vec::new(),
                cursor: 0,
                dead: false,
                overflowed: false,
                stall_since: None,
            }),
        }
    }

    /// Append one protocol line (newline added). Marks the connection
    /// overflowed instead of growing past the cap.
    fn push_line(&self, line: &str) {
        let mut o = self.inner.lock().unwrap();
        if o.dead {
            return;
        }
        if o.buf.len() - o.cursor + line.len() + 1 > self.cap {
            o.overflowed = true;
            return;
        }
        o.buf.extend_from_slice(line.as_bytes());
        o.buf.push(b'\n');
    }
}

/// What one IO thread shares with the rest of the process: its wakeup
/// pipe, a mailbox of cross-thread work, and its connection count (for
/// least-loaded placement of new sockets).
struct IoShared {
    waker: Arc<Waker>,
    inbox: Mutex<IoInbox>,
    conns_owned: AtomicUsize,
}

#[derive(Default)]
struct IoInbox {
    /// Sockets handed over by the accepting thread.
    new_conns: Vec<TcpStream>,
    /// Connection tokens with freshly buffered responses to flush.
    touched: Vec<u64>,
}

/// A lane worker's handle back to a reactor connection.
#[derive(Clone)]
pub struct ConnReply {
    token: u64,
    out: Arc<Outbox>,
    shared: Arc<IoShared>,
    /// The connection's pipeline-window gauge.
    conn_inflight: Arc<AtomicUsize>,
}

impl ConnReply {
    fn send(&self, resp: Response) {
        let line = resp.to_json().dump();
        {
            let mut o = self.out.inner.lock().unwrap();
            // free the pipeline slot in the same critical section that
            // buffers the response: the depth check and the flush both
            // run under this lock's happens-before, so a client that
            // replenishes on receipt can never race into a spurious
            // depth rejection
            self.conn_inflight.fetch_sub(1, Ordering::SeqCst);
            if !o.dead {
                if o.buf.len() - o.cursor + line.len() + 1 > self.out.cap {
                    o.overflowed = true;
                } else {
                    o.buf.extend_from_slice(line.as_bytes());
                    o.buf.push(b'\n');
                }
            }
        }
        self.shared.inbox.lock().unwrap().touched.push(self.token);
        self.shared.waker.wake();
    }
}

// ---------------------------------------------------------------------------
// Service: routing + batching core (transport-agnostic)
// ---------------------------------------------------------------------------

struct ModelLane {
    batcher: Arc<Batcher>,
    /// Input width for static lanes; registry lanes re-read it from the
    /// active version at submit (a swap may change the architecture).
    features: usize,
    registry_backed: bool,
    /// In-flight requests on this lane, for per-tenant admission
    /// control. Incremented at submit, decremented by whoever delivers
    /// the response.
    in_flight: Arc<AtomicUsize>,
}

/// What a lane worker runs its batches on.
enum LaneMode {
    /// A process-lifetime boxed backend (xla / svi / plain native).
    Static { backend: Box<dyn Backend>, features: usize },
    /// Per-batch executor resolution through the version `Arc` each
    /// request pinned at submit time.
    Registry { registry: Arc<Registry> },
}

/// The routing + batching service (transport-agnostic core).
pub struct Service {
    lanes: RwLock<HashMap<String, ModelLane>>,
    pub metrics: Arc<Metrics>,
    cfg: ServerConfig,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    stopping: Arc<AtomicBool>,
    /// One persistent operator pool shared by every lane and request.
    pool: Arc<ThreadPool>,
    /// The multi-model control plane, when serving registry-managed
    /// models (admin `load` / `swap` / `unload` / `models`).
    registry: Option<Arc<Registry>>,
    /// Calibration factor admin `load`/`swap` fall back to when the
    /// command omits `calib`.
    default_calib: f32,
    /// Wakeup pipes of the running reactor's IO threads, so `shutdown`
    /// (and the admin shutdown command) can interrupt their blocked
    /// `wait` calls immediately — this is what retired the old 200ms
    /// read-timeout tick.
    wakers: Mutex<Vec<Arc<Waker>>>,
}

impl Service {
    pub fn new(cfg: ServerConfig) -> Self {
        let pool = if cfg.pool_threads == 0 {
            threadpool::global().clone()
        } else {
            Arc::new(ThreadPool::new(cfg.pool_threads))
        };
        Self {
            lanes: RwLock::new(HashMap::new()),
            metrics: Arc::new(Metrics::new()),
            cfg,
            workers: Mutex::new(Vec::new()),
            stopping: Arc::new(AtomicBool::new(false)),
            pool,
            registry: None,
            default_calib: 1.0,
            wakers: Mutex::new(Vec::new()),
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Effective per-connection pipeline depth (`pipeline_depth`, or
    /// `batcher.max_batch` when left at 0 so one client can fill a batch).
    pub fn pipeline_depth(&self) -> usize {
        if self.cfg.pipeline_depth == 0 {
            self.cfg.batcher.max_batch.max(1)
        } else {
            self.cfg.pipeline_depth
        }
    }

    /// Accept-time connection admission limit.
    pub fn max_connections(&self) -> usize {
        self.cfg.max_connections.max(1)
    }

    /// The service-wide persistent operator pool. Backends registered on
    /// this service should be built with
    /// `Schedules::...with_pool(service.pool().clone())` so all lanes
    /// reuse the same long-lived workers.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Register a static model lane: spawns the worker thread that owns
    /// `backend` for the process lifetime.
    pub fn register(&mut self, name: &str, features: usize, mut backend: Box<dyn Backend>) {
        // let the backend publish its own counters (cold plan compiles)
        backend.attach_metrics(self.metrics.clone());
        self.spawn_lane(name, features, false, LaneMode::Static { backend, features });
    }

    /// Adopt a model registry: admin commands (`load`/`swap`/`unload`/
    /// `models`) become live, and a registry lane is opened for every
    /// model already published in it. `default_calib` is the calibration
    /// factor admin loads fall back to.
    pub fn attach_registry(&mut self, registry: Arc<Registry>, default_calib: f32) {
        for name in registry.names() {
            if let Some(mv) = registry.get(&name) {
                self.ensure_registry_lane(&name, mv.features());
            }
        }
        self.default_calib = default_calib;
        self.registry = Some(registry);
    }

    /// The attached registry, if any.
    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    fn require_registry(&self) -> Result<&Arc<Registry>> {
        self.registry.as_ref().ok_or_else(|| {
            Error::Coordinator(
                "no model registry attached (serve with --backend native)".into(),
            )
        })
    }

    fn spawn_lane(&self, name: &str, features: usize, registry_backed: bool, mode: LaneMode) {
        let batcher = Arc::new(Batcher::new(self.cfg.batcher));
        let lane_batcher = batcher.clone();
        let metrics = self.metrics.clone();
        let samples = self.cfg.logit_samples;
        let threshold = self.cfg.ood_threshold;
        let handle = std::thread::Builder::new()
            .name(format!("worker-{name}"))
            .spawn(move || lane_worker(lane_batcher, metrics, samples, threshold, mode))
            .expect("spawn worker");
        self.workers.lock().unwrap().push(handle);
        self.lanes.write().unwrap().insert(
            name.to_string(),
            ModelLane {
                batcher,
                features,
                registry_backed,
                in_flight: Arc::new(AtomicUsize::new(0)),
            },
        );
    }

    fn ensure_registry_lane(&self, name: &str, features: usize) {
        if self.lanes.read().unwrap().contains_key(name) {
            return;
        }
        let registry = self
            .registry
            .as_ref()
            .expect("registry lanes require an attached registry")
            .clone();
        self.spawn_lane(name, features, true, LaneMode::Registry { registry });
    }

    fn admin_spec(
        &self,
        model: &str,
        path: &str,
        arch: Option<&str>,
        calib: Option<f64>,
    ) -> Result<ModelSpec> {
        Ok(ModelSpec {
            name: model.to_string(),
            path: PathBuf::from(path),
            arch: Arch::by_name(arch.unwrap_or(model))?,
            calib: calib.map(|c| c as f32).unwrap_or(self.default_calib),
        })
    }

    fn reject_static_lane(&self, model: &str) -> Result<()> {
        let lanes = self.lanes.read().unwrap();
        match lanes.get(model) {
            Some(l) if !l.registry_backed => Err(Error::Coordinator(format!(
                "model '{model}' is a static lane (not registry-managed)"
            ))),
            _ => Ok(()),
        }
    }

    /// Admin `load`: publish a weight archive as a new model (version 1)
    /// and open its serving lane.
    pub fn admin_load(
        &self,
        model: &str,
        path: &str,
        arch: Option<&str>,
        calib: Option<f64>,
    ) -> Result<Json> {
        let registry = self.require_registry()?.clone();
        self.reject_static_lane(model)?;
        let spec = self.admin_spec(model, path, arch, calib)?;
        let mv = registry.load(&spec)?;
        self.ensure_registry_lane(model, mv.features());
        Ok(Json::obj(vec![
            ("loaded", Json::Bool(true)),
            ("model", Json::Str(model.to_string())),
            ("version", Json::Num(mv.version as f64)),
            ("checksum", Json::Str(format!("{:016x}", mv.checksum))),
            ("mapped", Json::Bool(mv.mapped)),
        ]))
    }

    /// Admin `swap`: atomically publish the next version of `model`.
    /// In-flight requests finish on the version they pinned at submit.
    pub fn admin_swap(
        &self,
        model: &str,
        path: &str,
        arch: Option<&str>,
        calib: Option<f64>,
    ) -> Result<Json> {
        let registry = self.require_registry()?.clone();
        self.reject_static_lane(model)?;
        let spec = self.admin_spec(model, path, arch, calib)?;
        let mv = registry.swap(&spec)?;
        self.ensure_registry_lane(model, mv.features());
        Ok(Json::obj(vec![
            ("swapped", Json::Bool(true)),
            ("model", Json::Str(model.to_string())),
            ("version", Json::Num(mv.version as f64)),
            ("checksum", Json::Str(format!("{:016x}", mv.checksum))),
            ("mapped", Json::Bool(mv.mapped)),
        ]))
    }

    /// Admin `unload`: retire a model. Queued and in-flight requests
    /// still drain on their pinned versions; the lane then closes.
    pub fn admin_unload(&self, model: &str) -> Result<Json> {
        let registry = self.require_registry()?.clone();
        self.reject_static_lane(model)?;
        registry.unload(model)?;
        if let Some(lane) = self.lanes.write().unwrap().remove(model) {
            lane.batcher.close();
        }
        Ok(Json::obj(vec![
            ("unloaded", Json::Bool(true)),
            ("model", Json::Str(model.to_string())),
        ]))
    }

    /// Admin `models`: the registry listing (per-model version, checksum,
    /// request/plan counters, budget headline).
    pub fn admin_models(&self) -> Result<Json> {
        Ok(self.require_registry()?.models_json())
    }

    /// The metrics snapshot, extended with the registry listing when a
    /// registry is attached (per-model request / plan-cache counters).
    pub fn metrics_snapshot(&self) -> Json {
        let base = self.metrics.snapshot();
        match (&self.registry, base) {
            (Some(reg), Json::Obj(mut m)) => {
                m.insert("registry".to_string(), reg.models_json());
                Json::Obj(m)
            }
            (_, base) => base,
        }
    }

    /// Route one request into its lane (non-blocking), delivering the
    /// response to `reply`. This is the pipelining primitive: many
    /// in-flight requests can share one reply sink, and responses arrive
    /// on it in completion order. On registry lanes the then-active model
    /// version is pinned here — the epoch handoff that makes `swap`
    /// atomic from the request's point of view. Admission control also
    /// lives here: a lane at its tenant quota, or with a full queue,
    /// sheds the request with an explicit load-shed error.
    pub fn submit_with_reply(
        &self,
        req: protocol::Request,
        reply: Reply,
        proto: ProtoVersion,
    ) -> Result<()> {
        let lanes = self.lanes.read().unwrap();
        let lane = lanes
            .get(&req.model)
            .ok_or_else(|| Error::Coordinator(format!("unknown model '{}'", req.model)))?;
        if self.cfg.tenant_quota > 0
            && lane.in_flight.load(Ordering::SeqCst) >= self.cfg.tenant_quota
        {
            Metrics::inc(&self.metrics.tenant_rejected);
            return Err(Error::Coordinator(format!(
                "admission: model '{}' at tenant quota {} (load shed)",
                req.model, self.cfg.tenant_quota
            )));
        }
        let model = if lane.registry_backed {
            Some(
                self.registry
                    .as_ref()
                    .and_then(|r| r.get(&req.model))
                    .ok_or_else(|| {
                        Error::Coordinator(format!("unknown model '{}'", req.model))
                    })?,
            )
        } else {
            None
        };
        let features = model.as_ref().map_or(lane.features, |m| m.features());
        if req.input.len() != features {
            return Err(Error::Coordinator(format!(
                "model '{}' expects {} features, got {}",
                req.model,
                features,
                req.input.len()
            )));
        }
        Metrics::inc(&self.metrics.requests);
        // gauges up BEFORE the push publishes the item: the lane worker
        // may pop and decrement immediately, and inc-after-push would let
        // the unsigned gauges wrap below zero
        Metrics::inc(&self.metrics.in_flight);
        lane.in_flight.fetch_add(1, Ordering::SeqCst);
        let item = WorkItem {
            id: req.id,
            input: req.input,
            enqueued: Instant::now(),
            reply,
            proto,
            model,
            lane_inflight: Some(lane.in_flight.clone()),
        };
        if lane.batcher.push(item).is_err() {
            Metrics::dec(&self.metrics.in_flight);
            lane.in_flight.fetch_sub(1, Ordering::SeqCst);
            Metrics::inc(&self.metrics.rejected);
            return Err(Error::Coordinator("queue full (load shed)".into()));
        }
        Ok(())
    }

    /// [`submit_with_reply`](Self::submit_with_reply) onto an mpsc
    /// channel, tagged with the caller's protocol generation.
    pub fn submit_with_proto(
        &self,
        req: protocol::Request,
        reply: Sender<Response>,
        proto: ProtoVersion,
    ) -> Result<()> {
        self.submit_with_reply(req, Reply::Channel(reply), proto)
    }

    /// [`submit_with_proto`](Self::submit_with_proto) under the legacy
    /// (v0) response shape.
    pub fn submit_with(&self, req: protocol::Request, reply: Sender<Response>) -> Result<()> {
        self.submit_with_proto(req, reply, ProtoVersion::V0)
    }

    /// Route one request into its lane (non-blocking) on a fresh channel.
    pub fn submit(&self, req: protocol::Request) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        self.submit_with(req, tx)?;
        Ok(rx)
    }

    /// Submit and block for the response (in-process convenience).
    pub fn infer_blocking(&self, req: protocol::Request) -> Response {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or(Response {
                id,
                result: Err("worker dropped".into()),
                queue_us: 0,
                infer_us: 0,
                proto: ProtoVersion::V0,
                model_version: 0,
            }),
            Err(e) => Response {
                id,
                result: Err(e.to_string()),
                queue_us: 0,
                infer_us: 0,
                proto: ProtoVersion::V0,
                model_version: 0,
            },
        }
    }

    /// Register one IO thread's wakeup pipe for stop-flag delivery.
    fn register_waker(&self, w: Arc<Waker>) {
        self.wakers.lock().unwrap().push(w);
    }

    /// Interrupt every IO thread's blocked `wait` so it re-checks the
    /// stop flag (and its mailbox) immediately.
    fn wake_all(&self) {
        for w in self.wakers.lock().unwrap().iter() {
            w.wake();
        }
    }

    /// Close all lanes and join workers.
    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.wake_all();
        for lane in self.lanes.read().unwrap().values() {
            lane.batcher.close();
        }
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    pub fn is_stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One model lane's worker loop: drain version-contiguous batches, run
/// them, fan the responses back out.
fn lane_worker(
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    samples: usize,
    threshold: f64,
    mut mode: LaneMode,
) {
    let mut seed = 0x5EED_u64;
    while let Some(batch) = batcher.next_batch() {
        let b = batch.len();
        Metrics::inc(&metrics.batches);
        Metrics::add(&metrics.batched_items, b as u64);
        let infer_t = Instant::now();
        // the batcher never mixes versions: the first item's pinned Arc
        // (if any) is the whole batch's executor
        let mv: Option<Arc<ModelVersion>> = batch[0].model.clone();
        let model_version = mv.as_ref().map_or(0, |m| m.version);
        let features = match (&mode, &mv) {
            (LaneMode::Static { features, .. }, _) => *features,
            (LaneMode::Registry { .. }, Some(m)) => m.features(),
            (LaneMode::Registry { .. }, None) => {
                fan_errors(batch, &metrics, "request lost its model version", 0);
                continue;
            }
        };
        let mut data = Vec::with_capacity(b * features);
        for it in &batch {
            data.extend_from_slice(&it.input);
        }
        let x = match Tensor::new(vec![b, features], data) {
            Ok(x) => x,
            Err(e) => {
                fan_errors(batch, &metrics, &format!("bad input: {e}"), model_version);
                continue;
            }
        };
        seed = seed.wrapping_add(1);
        let outcome = match &mut mode {
            LaneMode::Static { backend, .. } => backend.infer(&x),
            LaneMode::Registry { registry } => {
                let m = mv.as_ref().expect("registry batch carries its version");
                m.infer(&x).map(|(mu, var, delta)| {
                    // per-batch plan-cache movement -> global counters,
                    // then hold the whole fleet to the memory budget
                    Metrics::add(&metrics.plan_compiles, delta.compiles);
                    Metrics::add(&metrics.plan_cache_evictions, delta.evictions);
                    Metrics::add(
                        &metrics.plan_cache_evictions,
                        registry.enforce_budget(),
                    );
                    (mu, var)
                })
            }
        };
        match outcome {
            Ok((mu, var)) => {
                let infer_us = infer_t.elapsed().as_micros() as u64;
                let preds = postprocess(&mu, &var, samples, threshold, seed);
                for (it, p) in batch.into_iter().zip(preds) {
                    if p.ood {
                        Metrics::inc(&metrics.ood_flagged);
                    }
                    // one timestamp per item: end-to-end latency, of which
                    // everything not spent in the batch's inference call
                    // was queueing/batching wait
                    let elapsed = it.enqueued.elapsed().as_micros() as u64;
                    let queue_us = elapsed.saturating_sub(infer_us);
                    metrics.record_latency_us(elapsed as f64);
                    Metrics::inc(&metrics.responses);
                    Metrics::dec(&metrics.in_flight);
                    if let Some(li) = &it.lane_inflight {
                        li.fetch_sub(1, Ordering::SeqCst);
                    }
                    it.reply.send(Response {
                        id: it.id,
                        result: Ok(p),
                        queue_us,
                        infer_us,
                        proto: it.proto,
                        model_version,
                    });
                }
            }
            Err(e) => fan_errors(
                batch,
                &metrics,
                &format!("inference failed: {e}"),
                model_version,
            ),
        }
    }
}

fn fan_errors(batch: Vec<WorkItem>, metrics: &Metrics, msg: &str, model_version: u64) {
    for it in batch {
        Metrics::dec(&metrics.in_flight);
        if let Some(li) = &it.lane_inflight {
            li.fetch_sub(1, Ordering::SeqCst);
        }
        it.reply.send(Response {
            id: it.id,
            result: Err(msg.to_string()),
            queue_us: 0,
            infer_us: 0,
            proto: it.proto,
            model_version,
        });
    }
}

// ---------------------------------------------------------------------------
// TCP front end: the connection reactor
// ---------------------------------------------------------------------------

/// TCP front end over a [`Service`].
pub struct Server {
    service: Arc<Service>,
    listener: TcpListener,
    pub addr: std::net::SocketAddr,
}

impl Server {
    /// Bind (use port 0 in `cfg.addr` for an ephemeral port).
    pub fn bind(service: Arc<Service>) -> Result<Self> {
        let listener = TcpListener::bind(&service.cfg.addr)
            .map_err(|e| Error::Coordinator(format!("bind {}: {e}", service.cfg.addr)))?;
        let addr = listener.local_addr()?;
        Ok(Self { service, listener, addr })
    }

    /// Serve until a shutdown command arrives: `io_threads` reactor
    /// threads (the caller's thread is thread 0 and also owns the
    /// listener) share every socket; past `max_connections` concurrent
    /// clients, new sockets get an error line and are closed at accept
    /// time. Returns once the stop flag is set and every connection has
    /// drained its in-flight responses — shutdown is wakeup-pipe-driven,
    /// with no polling tick anywhere.
    #[cfg(unix)]
    pub fn run(&self) -> Result<()> {
        let reactor_err = |e: std::io::Error| Error::Coordinator(format!("reactor: {e}"));
        self.listener.set_nonblocking(true)?;
        let n_io = self.service.cfg.io_threads.max(1);
        // re-runs on the same service re-register from scratch
        self.service.wakers.lock().unwrap().clear();
        let mut slots = Vec::with_capacity(n_io);
        for _ in 0..n_io {
            let poller = Poller::new().map_err(reactor_err)?;
            let waker = Arc::new(Waker::new().map_err(reactor_err)?);
            poller
                .add(waker.read_fd(), TOKEN_WAKER, true, false)
                .map_err(reactor_err)?;
            let shared = Arc::new(IoShared {
                waker: waker.clone(),
                inbox: Mutex::new(IoInbox::default()),
                conns_owned: AtomicUsize::new(0),
            });
            self.service.register_waker(waker);
            slots.push((poller, shared));
        }
        let peers: Vec<Arc<IoShared>> = slots.iter().map(|(_, s)| s.clone()).collect();
        let active = Arc::new(AtomicUsize::new(0));
        let io_thread = |poller: Poller, shared: Arc<IoShared>| IoThread {
            svc: self.service.clone(),
            shared,
            peers: peers.clone(),
            poller,
            conns: HashMap::new(),
            wet: HashSet::new(),
            next_token: FIRST_CONN_TOKEN,
            active: active.clone(),
            read_buf: vec![0u8; READ_CHUNK],
        };
        let mut slots = slots.into_iter();
        let (p0, s0) = slots.next().expect("io_threads >= 1");
        let mut handles = Vec::new();
        for (i, (poller, shared)) in slots.enumerate() {
            let t = io_thread(poller, shared);
            let handle = std::thread::Builder::new()
                .name(format!("pfp-io-{}", i + 1))
                .spawn(move || t.run(None))
                .expect("spawn io thread");
            handles.push(handle);
        }
        // thread 0 (this thread) owns the listener
        io_thread(p0, s0).run(Some(&self.listener));
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    /// The reactor needs epoll or kqueue; other targets refuse to serve
    /// TCP (the in-process [`Service`] API still works everywhere).
    #[cfg(not(unix))]
    pub fn run(&self) -> Result<()> {
        Err(Error::Coordinator(
            "connection reactor requires epoll (Linux) or kqueue (macOS)".into(),
        ))
    }
}

/// Reactor token of each IO thread's own wakeup pipe.
#[cfg(unix)]
const TOKEN_WAKER: u64 = 0;
/// Reactor token of the listener (IO thread 0 only).
#[cfg(unix)]
const TOKEN_LISTENER: u64 = 1;
/// First token handed to an accepted connection.
#[cfg(unix)]
const FIRST_CONN_TOKEN: u64 = 2;
/// Kernel events drained per `wait` call.
#[cfg(unix)]
const EVENTS_PER_WAIT: usize = 256;
/// Bytes read per readiness event (one chunk per event keeps a
/// fire-hosing client from starving its neighbours on the IO thread).
#[cfg(unix)]
const READ_CHUNK: usize = 64 * 1024;
/// Flushed bytes compact out of an outbox once they pass this threshold.
#[cfg(unix)]
const OUTBUF_COMPACT_AT: usize = 4096;

/// Per-connection pipelining state.
#[cfg(unix)]
struct ConnState {
    /// Max requests in flight on this connection.
    depth: usize,
    /// True once the client opted in via `{"cmd":"hello","pipeline":true}`.
    /// Pipelined connections get an explicit error response on a depth
    /// overrun; non-pipelined ones keep the legacy one-at-a-time in-order
    /// semantics — the engine simply stops popping (and reading) lines
    /// while the single-slot window is full, so clients written against
    /// the old synchronous server behave identically.
    pipelined: bool,
    /// Whether the one-time v0 deprecation warning already went out on
    /// this connection.
    warned_v0: bool,
}

/// One reactor-owned connection.
#[cfg(unix)]
struct Conn {
    stream: TcpStream,
    fd: i32,
    codec: LineCodec,
    state: ConnState,
    out: Arc<Outbox>,
    /// Requests currently in flight on this connection (pipeline window).
    in_flight: Arc<AtomicUsize>,
    /// The `Reply` handed to every submit from this connection.
    reply: Reply,
    /// Peer half-closed its write side (EOF seen).
    read_closed: bool,
    /// Stop reading; close once buffered + in-flight work drains.
    closing: bool,
    /// Legacy (non-pipelined) window is full: reading is suspended until
    /// the in-flight response is delivered.
    paused: bool,
    /// Interest set currently registered with the poller.
    reg_read: bool,
    reg_write: bool,
}

/// One reactor IO thread: owns a poller, a wakeup pipe, and a share of
/// the connections.
#[cfg(unix)]
struct IoThread {
    svc: Arc<Service>,
    shared: Arc<IoShared>,
    peers: Vec<Arc<IoShared>>,
    poller: Poller,
    conns: HashMap<u64, Conn>,
    /// Tokens with undelivered outbound bytes (stall-deadline watchlist).
    wet: HashSet<u64>,
    next_token: u64,
    /// Process-wide admitted-connection count (accept-time limit).
    active: Arc<AtomicUsize>,
    /// Scratch buffer for socket reads, reused across all connections.
    read_buf: Vec<u8>,
}

#[cfg(unix)]
impl IoThread {
    fn run(mut self, listener: Option<&TcpListener>) {
        let mut events = Events::with_capacity(EVENTS_PER_WAIT);
        let mut listener_registered = false;
        if let Some(l) = listener {
            if let Err(e) = self.poller.add(l.as_raw_fd(), TOKEN_LISTENER, true, false) {
                eprintln!("reactor: register listener: {e}");
                return;
            }
            listener_registered = true;
        }
        loop {
            if self.svc.is_stopping() {
                if listener_registered {
                    if let Some(l) = listener {
                        let _ = self.poller.delete(l.as_raw_fd());
                    }
                    listener_registered = false;
                }
                self.begin_close_all();
                if self.conns.is_empty() {
                    break;
                }
            }
            let timeout = self.sweep_stalls();
            if let Err(e) = self.poller.wait(&mut events, timeout) {
                eprintln!("reactor: wait: {e}");
                break;
            }
            let mut woken = false;
            let mut accept_ready = false;
            for ev in events.iter() {
                match ev.token {
                    TOKEN_WAKER => woken = true,
                    TOKEN_LISTENER => accept_ready = true,
                    t => self.conn_event(t, ev.readable, ev.writable),
                }
            }
            if woken {
                self.shared.waker.drain();
            }
            if accept_ready {
                if let Some(l) = listener {
                    self.accept_all(l);
                }
            }
            self.drain_inbox();
        }
    }

    /// Stop-flag handling: every connection flips to `closing` (reads
    /// stop, buffered + in-flight responses still drain) and idle ones
    /// close immediately, so shutdown completes as fast as the lanes do.
    fn begin_close_all(&mut self) {
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for t in tokens {
            if let Some(c) = self.conns.get_mut(&t) {
                c.closing = true;
            }
            self.flush_token(t);
            self.finish_conn(t);
        }
    }

    /// One readiness notification for a connection token.
    fn conn_event(&mut self, token: u64, readable: bool, writable: bool) {
        if !self.conns.contains_key(&token) {
            return; // closed earlier in this same event batch
        }
        if writable {
            self.flush_token(token);
        }
        if readable {
            self.read_token(token);
        }
        self.process_lines(token);
        self.flush_token(token);
        self.finish_conn(token);
    }

    /// Pull one chunk of inbound bytes into the connection's codec.
    fn read_token(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.paused || conn.closing || conn.read_closed {
            return; // level-triggered: unread data keeps the event hot
        }
        loop {
            match conn.stream.read(&mut self.read_buf) {
                Ok(0) => {
                    conn.read_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.codec.push(&self.read_buf[..n]);
                    break; // one chunk per event: fairness across conns
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => {
                    conn.out.inner.lock().unwrap().dead = true;
                    break;
                }
            }
        }
    }

    /// Decode and dispatch every complete line buffered on `token`.
    fn process_lines(&mut self, token: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.closing {
                return;
            }
            if !conn.state.pipelined
                && conn.in_flight.load(Ordering::SeqCst) >= conn.state.depth
            {
                // legacy window full: stop popping (and reading) until
                // the response is delivered — strict one-at-a-time order
                conn.paused = true;
                return;
            }
            let line = match conn.codec.next_line() {
                None => return,
                Some(Line::Oversized { len }) => {
                    Metrics::inc(&self.svc.metrics.lines_oversized);
                    conn.out.push_line(&format!(
                        "{{\"error\":\"line exceeds {} byte limit ({len} bytes)\"}}",
                        self.svc.cfg.max_line_bytes
                    ));
                    continue;
                }
                Some(Line::Full(bytes)) => String::from_utf8_lossy(bytes).into_owned(),
            };
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let keep = handle_line(
                &self.svc,
                &mut conn.state,
                &conn.out,
                &conn.reply,
                &conn.in_flight,
                trimmed,
            );
            if !keep {
                conn.closing = true;
                return;
            }
        }
    }

    /// Write as much buffered output as the kernel will take.
    fn flush_token(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let mut o = conn.out.inner.lock().unwrap();
        if o.dead {
            return;
        }
        while o.cursor < o.buf.len() {
            match (&conn.stream).write(&o.buf[o.cursor..]) {
                Ok(0) => {
                    o.dead = true;
                    break;
                }
                Ok(n) => o.cursor += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    // kernel buffer full: the stall clock starts at the
                    // first blocked write and only a FULL drain clears
                    // it, so a drip-draining peer still trips the
                    // deadline
                    if o.stall_since.is_none() {
                        o.stall_since = Some(Instant::now());
                    }
                    break;
                }
                Err(_) => {
                    o.dead = true;
                    break;
                }
            }
        }
        if o.cursor == o.buf.len() {
            o.buf.clear();
            o.cursor = 0;
            o.stall_since = None;
        } else if o.cursor >= OUTBUF_COMPACT_AT {
            o.buf.drain(..o.cursor);
            o.cursor = 0;
        }
    }

    /// Decide a connection's fate after an event round: close it, or
    /// reconcile its poller interest set with what it now needs.
    fn finish_conn(&mut self, token: u64) {
        enum Fate {
            Close { slow: bool },
            Keep { want_read: bool, want_write: bool },
        }
        let fate = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            let (dead, overflowed, pending) = {
                let o = conn.out.inner.lock().unwrap();
                (o.dead, o.overflowed, o.buf.len() - o.cursor)
            };
            // the in-flight read happens under the same outbox lock
            // discipline as ConnReply::send, so (pending == 0 && idle)
            // is never observed between a slot-free and its response
            let idle = conn.in_flight.load(Ordering::SeqCst) == 0;
            if dead {
                Fate::Close { slow: false }
            } else if overflowed {
                Fate::Close { slow: true }
            } else if (conn.closing || conn.read_closed) && pending == 0 && idle {
                Fate::Close { slow: false }
            } else {
                Fate::Keep {
                    want_read: !(conn.paused || conn.closing || conn.read_closed),
                    want_write: pending > 0,
                }
            }
        };
        match fate {
            Fate::Close { slow } => self.close_conn(token, slow),
            Fate::Keep { want_read, want_write } => {
                let mut lost = false;
                if let Some(conn) = self.conns.get_mut(&token) {
                    if want_read != conn.reg_read || want_write != conn.reg_write {
                        if self.poller.modify(conn.fd, token, want_read, want_write).is_ok() {
                            conn.reg_read = want_read;
                            conn.reg_write = want_write;
                        } else {
                            lost = true;
                        }
                    }
                }
                if lost {
                    self.close_conn(token, false);
                } else if want_write {
                    self.wet.insert(token);
                } else {
                    self.wet.remove(&token);
                }
            }
        }
    }

    fn close_conn(&mut self, token: u64, slow: bool) {
        let Some(conn) = self.conns.remove(&token) else { return };
        // deregister before the socket closes on drop
        let _ = self.poller.delete(conn.fd);
        {
            // late lane replies will see `dead` and drop their bytes
            let mut o = conn.out.inner.lock().unwrap();
            o.dead = true;
            o.buf.clear();
            o.cursor = 0;
        }
        self.wet.remove(&token);
        if slow {
            Metrics::inc(&self.svc.metrics.conns_dropped_slow);
        }
        self.active.fetch_sub(1, Ordering::SeqCst);
        self.shared.conns_owned.fetch_sub(1, Ordering::SeqCst);
    }

    /// Accept every pending socket (the listener is level-triggered and
    /// nonblocking).
    fn accept_all(&mut self, listener: &TcpListener) {
        loop {
            match listener.accept() {
                Ok((sock, _)) => self.admit(sock),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    eprintln!("accept error: {e}");
                    break;
                }
            }
        }
    }

    fn admit(&mut self, mut sock: TcpStream) {
        if self.active.load(Ordering::SeqCst) >= self.svc.max_connections() {
            Metrics::inc(&self.svc.metrics.conns_rejected);
            // best-effort: a fresh socket's send buffer is empty, so
            // this short line goes out in one write
            let _ = sock.write_all(b"{\"error\":\"server at max connections\"}\n");
            return; // socket dropped: rejected at accept
        }
        // line-sized request/response pairs: Nagle + delayed-ACK would
        // add ~40ms per round trip, swamping sub-ms inference. A socket
        // we cannot configure must not be served in a broken state —
        // count it, log it, close it.
        if let Err(e) = sock.set_nonblocking(true).and_then(|_| sock.set_nodelay(true)) {
            Metrics::inc(&self.svc.metrics.conns_setup_failed);
            eprintln!("connection setup error: {e}");
            return;
        }
        self.active.fetch_add(1, Ordering::SeqCst);
        Metrics::inc(&self.svc.metrics.connections);
        // least-loaded IO thread takes ownership
        let mut best = 0;
        let mut best_owned = usize::MAX;
        for (i, peer) in self.peers.iter().enumerate() {
            let owned = peer.conns_owned.load(Ordering::SeqCst);
            if owned < best_owned {
                best = i;
                best_owned = owned;
            }
        }
        let peer = self.peers[best].clone();
        peer.conns_owned.fetch_add(1, Ordering::SeqCst);
        if Arc::ptr_eq(&peer, &self.shared) {
            self.register_conn(sock);
        } else {
            peer.inbox.lock().unwrap().new_conns.push(sock);
            peer.waker.wake();
        }
    }

    /// Take ownership of an admitted socket on this IO thread.
    fn register_conn(&mut self, sock: TcpStream) {
        let fd = sock.as_raw_fd();
        let token = self.next_token;
        self.next_token += 1;
        if let Err(e) = self.poller.add(fd, token, true, false) {
            Metrics::inc(&self.svc.metrics.conns_setup_failed);
            eprintln!("connection setup error: {e}");
            self.active.fetch_sub(1, Ordering::SeqCst);
            self.shared.conns_owned.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let out = Arc::new(Outbox::new(self.svc.cfg.max_outbuf_bytes));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let reply = Reply::Conn(ConnReply {
            token,
            out: out.clone(),
            shared: self.shared.clone(),
            conn_inflight: in_flight.clone(),
        });
        self.conns.insert(
            token,
            Conn {
                stream: sock,
                fd,
                codec: LineCodec::new(self.svc.cfg.max_line_bytes),
                // until the hello handshake opts in, a connection is
                // limited to one request in flight and served strictly
                // in order — the old synchronous server's observable
                // behaviour, even for clients that pipeline their writes
                state: ConnState { depth: 1, pipelined: false, warned_v0: false },
                out,
                in_flight,
                reply,
                read_closed: false,
                closing: false,
                paused: false,
                reg_read: true,
                reg_write: false,
            },
        );
    }

    /// Adopt handed-over sockets and revisit connections whose lane
    /// responses just landed.
    fn drain_inbox(&mut self) {
        let (new_conns, touched) = {
            let mut inbox = self.shared.inbox.lock().unwrap();
            (
                std::mem::take(&mut inbox.new_conns),
                std::mem::take(&mut inbox.touched),
            )
        };
        for sock in new_conns {
            self.register_conn(sock);
        }
        for token in touched {
            let Some(conn) = self.conns.get_mut(&token) else { continue };
            if conn.paused && conn.in_flight.load(Ordering::SeqCst) < conn.state.depth {
                // a response freed the legacy window: resume reading
                conn.paused = false;
            }
            self.process_lines(token);
            self.flush_token(token);
            self.finish_conn(token);
        }
    }

    /// Disconnect peers stalled past the write deadline; returns how
    /// long `wait` may block before the next deadline expires (`None`
    /// blocks until an event or wakeup — there is no idle tick).
    fn sweep_stalls(&mut self) -> Option<Duration> {
        if self.wet.is_empty() {
            return None;
        }
        let stall = self.svc.cfg.write_stall;
        let now = Instant::now();
        let mut expired: Vec<u64> = Vec::new();
        let mut next: Option<Duration> = None;
        for &t in self.wet.iter() {
            let Some(conn) = self.conns.get(&t) else { continue };
            let since = conn.out.inner.lock().unwrap().stall_since;
            if let Some(s) = since {
                let deadline = s + stall;
                if deadline <= now {
                    expired.push(t);
                } else {
                    let left = deadline - now;
                    next = Some(next.map_or(left, |n: Duration| n.min(left)));
                }
            }
        }
        for t in expired {
            // the peer stopped draining: cut it loose so its buffered
            // responses cannot pin memory or delay anyone else
            self.close_conn(t, true);
        }
        next
    }
}

/// Take the one-time v0 deprecation warning if this message earns it.
#[cfg(unix)]
fn take_v0_warning(proto: ProtoVersion, state: &mut ConnState) -> Option<&'static str> {
    if proto == ProtoVersion::V0 && !state.warned_v0 {
        state.warned_v0 = true;
        Some(protocol::V0_DEPRECATION)
    } else {
        None
    }
}

/// Buffer a control acknowledgement sealed under the request's protocol
/// generation (first v0 ack carries the deprecation warning).
#[cfg(unix)]
fn conn_ack(out: &Outbox, body: Json, proto: ProtoVersion, state: &mut ConnState) {
    let warning = take_v0_warning(proto, state);
    out.push_line(&Envelope::seal(body, proto, warning).dump());
}

/// Handle one decoded line; returns false when the connection is done.
/// All replies go through the connection's outbox — nothing here
/// touches the socket, so protocol work never blocks the event loop.
#[cfg(unix)]
fn handle_line(
    svc: &Service,
    state: &mut ConnState,
    out: &Outbox,
    reply: &Reply,
    in_flight: &AtomicUsize,
    line: &str,
) -> bool {
    let env = match Envelope::parse(line) {
        Ok(env) => env,
        Err(e) => {
            // a malformed or unknown-version line has no trustworthy
            // generation to answer under: reply bare, like v0 always did
            let msg = Json::obj(vec![(
                "error",
                Json::Str(format!("bad request: {e}")),
            )]);
            out.push_line(&msg.dump());
            return true;
        }
    };
    let proto = env.proto;
    match env.body {
        Inbound::Control(Command::Ping) => {
            conn_ack(out, Json::obj(vec![("pong", Json::Bool(true))]), proto, state);
        }
        Inbound::Control(Command::Hello { pipeline }) => {
            state.pipelined = pipeline;
            state.depth = if pipeline { svc.pipeline_depth() } else { 1 };
            let warning = take_v0_warning(proto, state);
            let ack = protocol::hello_json_proto(
                pipeline,
                state.depth,
                svc.cfg.batcher.max_batch,
                proto,
                warning,
            );
            out.push_line(&ack);
        }
        Inbound::Control(Command::Metrics) => {
            conn_ack(out, svc.metrics_snapshot(), proto, state);
        }
        Inbound::Control(Command::Shutdown) => {
            conn_ack(
                out,
                Json::obj(vec![("shutting_down", Json::Bool(true))]),
                proto,
                state,
            );
            svc.stopping.store(true, Ordering::SeqCst);
            // every IO thread re-checks the stop flag when its wakeup
            // pipe fires — no TCP self-poke, no tick
            svc.wake_all();
            return false;
        }
        Inbound::Control(Command::Load { model, path, arch, calib }) => {
            let body = svc
                .admin_load(&model, &path, arch.as_deref(), calib)
                .unwrap_or_else(|e| Json::obj(vec![("error", Json::Str(e.to_string()))]));
            conn_ack(out, body, proto, state);
        }
        Inbound::Control(Command::Swap { model, path, arch, calib }) => {
            let body = svc
                .admin_swap(&model, &path, arch.as_deref(), calib)
                .unwrap_or_else(|e| Json::obj(vec![("error", Json::Str(e.to_string()))]));
            conn_ack(out, body, proto, state);
        }
        Inbound::Control(Command::Unload { model }) => {
            let body = svc
                .admin_unload(&model)
                .unwrap_or_else(|e| Json::obj(vec![("error", Json::Str(e.to_string()))]));
            conn_ack(out, body, proto, state);
        }
        Inbound::Control(Command::Models) => {
            let body = svc
                .admin_models()
                .unwrap_or_else(|e| Json::obj(vec![("error", Json::Str(e.to_string()))]));
            conn_ack(out, body, proto, state);
        }
        Inbound::Infer(req) => {
            let current = in_flight.load(Ordering::SeqCst);
            if current >= state.depth {
                // pipelined overrun -> explicit per-request error the
                // client can match by id and retry after draining some
                // responses (legacy connections never reach here: the
                // engine pauses reads while their window is full)
                Metrics::inc(&svc.metrics.depth_rejected);
                out.push_line(
                    &Response::error(
                        req.id,
                        format!("pipeline depth {} exceeded", state.depth),
                        proto,
                    )
                    .to_json()
                    .dump(),
                );
                return true;
            }
            svc.metrics.record_conn_depth((current + 1) as f64);
            in_flight.fetch_add(1, Ordering::SeqCst);
            let id = req.id;
            if let Err(e) = svc.submit_with_reply(req, reply.clone(), proto) {
                in_flight.fetch_sub(1, Ordering::SeqCst);
                out.push_line(&Response::error(id, e.to_string(), proto).to_json().dump());
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativePfpBackend;
    use crate::model::{Arch, PosteriorWeights, Schedules, SchedulesBuilder};

    fn test_service() -> Service {
        let mut svc = Service::new(ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        svc.register(
            "mlp",
            784,
            Box::new(NativePfpBackend::new(arch, w, Schedules::default())),
        );
        svc
    }

    fn registry_service(tag: &str) -> (Service, std::path::PathBuf) {
        let mut svc = Service::new(ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        let registry = Arc::new(Registry::new(None, true, SchedulesBuilder::tuned(1)));
        svc.attach_registry(registry, 1.0);
        let arch = Arch::mlp();
        let path = std::env::temp_dir().join(format!(
            "pfp_server_reg_{}_{tag}.npz",
            std::process::id()
        ));
        PosteriorWeights::synthetic(&arch, 9).save_npz(&path).unwrap();
        (svc, path)
    }

    #[test]
    fn in_process_roundtrip() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 1,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        let p = resp.result.expect("inference should succeed");
        assert!((0..10).contains(&p.pred));
        assert_eq!(p.mu.len(), 10);
        assert!(p.total >= p.mi - 1e-9);
        assert_eq!(resp.model_version, 0, "static lanes carry no version");
    }

    #[test]
    fn unknown_model_rejected() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 2,
            model: "nope".into(),
            input: vec![0.0; 784],
        });
        assert!(resp.result.is_err());
    }

    #[test]
    fn wrong_feature_count_rejected() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 3,
            model: "mlp".into(),
            input: vec![0.0; 10],
        });
        assert!(resp.result.unwrap_err().contains("features"));
    }

    #[test]
    fn pipeline_depth_defaults_to_max_batch() {
        let mut cfg = ServerConfig::default();
        cfg.batcher.max_batch = 7;
        let svc = Service::new(cfg);
        assert_eq!(svc.pipeline_depth(), 7);
        let mut cfg = ServerConfig::default();
        cfg.pipeline_depth = 3;
        let svc = Service::new(cfg);
        assert_eq!(svc.pipeline_depth(), 3);
    }

    #[test]
    fn submit_with_shares_one_reply_channel() {
        let svc = test_service();
        let (tx, rx) = channel();
        for i in 0..4u64 {
            svc.submit_with(
                protocol::Request {
                    id: i,
                    model: "mlp".into(),
                    input: vec![0.25; 784],
                },
                tx.clone(),
            )
            .expect("submit");
        }
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| {
            assert!(r.result.is_ok());
            r.id
        }).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        // gauge drained back to zero once every response was delivered
        assert_eq!(
            svc.metrics.in_flight.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn concurrent_submissions_batched() {
        let svc = Arc::new(test_service());
        let mut handles = Vec::new();
        for i in 0..20 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                svc.infer_blocking(protocol::Request {
                    id: i,
                    model: "mlp".into(),
                    input: vec![0.1 * (i as f32 % 10.0); 784],
                })
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.result.is_ok());
        }
        // dynamic batching must have coalesced at least some requests
        assert!(svc.metrics.mean_batch_size() >= 1.0);
        assert_eq!(
            svc.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
            20
        );
    }

    #[test]
    fn admin_lifecycle_load_infer_swap_unload() {
        let (svc, path) = registry_service("lifecycle");
        let p = path.to_string_lossy().to_string();

        // load opens a lane; responses carry the version
        let ack = svc.admin_load("mlp", &p, None, None).unwrap();
        assert_eq!(ack.num_field("version").unwrap(), 1.0);
        let resp = svc.infer_blocking(protocol::Request {
            id: 1,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        assert!(resp.result.is_ok());
        assert_eq!(resp.model_version, 1);

        // swap bumps the served version
        let ack = svc.admin_swap("mlp", &p, None, None).unwrap();
        assert_eq!(ack.num_field("version").unwrap(), 2.0);
        let resp = svc.infer_blocking(protocol::Request {
            id: 2,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        assert_eq!(resp.model_version, 2);

        // listing + merged metrics see the registry
        let models = svc.admin_models().unwrap();
        assert!(models.get("models").is_some());
        assert!(svc.metrics_snapshot().get("registry").is_some());

        // unload closes the lane
        svc.admin_unload("mlp").unwrap();
        let resp = svc.infer_blocking(protocol::Request {
            id: 3,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        assert!(resp.result.is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn admin_requires_registry() {
        let svc = test_service();
        assert!(svc.admin_models().is_err());
        assert!(svc.admin_load("m", "w.npz", None, None).is_err());
        // and a static lane name cannot be hijacked even with a registry
        let (svc2, path) = registry_service("requires");
        drop(svc2);
        std::fs::remove_file(&path).ok();
        let err = svc.admin_unload("mlp").unwrap_err();
        assert!(err.to_string().contains("no model registry"));
    }

    #[test]
    fn outbox_buffers_lines_until_capacity() {
        let out = Outbox::new(4096);
        out.push_line("{\"a\":1}");
        out.push_line("{\"b\":2}");
        let o = out.inner.lock().unwrap();
        assert_eq!(o.buf, b"{\"a\":1}\n{\"b\":2}\n");
        assert!(!o.overflowed);
    }

    #[test]
    fn outbox_overflow_marks_peer_slow_instead_of_growing() {
        // cap clamps to 1024; a line that cannot fit flips `overflowed`
        // and is dropped rather than buffered
        let out = Outbox::new(0);
        let big = "x".repeat(2048);
        out.push_line(&big);
        let o = out.inner.lock().unwrap();
        assert!(o.overflowed, "over-cap line must mark the peer slow");
        assert!(o.buf.is_empty(), "over-cap line must not be buffered");
    }

    #[test]
    fn outbox_dead_drops_writes() {
        let out = Outbox::new(4096);
        out.inner.lock().unwrap().dead = true;
        out.push_line("{\"late\":true}");
        assert!(out.inner.lock().unwrap().buf.is_empty());
    }

    #[test]
    fn tenant_quota_sheds_excess_load() {
        let mut cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        cfg.tenant_quota = 1;
        // single-item batches so the first request parks in flight long
        // enough for the burst behind it to trip the quota check
        cfg.batcher.max_batch = 1;
        let mut svc = Service::new(cfg);
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        svc.register(
            "mlp",
            784,
            Box::new(NativePfpBackend::new(arch, w, Schedules::default())),
        );
        let (tx, rx) = channel();
        let mut shed = 0u64;
        let mut submitted = 0usize;
        for i in 0..16u64 {
            match svc.submit_with(
                protocol::Request {
                    id: i,
                    model: "mlp".into(),
                    input: vec![0.25; 784],
                },
                tx.clone(),
            ) {
                Ok(()) => submitted += 1,
                Err(e) => {
                    assert!(e.to_string().contains("load shed"), "got: {e}");
                    shed += 1;
                }
            }
        }
        drop(tx);
        let got = rx.iter().count();
        assert_eq!(got, submitted, "every admitted request must answer");
        assert_eq!(
            shed,
            svc.metrics
                .tenant_rejected
                .load(std::sync::atomic::Ordering::Relaxed),
            "every shed request must be counted"
        );
        // a 16-burst against quota 1 cannot all have been admitted
        assert!(shed > 0, "quota must have shed at least one request");
    }
}
