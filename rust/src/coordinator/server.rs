//! TCP server + model workers.
//!
//! Topology: one listener thread accepts connections into a **bounded
//! connection-worker pool** (reusing [`util::threadpool`]); beyond
//! `max_connections` concurrent connections, new sockets are rejected at
//! accept time with an error line (`conns_rejected` counter). Each
//! admitted connection is split into two pool jobs:
//!
//! * a **reader** that parses line-JSON envelopes (v1, or legacy v0 — see
//!   [`protocol`]) and `submit()`s requests to the model's [`Batcher`]
//!   *without blocking* — after the `hello` handshake, up to
//!   `pipeline_depth` requests per connection may be in flight at once,
//!   so the dynamic batcher can coalesce a single client's burst into one
//!   probabilistic forward pass (the paper's Fig. 7 batching advantage,
//!   reachable from one socket); connections that never send `hello` keep
//!   the legacy one-at-a-time in-order semantics;
//! * a **writer** fed by a per-connection response channel that sends
//!   responses back tagged by `id` in *completion order* (out-of-order
//!   relative to submission is allowed and expected).
//!
//! One worker thread per model lane drains its batcher, runs the lane on
//! the coalesced mini-batch, post-processes uncertainty and fans
//! responses back out to each request's reply channel. Lanes come in two
//! kinds:
//!
//! * **static lanes** ([`Service::register`]) own a boxed [`Backend`] for
//!   the process lifetime — the xla / svi paths;
//! * **registry lanes** (opened by the admin `load` command or
//!   [`Service::attach_registry`]) resolve their executor per batch
//!   through the [`Registry`]: each request pins the then-active
//!   [`ModelVersion`] `Arc` at submit time, the batcher never mixes
//!   versions in one batch, and a `swap` cuts over atomically — in-flight
//!   requests finish on the version they pinned, new ones land on the new
//!   version, and the old executor (plans included) frees at refcount
//!   zero.
//!
//! Also usable in-process (no TCP) through [`Service::submit`] /
//! [`Service::infer_blocking`] — the integration tests and benches drive
//! it both ways.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig, WorkItem};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{
    self, Command, Envelope, Inbound, ProtoVersion, Response,
};
use crate::coordinator::{postprocess, Backend};
use crate::error::{Error, Result};
use crate::model::Arch;
use crate::registry::{ModelSpec, ModelVersion, Registry};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::threadpool::{self, ThreadPool};

/// Tick granularity for blocked connection readers: a reader blocked in
/// `read_until` re-checks the server-wide stop flag at this interval, so
/// `Server::run` terminates promptly even with idle clients connected.
const READ_TICK: Duration = Duration::from_millis(200);

/// Upper bound on one blocking socket write. A peer that sends requests
/// but never drains responses would otherwise wedge a connection job in
/// `write_all` forever — and `Server::run` waits for connection jobs, so
/// a wedged write would turn into a shutdown hang. After a timed-out
/// write the connection is killed instead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(2);

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub batcher: BatcherConfig,
    /// Eq. 11 logit samples for the uncertainty decomposition.
    pub logit_samples: usize,
    /// MI threshold above which a prediction is flagged OOD.
    pub ood_threshold: f64,
    /// Size of the service-owned persistent operator pool; 0 (default)
    /// shares the process-wide pool. Every model lane dispatches its
    /// parallel operators onto this one pool, so serving never pays
    /// per-request thread-spawn cost.
    pub pool_threads: usize,
    /// Accept-time admission limit: at most this many concurrent TCP
    /// connections; further sockets are refused with an error line.
    pub max_connections: usize,
    /// Maximum inference requests one connection may keep in flight after
    /// it opts in via the `hello` handshake (0 = follow
    /// `batcher.max_batch`, so a single pipelined client can fill a whole
    /// batch by itself). Requests past the limit get an immediate
    /// per-request error response; connections that never send `hello`
    /// are served one-at-a-time in order (legacy semantics).
    pub pipeline_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            batcher: BatcherConfig::default(),
            logit_samples: 30,
            ood_threshold: 0.25,
            pool_threads: 0,
            max_connections: 64,
            pipeline_depth: 0,
        }
    }
}

struct ModelLane {
    batcher: Arc<Batcher>,
    /// Input width for static lanes; registry lanes re-read it from the
    /// active version at submit (a swap may change the architecture).
    features: usize,
    registry_backed: bool,
}

/// What a lane worker runs its batches on.
enum LaneMode {
    /// A process-lifetime boxed backend (xla / svi / plain native).
    Static { backend: Box<dyn Backend>, features: usize },
    /// Per-batch executor resolution through the version `Arc` each
    /// request pinned at submit time.
    Registry { registry: Arc<Registry> },
}

/// The routing + batching service (transport-agnostic core).
pub struct Service {
    lanes: RwLock<HashMap<String, ModelLane>>,
    pub metrics: Arc<Metrics>,
    cfg: ServerConfig,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    stopping: Arc<AtomicBool>,
    /// One persistent operator pool shared by every lane and request.
    pool: Arc<ThreadPool>,
    /// The multi-model control plane, when serving registry-managed
    /// models (admin `load` / `swap` / `unload` / `models`).
    registry: Option<Arc<Registry>>,
    /// Calibration factor admin `load`/`swap` fall back to when the
    /// command omits `calib`.
    default_calib: f32,
}

impl Service {
    pub fn new(cfg: ServerConfig) -> Self {
        let pool = if cfg.pool_threads == 0 {
            threadpool::global().clone()
        } else {
            Arc::new(ThreadPool::new(cfg.pool_threads))
        };
        Self {
            lanes: RwLock::new(HashMap::new()),
            metrics: Arc::new(Metrics::new()),
            cfg,
            workers: Mutex::new(Vec::new()),
            stopping: Arc::new(AtomicBool::new(false)),
            pool,
            registry: None,
            default_calib: 1.0,
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Effective per-connection pipeline depth (`pipeline_depth`, or
    /// `batcher.max_batch` when left at 0 so one client can fill a batch).
    pub fn pipeline_depth(&self) -> usize {
        if self.cfg.pipeline_depth == 0 {
            self.cfg.batcher.max_batch.max(1)
        } else {
            self.cfg.pipeline_depth
        }
    }

    /// Accept-time connection admission limit.
    pub fn max_connections(&self) -> usize {
        self.cfg.max_connections.max(1)
    }

    /// The service-wide persistent operator pool. Backends registered on
    /// this service should be built with
    /// `Schedules::...with_pool(service.pool().clone())` so all lanes
    /// reuse the same long-lived workers.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Register a static model lane: spawns the worker thread that owns
    /// `backend` for the process lifetime.
    pub fn register(&mut self, name: &str, features: usize, mut backend: Box<dyn Backend>) {
        // let the backend publish its own counters (cold plan compiles)
        backend.attach_metrics(self.metrics.clone());
        self.spawn_lane(name, features, false, LaneMode::Static { backend, features });
    }

    /// Adopt a model registry: admin commands (`load`/`swap`/`unload`/
    /// `models`) become live, and a registry lane is opened for every
    /// model already published in it. `default_calib` is the calibration
    /// factor admin loads fall back to.
    pub fn attach_registry(&mut self, registry: Arc<Registry>, default_calib: f32) {
        for name in registry.names() {
            if let Some(mv) = registry.get(&name) {
                self.ensure_registry_lane(&name, mv.features());
            }
        }
        self.default_calib = default_calib;
        self.registry = Some(registry);
    }

    /// The attached registry, if any.
    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    fn require_registry(&self) -> Result<&Arc<Registry>> {
        self.registry.as_ref().ok_or_else(|| {
            Error::Coordinator(
                "no model registry attached (serve with --backend native)".into(),
            )
        })
    }

    fn spawn_lane(&self, name: &str, features: usize, registry_backed: bool, mode: LaneMode) {
        let batcher = Arc::new(Batcher::new(self.cfg.batcher));
        let lane_batcher = batcher.clone();
        let metrics = self.metrics.clone();
        let samples = self.cfg.logit_samples;
        let threshold = self.cfg.ood_threshold;
        let handle = std::thread::Builder::new()
            .name(format!("worker-{name}"))
            .spawn(move || lane_worker(lane_batcher, metrics, samples, threshold, mode))
            .expect("spawn worker");
        self.workers.lock().unwrap().push(handle);
        self.lanes.write().unwrap().insert(
            name.to_string(),
            ModelLane { batcher, features, registry_backed },
        );
    }

    fn ensure_registry_lane(&self, name: &str, features: usize) {
        if self.lanes.read().unwrap().contains_key(name) {
            return;
        }
        let registry = self
            .registry
            .as_ref()
            .expect("registry lanes require an attached registry")
            .clone();
        self.spawn_lane(name, features, true, LaneMode::Registry { registry });
    }

    fn admin_spec(
        &self,
        model: &str,
        path: &str,
        arch: Option<&str>,
        calib: Option<f64>,
    ) -> Result<ModelSpec> {
        Ok(ModelSpec {
            name: model.to_string(),
            path: PathBuf::from(path),
            arch: Arch::by_name(arch.unwrap_or(model))?,
            calib: calib.map(|c| c as f32).unwrap_or(self.default_calib),
        })
    }

    fn reject_static_lane(&self, model: &str) -> Result<()> {
        let lanes = self.lanes.read().unwrap();
        match lanes.get(model) {
            Some(l) if !l.registry_backed => Err(Error::Coordinator(format!(
                "model '{model}' is a static lane (not registry-managed)"
            ))),
            _ => Ok(()),
        }
    }

    /// Admin `load`: publish a weight archive as a new model (version 1)
    /// and open its serving lane.
    pub fn admin_load(
        &self,
        model: &str,
        path: &str,
        arch: Option<&str>,
        calib: Option<f64>,
    ) -> Result<Json> {
        let registry = self.require_registry()?.clone();
        self.reject_static_lane(model)?;
        let spec = self.admin_spec(model, path, arch, calib)?;
        let mv = registry.load(&spec)?;
        self.ensure_registry_lane(model, mv.features());
        Ok(Json::obj(vec![
            ("loaded", Json::Bool(true)),
            ("model", Json::Str(model.to_string())),
            ("version", Json::Num(mv.version as f64)),
            ("checksum", Json::Str(format!("{:016x}", mv.checksum))),
            ("mapped", Json::Bool(mv.mapped)),
        ]))
    }

    /// Admin `swap`: atomically publish the next version of `model`.
    /// In-flight requests finish on the version they pinned at submit.
    pub fn admin_swap(
        &self,
        model: &str,
        path: &str,
        arch: Option<&str>,
        calib: Option<f64>,
    ) -> Result<Json> {
        let registry = self.require_registry()?.clone();
        self.reject_static_lane(model)?;
        let spec = self.admin_spec(model, path, arch, calib)?;
        let mv = registry.swap(&spec)?;
        self.ensure_registry_lane(model, mv.features());
        Ok(Json::obj(vec![
            ("swapped", Json::Bool(true)),
            ("model", Json::Str(model.to_string())),
            ("version", Json::Num(mv.version as f64)),
            ("checksum", Json::Str(format!("{:016x}", mv.checksum))),
            ("mapped", Json::Bool(mv.mapped)),
        ]))
    }

    /// Admin `unload`: retire a model. Queued and in-flight requests
    /// still drain on their pinned versions; the lane then closes.
    pub fn admin_unload(&self, model: &str) -> Result<Json> {
        let registry = self.require_registry()?.clone();
        self.reject_static_lane(model)?;
        registry.unload(model)?;
        if let Some(lane) = self.lanes.write().unwrap().remove(model) {
            lane.batcher.close();
        }
        Ok(Json::obj(vec![
            ("unloaded", Json::Bool(true)),
            ("model", Json::Str(model.to_string())),
        ]))
    }

    /// Admin `models`: the registry listing (per-model version, checksum,
    /// request/plan counters, budget headline).
    pub fn admin_models(&self) -> Result<Json> {
        Ok(self.require_registry()?.models_json())
    }

    /// The metrics snapshot, extended with the registry listing when a
    /// registry is attached (per-model request / plan-cache counters).
    pub fn metrics_snapshot(&self) -> Json {
        let base = self.metrics.snapshot();
        match (&self.registry, base) {
            (Some(reg), Json::Obj(mut m)) => {
                m.insert("registry".to_string(), reg.models_json());
                Json::Obj(m)
            }
            (_, base) => base,
        }
    }

    /// Route one request into its lane (non-blocking), sending the
    /// response to the caller-provided channel. This is the pipelining
    /// primitive: many in-flight requests can share one reply sender, and
    /// responses arrive on it in completion order. On registry lanes the
    /// then-active model version is pinned here — the epoch handoff that
    /// makes `swap` atomic from the request's point of view.
    pub fn submit_with_proto(
        &self,
        req: protocol::Request,
        reply: Sender<Response>,
        proto: ProtoVersion,
    ) -> Result<()> {
        let lanes = self.lanes.read().unwrap();
        let lane = lanes
            .get(&req.model)
            .ok_or_else(|| Error::Coordinator(format!("unknown model '{}'", req.model)))?;
        let model = if lane.registry_backed {
            Some(
                self.registry
                    .as_ref()
                    .and_then(|r| r.get(&req.model))
                    .ok_or_else(|| {
                        Error::Coordinator(format!("unknown model '{}'", req.model))
                    })?,
            )
        } else {
            None
        };
        let features = model.as_ref().map_or(lane.features, |m| m.features());
        if req.input.len() != features {
            return Err(Error::Coordinator(format!(
                "model '{}' expects {} features, got {}",
                req.model,
                features,
                req.input.len()
            )));
        }
        Metrics::inc(&self.metrics.requests);
        // gauge up BEFORE the push publishes the item: the lane worker may
        // pop and decrement immediately, and inc-after-push would let the
        // unsigned gauge wrap below zero
        Metrics::inc(&self.metrics.in_flight);
        let item = WorkItem {
            id: req.id,
            input: req.input,
            enqueued: Instant::now(),
            reply,
            proto,
            model,
        };
        if lane.batcher.push(item).is_err() {
            Metrics::dec(&self.metrics.in_flight);
            Metrics::inc(&self.metrics.rejected);
            return Err(Error::Coordinator("queue full".into()));
        }
        Ok(())
    }

    /// [`submit_with_proto`](Self::submit_with_proto) under the legacy
    /// (v0) response shape.
    pub fn submit_with(&self, req: protocol::Request, reply: Sender<Response>) -> Result<()> {
        self.submit_with_proto(req, reply, ProtoVersion::V0)
    }

    /// Route one request into its lane (non-blocking) on a fresh channel.
    pub fn submit(&self, req: protocol::Request) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        self.submit_with(req, tx)?;
        Ok(rx)
    }

    /// Submit and block for the response (in-process convenience).
    pub fn infer_blocking(&self, req: protocol::Request) -> Response {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or(Response {
                id,
                result: Err("worker dropped".into()),
                queue_us: 0,
                infer_us: 0,
                proto: ProtoVersion::V0,
                model_version: 0,
            }),
            Err(e) => Response {
                id,
                result: Err(e.to_string()),
                queue_us: 0,
                infer_us: 0,
                proto: ProtoVersion::V0,
                model_version: 0,
            },
        }
    }

    /// Close all lanes and join workers.
    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        for lane in self.lanes.read().unwrap().values() {
            lane.batcher.close();
        }
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    pub fn is_stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One model lane's worker loop: drain version-contiguous batches, run
/// them, fan the responses back out.
fn lane_worker(
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    samples: usize,
    threshold: f64,
    mut mode: LaneMode,
) {
    let mut seed = 0x5EED_u64;
    while let Some(batch) = batcher.next_batch() {
        let b = batch.len();
        Metrics::inc(&metrics.batches);
        Metrics::add(&metrics.batched_items, b as u64);
        let infer_t = Instant::now();
        // the batcher never mixes versions: the first item's pinned Arc
        // (if any) is the whole batch's executor
        let mv: Option<Arc<ModelVersion>> = batch[0].model.clone();
        let model_version = mv.as_ref().map_or(0, |m| m.version);
        let features = match (&mode, &mv) {
            (LaneMode::Static { features, .. }, _) => *features,
            (LaneMode::Registry { .. }, Some(m)) => m.features(),
            (LaneMode::Registry { .. }, None) => {
                fan_errors(batch, &metrics, "request lost its model version", 0);
                continue;
            }
        };
        let mut data = Vec::with_capacity(b * features);
        for it in &batch {
            data.extend_from_slice(&it.input);
        }
        let x = match Tensor::new(vec![b, features], data) {
            Ok(x) => x,
            Err(e) => {
                fan_errors(batch, &metrics, &format!("bad input: {e}"), model_version);
                continue;
            }
        };
        seed = seed.wrapping_add(1);
        let outcome = match &mut mode {
            LaneMode::Static { backend, .. } => backend.infer(&x),
            LaneMode::Registry { registry } => {
                let m = mv.as_ref().expect("registry batch carries its version");
                m.infer(&x).map(|(mu, var, delta)| {
                    // per-batch plan-cache movement -> global counters,
                    // then hold the whole fleet to the memory budget
                    Metrics::add(&metrics.plan_compiles, delta.compiles);
                    Metrics::add(&metrics.plan_cache_evictions, delta.evictions);
                    Metrics::add(
                        &metrics.plan_cache_evictions,
                        registry.enforce_budget(),
                    );
                    (mu, var)
                })
            }
        };
        match outcome {
            Ok((mu, var)) => {
                let infer_us = infer_t.elapsed().as_micros() as u64;
                let preds = postprocess(&mu, &var, samples, threshold, seed);
                for (it, p) in batch.into_iter().zip(preds) {
                    if p.ood {
                        Metrics::inc(&metrics.ood_flagged);
                    }
                    // one timestamp per item: end-to-end latency, of which
                    // everything not spent in the batch's inference call
                    // was queueing/batching wait
                    let elapsed = it.enqueued.elapsed().as_micros() as u64;
                    let queue_us = elapsed.saturating_sub(infer_us);
                    metrics.record_latency_us(elapsed as f64);
                    Metrics::inc(&metrics.responses);
                    Metrics::dec(&metrics.in_flight);
                    let _ = it.reply.send(Response {
                        id: it.id,
                        result: Ok(p),
                        queue_us,
                        infer_us,
                        proto: it.proto,
                        model_version,
                    });
                }
            }
            Err(e) => fan_errors(
                batch,
                &metrics,
                &format!("inference failed: {e}"),
                model_version,
            ),
        }
    }
}

fn fan_errors(batch: Vec<WorkItem>, metrics: &Metrics, msg: &str, model_version: u64) {
    for it in batch {
        Metrics::dec(&metrics.in_flight);
        let _ = it.reply.send(Response {
            id: it.id,
            result: Err(msg.to_string()),
            queue_us: 0,
            infer_us: 0,
            proto: it.proto,
            model_version,
        });
    }
}

/// TCP front end over a [`Service`].
pub struct Server {
    service: Arc<Service>,
    listener: TcpListener,
    pub addr: std::net::SocketAddr,
}

impl Server {
    /// Bind (use port 0 in `cfg.addr` for an ephemeral port).
    pub fn bind(service: Arc<Service>) -> Result<Self> {
        let listener = TcpListener::bind(&service.cfg.addr)
            .map_err(|e| Error::Coordinator(format!("bind {}: {e}", service.cfg.addr)))?;
        let addr = listener.local_addr()?;
        Ok(Self { service, listener, addr })
    }

    /// Serve until a shutdown command arrives. Connections are handled by
    /// a bounded worker pool (two jobs per connection: reader + writer);
    /// past `max_connections` concurrent clients, new sockets get an
    /// error line and are closed at accept time. Returns once the accept
    /// loop has stopped and every connection job has finished (readers
    /// notice the stop flag within [`READ_TICK`]).
    pub fn run(&self) -> Result<()> {
        self.listener.set_nonblocking(false)?;
        let max_conns = self.service.max_connections();
        // Lazily grown: an idle server owns zero connection threads; each
        // admitted connection grows the pool by its two jobs (reader +
        // writer) on demand, up to the 2-per-connection cap. The old
        // eager sizing burned 2 * max_connections OS threads (128 with
        // defaults) at bind time — hostile to the embedded target.
        let conn_pool = ThreadPool::new_lazy(2 * max_conns);
        let active = AtomicUsize::new(0);
        let listener_addr = self.addr;
        conn_pool.scope(|s| {
            for stream in self.listener.incoming() {
                if self.service.is_stopping() {
                    break;
                }
                match stream {
                    Ok(sock) => {
                        if active.load(Ordering::SeqCst) >= max_conns {
                            Metrics::inc(&self.service.metrics.conns_rejected);
                            let mut sock = sock;
                            let _ = sock.write_all(
                                b"{\"error\":\"server at max connections\"}\n",
                            );
                            continue; // socket dropped: rejected at accept
                        }
                        active.fetch_add(1, Ordering::SeqCst);
                        Metrics::inc(&self.service.metrics.connections);
                        match ConnectionHalves::split(self.service.clone(), sock) {
                            Ok((reader, writer)) => {
                                s.spawn(move || reader.run(listener_addr));
                                let active = &active;
                                s.spawn(move || {
                                    writer.run();
                                    // the writer outlives its reader (it
                                    // exits only after the reader drops the
                                    // reply sender and the channel drains),
                                    // so the admission slot frees only when
                                    // BOTH halves are done and both pool
                                    // workers are truly reusable
                                    active.fetch_sub(1, Ordering::SeqCst);
                                });
                            }
                            Err(e) => {
                                active.fetch_sub(1, Ordering::SeqCst);
                                eprintln!("connection setup error: {e}");
                            }
                        }
                    }
                    Err(e) => {
                        eprintln!("accept error: {e}");
                    }
                }
            }
        });
        Ok(())
    }
}

/// Write one protocol line atomically (the socket is shared between the
/// connection's reader — control/rejection replies — and its writer).
///
/// The whole line is subject to one [`WRITE_TIMEOUT`] budget: the socket's
/// `SO_SNDTIMEO` only bounds a *single* `write()` call, so a slow-drip
/// peer draining a few bytes per timeout could otherwise keep a plain
/// `write_all` looping forever and wedge the connection job.
fn send_line(out: &Mutex<TcpStream>, line: &str) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(line.len() + 1);
    buf.extend_from_slice(line.as_bytes());
    buf.push(b'\n');
    let deadline = Instant::now() + WRITE_TIMEOUT;
    let mut w = out.lock().unwrap();
    let mut written = 0;
    while written < buf.len() {
        if Instant::now() >= deadline {
            return Err(std::io::Error::new(
                ErrorKind::TimedOut,
                "write budget exceeded",
            ));
        }
        match w.write(&buf[written..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "peer closed",
                ))
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// The two pool jobs one admitted connection turns into.
struct ConnectionHalves;

impl ConnectionHalves {
    fn split(svc: Arc<Service>, stream: TcpStream) -> Result<(ConnReader, ConnWriter)> {
        // line-sized request/response pairs: Nagle + delayed-ACK would add
        // ~40ms per round trip, swamping sub-ms inference.
        stream.set_nodelay(true).ok();
        // bounded blocking so the reader can notice a server-wide stop
        stream.set_read_timeout(Some(READ_TICK)).ok();
        // and so a never-draining peer cannot wedge a write forever
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
        let out = Arc::new(Mutex::new(stream.try_clone()?));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let (reply_tx, reply_rx) = channel::<Response>();
        let reader = ConnReader {
            svc,
            reader: BufReader::new(stream),
            out: out.clone(),
            reply_tx,
            in_flight: in_flight.clone(),
        };
        let writer = ConnWriter { reply_rx, out, in_flight };
        Ok((reader, writer))
    }
}

/// Reader half: parses inbound lines and routes them without blocking on
/// inference, so one client can keep `pipeline_depth` requests in flight.
struct ConnReader {
    svc: Arc<Service>,
    reader: BufReader<TcpStream>,
    out: Arc<Mutex<TcpStream>>,
    reply_tx: Sender<Response>,
    in_flight: Arc<AtomicUsize>,
}

/// Per-connection pipelining state, owned by the reader.
struct ConnState {
    /// Max requests in flight on this connection.
    depth: usize,
    /// True once the client opted in via `{"cmd":"hello","pipeline":true}`.
    /// Pipelined connections get an explicit error response on a depth
    /// overrun; non-pipelined ones are served with the legacy blocking
    /// semantics (the reader waits for the window to drain), so clients
    /// written against the old synchronous server behave identically.
    pipelined: bool,
    /// Whether the one-time v0 deprecation warning already went out on
    /// this connection.
    warned_v0: bool,
}

impl ConnReader {
    fn run(mut self, listener_addr: SocketAddr) {
        let configured_depth = self.svc.pipeline_depth();
        // until the hello handshake opts in, a connection is limited to
        // one request in flight and served strictly in order — exactly
        // the old synchronous server's observable behaviour, even for
        // clients that pipeline their *writes*
        let mut state = ConnState { depth: 1, pipelined: false, warned_v0: false };
        // accumulate raw bytes (NOT read_line into a String: on a timeout
        // error read_line discards the bytes it already consumed from the
        // socket, corrupting the stream; read_until keeps them appended,
        // so partial lines survive READ_TICK timeouts until the newline
        // arrives)
        let mut acc: Vec<u8> = Vec::new();
        loop {
            if self.svc.is_stopping() {
                break;
            }
            match self.reader.read_until(b'\n', &mut acc) {
                Ok(0) => break, // EOF
                Ok(_) => {
                    let bytes = std::mem::take(&mut acc);
                    let line = String::from_utf8_lossy(&bytes);
                    let line = line.trim();
                    if line.is_empty() {
                        continue;
                    }
                    if !self.handle_line(line, &mut state, configured_depth, listener_addr) {
                        break;
                    }
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut =>
                {
                    continue
                }
                Err(_) => break,
            }
        }
        // dropping reply_tx lets the writer exit once all in-flight
        // responses have drained
    }

    /// Take the one-time v0 deprecation warning if this message earns it.
    fn take_v0_warning(
        &self,
        proto: ProtoVersion,
        state: &mut ConnState,
    ) -> Option<&'static str> {
        if proto == ProtoVersion::V0 && !state.warned_v0 {
            state.warned_v0 = true;
            Some(protocol::V0_DEPRECATION)
        } else {
            None
        }
    }

    /// Send a control acknowledgement sealed under the request's protocol
    /// generation (first v0 ack carries the deprecation warning).
    fn ack(&self, body: Json, proto: ProtoVersion, state: &mut ConnState) {
        let warning = self.take_v0_warning(proto, state);
        let _ = send_line(&self.out, &Envelope::seal(body, proto, warning).dump());
    }

    /// Handle one parsed line; returns false when the connection is done.
    fn handle_line(
        &self,
        line: &str,
        state: &mut ConnState,
        configured_depth: usize,
        listener_addr: SocketAddr,
    ) -> bool {
        let env = match Envelope::parse(line) {
            Ok(env) => env,
            Err(e) => {
                // a malformed or unknown-version line has no trustworthy
                // generation to answer under: reply bare, like v0 always did
                let msg = Json::obj(vec![(
                    "error",
                    Json::Str(format!("bad request: {e}")),
                )]);
                let _ = send_line(&self.out, &msg.dump());
                return true;
            }
        };
        let proto = env.proto;
        match env.body {
            Inbound::Control(Command::Ping) => {
                self.ack(Json::obj(vec![("pong", Json::Bool(true))]), proto, state);
            }
            Inbound::Control(Command::Hello { pipeline }) => {
                state.pipelined = pipeline;
                state.depth = if pipeline { configured_depth } else { 1 };
                let warning = self.take_v0_warning(proto, state);
                let ack = protocol::hello_json_proto(
                    pipeline,
                    state.depth,
                    self.svc.cfg.batcher.max_batch,
                    proto,
                    warning,
                );
                let _ = send_line(&self.out, &ack);
            }
            Inbound::Control(Command::Metrics) => {
                self.ack(self.svc.metrics_snapshot(), proto, state);
            }
            Inbound::Control(Command::Shutdown) => {
                self.ack(
                    Json::obj(vec![("shutting_down", Json::Bool(true))]),
                    proto,
                    state,
                );
                self.svc.stopping.store(true, Ordering::SeqCst);
                // wake the accept loop with a dummy connection to the
                // *listener* address (the accepted socket's own address
                // is not reliably dialable); a wildcard bind (0.0.0.0 /
                // ::) is itself not dialable everywhere, so rewrite it to
                // the matching loopback
                let mut poke = listener_addr;
                if poke.ip().is_unspecified() {
                    poke.set_ip(match poke.ip() {
                        std::net::IpAddr::V4(_) => {
                            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                        }
                        std::net::IpAddr::V6(_) => {
                            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                        }
                    });
                }
                let _ = TcpStream::connect(poke);
                return false;
            }
            Inbound::Control(Command::Load { model, path, arch, calib }) => {
                let body = self
                    .svc
                    .admin_load(&model, &path, arch.as_deref(), calib)
                    .unwrap_or_else(|e| {
                        Json::obj(vec![("error", Json::Str(e.to_string()))])
                    });
                self.ack(body, proto, state);
            }
            Inbound::Control(Command::Swap { model, path, arch, calib }) => {
                let body = self
                    .svc
                    .admin_swap(&model, &path, arch.as_deref(), calib)
                    .unwrap_or_else(|e| {
                        Json::obj(vec![("error", Json::Str(e.to_string()))])
                    });
                self.ack(body, proto, state);
            }
            Inbound::Control(Command::Unload { model }) => {
                let body = self.svc.admin_unload(&model).unwrap_or_else(|e| {
                    Json::obj(vec![("error", Json::Str(e.to_string()))])
                });
                self.ack(body, proto, state);
            }
            Inbound::Control(Command::Models) => {
                let body = self.svc.admin_models().unwrap_or_else(|e| {
                    Json::obj(vec![("error", Json::Str(e.to_string()))])
                });
                self.ack(body, proto, state);
            }
            Inbound::Infer(req) => {
                let mut current = self.in_flight.load(Ordering::SeqCst);
                if current >= state.depth {
                    if state.pipelined {
                        // explicit per-request error: the client can match
                        // it by id and retry after draining some responses
                        Metrics::inc(&self.svc.metrics.depth_rejected);
                        let resp = Response {
                            id: req.id,
                            result: Err(format!(
                                "pipeline depth {} exceeded",
                                state.depth
                            )),
                            queue_us: 0,
                            infer_us: 0,
                            proto,
                            model_version: 0,
                        };
                        let _ = send_line(&self.out, &resp.to_json().dump());
                        return true;
                    }
                    // legacy connection: emulate the old synchronous
                    // server — apply backpressure by waiting for the
                    // previous response to go out before admitting more
                    while current >= state.depth {
                        if self.svc.is_stopping() {
                            return false;
                        }
                        std::thread::sleep(Duration::from_micros(200));
                        current = self.in_flight.load(Ordering::SeqCst);
                    }
                }
                self.svc.metrics.record_conn_depth((current + 1) as f64);
                self.in_flight.fetch_add(1, Ordering::SeqCst);
                let id = req.id;
                if let Err(e) = self.svc.submit_with_proto(req, self.reply_tx.clone(), proto)
                {
                    self.in_flight.fetch_sub(1, Ordering::SeqCst);
                    let resp = Response {
                        id,
                        result: Err(e.to_string()),
                        queue_us: 0,
                        infer_us: 0,
                        proto,
                        model_version: 0,
                    };
                    let _ = send_line(&self.out, &resp.to_json().dump());
                }
            }
        }
        true
    }
}

/// Writer half: drains the per-connection response channel and sends each
/// response (tagged by `id`, completion order) back over the socket.
struct ConnWriter {
    reply_rx: Receiver<Response>,
    out: Arc<Mutex<TcpStream>>,
    in_flight: Arc<AtomicUsize>,
}

impl ConnWriter {
    fn run(self) {
        let ConnWriter { reply_rx, out, in_flight } = self;
        let mut dead = false;
        for resp in reply_rx {
            // free the pipeline slot *before* the response hits the wire,
            // so a client that replenishes on receipt never races into a
            // spurious depth rejection
            in_flight.fetch_sub(1, Ordering::SeqCst);
            if dead {
                // keep draining (without writing) so lane replies stay
                // paired with the in-flight accounting
                continue;
            }
            if send_line(&out, &resp.to_json().dump()).is_err() {
                // peer gone or not draining (write timed out): kill the
                // socket so the reader unblocks too, and stop writing
                dead = true;
                if let Ok(s) = out.lock() {
                    let _ = s.shutdown(std::net::Shutdown::Both);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativePfpBackend;
    use crate::model::{Arch, PosteriorWeights, Schedules, SchedulesBuilder};

    fn test_service() -> Service {
        let mut svc = Service::new(ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        svc.register(
            "mlp",
            784,
            Box::new(NativePfpBackend::new(arch, w, Schedules::default())),
        );
        svc
    }

    fn registry_service(tag: &str) -> (Service, std::path::PathBuf) {
        let mut svc = Service::new(ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        let registry = Arc::new(Registry::new(None, true, SchedulesBuilder::tuned(1)));
        svc.attach_registry(registry, 1.0);
        let arch = Arch::mlp();
        let path = std::env::temp_dir().join(format!(
            "pfp_server_reg_{}_{tag}.npz",
            std::process::id()
        ));
        PosteriorWeights::synthetic(&arch, 9).save_npz(&path).unwrap();
        (svc, path)
    }

    #[test]
    fn in_process_roundtrip() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 1,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        let p = resp.result.expect("inference should succeed");
        assert!((0..10).contains(&p.pred));
        assert_eq!(p.mu.len(), 10);
        assert!(p.total >= p.mi - 1e-9);
        assert_eq!(resp.model_version, 0, "static lanes carry no version");
    }

    #[test]
    fn unknown_model_rejected() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 2,
            model: "nope".into(),
            input: vec![0.0; 784],
        });
        assert!(resp.result.is_err());
    }

    #[test]
    fn wrong_feature_count_rejected() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 3,
            model: "mlp".into(),
            input: vec![0.0; 10],
        });
        assert!(resp.result.unwrap_err().contains("features"));
    }

    #[test]
    fn pipeline_depth_defaults_to_max_batch() {
        let mut cfg = ServerConfig::default();
        cfg.batcher.max_batch = 7;
        let svc = Service::new(cfg);
        assert_eq!(svc.pipeline_depth(), 7);
        let mut cfg = ServerConfig::default();
        cfg.pipeline_depth = 3;
        let svc = Service::new(cfg);
        assert_eq!(svc.pipeline_depth(), 3);
    }

    #[test]
    fn submit_with_shares_one_reply_channel() {
        let svc = test_service();
        let (tx, rx) = channel();
        for i in 0..4u64 {
            svc.submit_with(
                protocol::Request {
                    id: i,
                    model: "mlp".into(),
                    input: vec![0.25; 784],
                },
                tx.clone(),
            )
            .expect("submit");
        }
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| {
            assert!(r.result.is_ok());
            r.id
        }).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        // gauge drained back to zero once every response was delivered
        assert_eq!(
            svc.metrics.in_flight.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn concurrent_submissions_batched() {
        let svc = Arc::new(test_service());
        let mut handles = Vec::new();
        for i in 0..20 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                svc.infer_blocking(protocol::Request {
                    id: i,
                    model: "mlp".into(),
                    input: vec![0.1 * (i as f32 % 10.0); 784],
                })
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.result.is_ok());
        }
        // dynamic batching must have coalesced at least some requests
        assert!(svc.metrics.mean_batch_size() >= 1.0);
        assert_eq!(
            svc.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
            20
        );
    }

    #[test]
    fn admin_lifecycle_load_infer_swap_unload() {
        let (svc, path) = registry_service("lifecycle");
        let p = path.to_string_lossy().to_string();

        // load opens a lane; responses carry the version
        let ack = svc.admin_load("mlp", &p, None, None).unwrap();
        assert_eq!(ack.num_field("version").unwrap(), 1.0);
        let resp = svc.infer_blocking(protocol::Request {
            id: 1,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        assert!(resp.result.is_ok());
        assert_eq!(resp.model_version, 1);

        // swap bumps the served version
        let ack = svc.admin_swap("mlp", &p, None, None).unwrap();
        assert_eq!(ack.num_field("version").unwrap(), 2.0);
        let resp = svc.infer_blocking(protocol::Request {
            id: 2,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        assert_eq!(resp.model_version, 2);

        // listing + merged metrics see the registry
        let models = svc.admin_models().unwrap();
        assert!(models.get("models").is_some());
        assert!(svc.metrics_snapshot().get("registry").is_some());

        // unload closes the lane
        svc.admin_unload("mlp").unwrap();
        let resp = svc.infer_blocking(protocol::Request {
            id: 3,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        assert!(resp.result.is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn admin_requires_registry() {
        let svc = test_service();
        assert!(svc.admin_models().is_err());
        assert!(svc.admin_load("m", "w.npz", None, None).is_err());
        // and a static lane name cannot be hijacked even with a registry
        let (svc2, path) = registry_service("requires");
        drop(svc2);
        std::fs::remove_file(&path).ok();
        let err = svc.admin_unload("mlp").unwrap_err();
        assert!(err.to_string().contains("no model registry"));
    }
}
