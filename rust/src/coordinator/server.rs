//! TCP server + model workers.
//!
//! Topology: one listener thread accepts connections; each connection gets
//! a reader thread that parses line-JSON requests, routes them to the
//! model's [`Batcher`] and forwards responses back over the socket. One
//! worker thread per registered model drains its batcher, runs the
//! backend on the coalesced mini-batch, post-processes uncertainty and
//! fans responses back out.
//!
//! Also usable in-process (no TCP) through [`Service::infer_blocking`] —
//! the integration tests and benches drive it both ways.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::{Batcher, BatcherConfig, WorkItem};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{self, Command, Inbound, Response};
use crate::coordinator::{postprocess, Backend};
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::threadpool::{self, ThreadPool};

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub batcher: BatcherConfig,
    /// Eq. 11 logit samples for the uncertainty decomposition.
    pub logit_samples: usize,
    /// MI threshold above which a prediction is flagged OOD.
    pub ood_threshold: f64,
    /// Size of the service-owned persistent operator pool; 0 (default)
    /// shares the process-wide pool. Every model lane dispatches its
    /// parallel operators onto this one pool, so serving never pays
    /// per-request thread-spawn cost.
    pub pool_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            batcher: BatcherConfig::default(),
            logit_samples: 30,
            ood_threshold: 0.25,
            pool_threads: 0,
        }
    }
}

struct ModelLane {
    batcher: Arc<Batcher>,
    features: usize,
}

/// The routing + batching service (transport-agnostic core).
pub struct Service {
    lanes: HashMap<String, ModelLane>,
    pub metrics: Arc<Metrics>,
    cfg: ServerConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    /// One persistent operator pool shared by every lane and request.
    pool: Arc<ThreadPool>,
}

impl Service {
    pub fn new(cfg: ServerConfig) -> Self {
        let pool = if cfg.pool_threads == 0 {
            threadpool::global().clone()
        } else {
            Arc::new(ThreadPool::new(cfg.pool_threads))
        };
        Self {
            lanes: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            cfg,
            workers: Vec::new(),
            stopping: Arc::new(AtomicBool::new(false)),
            pool,
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The service-wide persistent operator pool. Backends registered on
    /// this service should be built with
    /// `Schedules::...with_pool(service.pool().clone())` so all lanes
    /// reuse the same long-lived workers.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Register a model lane: spawns the worker thread that owns `backend`.
    pub fn register(&mut self, name: &str, features: usize, mut backend: Box<dyn Backend>) {
        let batcher = Arc::new(Batcher::new(self.cfg.batcher));
        let lane_batcher = batcher.clone();
        let metrics = self.metrics.clone();
        let samples = self.cfg.logit_samples;
        let threshold = self.cfg.ood_threshold;
        let model = name.to_string();
        let handle = std::thread::Builder::new()
            .name(format!("worker-{model}"))
            .spawn(move || {
                let mut seed = 0x5EED_u64;
                while let Some(batch) = lane_batcher.next_batch() {
                    let b = batch.len();
                    Metrics::inc(&metrics.batches);
                    Metrics::add(&metrics.batched_items, b as u64);
                    let infer_t = Instant::now();
                    let mut data = Vec::with_capacity(b * features);
                    for it in &batch {
                        data.extend_from_slice(&it.input);
                    }
                    let x = match Tensor::new(vec![b, features], data) {
                        Ok(x) => x,
                        Err(e) => {
                            for it in batch {
                                let _ = it.reply.send(Response {
                                    id: it.id,
                                    result: Err(format!("bad input: {e}")),
                                    queue_us: 0,
                                    infer_us: 0,
                                });
                            }
                            continue;
                        }
                    };
                    seed = seed.wrapping_add(1);
                    match backend.infer(&x) {
                        Ok((mu, var)) => {
                            let infer_us = infer_t.elapsed().as_micros() as u64;
                            let preds = postprocess(&mu, &var, samples, threshold, seed);
                            for (it, p) in batch.into_iter().zip(preds) {
                                if p.ood {
                                    Metrics::inc(&metrics.ood_flagged);
                                }
                                let queue_us =
                                    it.enqueued.elapsed().as_micros() as u64 - infer_us.min(
                                        it.enqueued.elapsed().as_micros() as u64,
                                    );
                                metrics.record_latency_us(
                                    it.enqueued.elapsed().as_micros() as f64
                                );
                                Metrics::inc(&metrics.responses);
                                let _ = it.reply.send(Response {
                                    id: it.id,
                                    result: Ok(p),
                                    queue_us,
                                    infer_us,
                                });
                            }
                        }
                        Err(e) => {
                            for it in batch {
                                let _ = it.reply.send(Response {
                                    id: it.id,
                                    result: Err(format!("inference failed: {e}")),
                                    queue_us: 0,
                                    infer_us: 0,
                                });
                            }
                        }
                    }
                }
            })
            .expect("spawn worker");
        self.workers.push(handle);
        self.lanes.insert(name.to_string(), ModelLane { batcher, features });
    }

    /// Route one request into its lane (non-blocking).
    pub fn submit(&self, req: protocol::Request) -> Result<std::sync::mpsc::Receiver<Response>> {
        let lane = self
            .lanes
            .get(&req.model)
            .ok_or_else(|| Error::Coordinator(format!("unknown model '{}'", req.model)))?;
        if req.input.len() != lane.features {
            return Err(Error::Coordinator(format!(
                "model '{}' expects {} features, got {}",
                req.model,
                lane.features,
                req.input.len()
            )));
        }
        Metrics::inc(&self.metrics.requests);
        let (tx, rx) = channel();
        let item = WorkItem {
            id: req.id,
            input: req.input,
            enqueued: Instant::now(),
            reply: tx,
        };
        if lane.batcher.push(item).is_err() {
            Metrics::inc(&self.metrics.rejected);
            return Err(Error::Coordinator("queue full".into()));
        }
        Ok(rx)
    }

    /// Submit and block for the response (in-process convenience).
    pub fn infer_blocking(&self, req: protocol::Request) -> Response {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or(Response {
                id,
                result: Err("worker dropped".into()),
                queue_us: 0,
                infer_us: 0,
            }),
            Err(e) => Response {
                id,
                result: Err(e.to_string()),
                queue_us: 0,
                infer_us: 0,
            },
        }
    }

    /// Close all lanes and join workers.
    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        for lane in self.lanes.values() {
            lane.batcher.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    pub fn is_stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// TCP front end over a [`Service`].
pub struct Server {
    service: Arc<Service>,
    listener: TcpListener,
    pub addr: std::net::SocketAddr,
}

impl Server {
    /// Bind (use port 0 in `cfg.addr` for an ephemeral port).
    pub fn bind(service: Arc<Service>) -> Result<Self> {
        let listener = TcpListener::bind(&service.cfg.addr)
            .map_err(|e| Error::Coordinator(format!("bind {}: {e}", service.cfg.addr)))?;
        let addr = listener.local_addr()?;
        Ok(Self { service, listener, addr })
    }

    /// Serve until a shutdown command arrives.
    pub fn run(&self) -> Result<()> {
        self.listener.set_nonblocking(false)?;
        for stream in self.listener.incoming() {
            if self.service.is_stopping() {
                break;
            }
            match stream {
                Ok(s) => {
                    let svc = self.service.clone();
                    std::thread::spawn(move || {
                        let _ = handle_connection(svc, s);
                    });
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                }
            }
        }
        Ok(())
    }
}

fn handle_connection(svc: Arc<Service>, stream: TcpStream) -> Result<()> {
    // line-sized request/response pairs: Nagle + delayed-ACK would add
    // ~40ms per round trip, swamping sub-ms inference.
    stream.set_nodelay(true).ok();
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_inbound(&line) {
            Ok(Inbound::Control(Command::Ping)) => {
                writeln!(writer, r#"{{"pong":true}}"#)?;
            }
            Ok(Inbound::Control(Command::Metrics)) => {
                writeln!(writer, "{}", svc.metrics.snapshot().dump())?;
            }
            Ok(Inbound::Control(Command::Shutdown)) => {
                writeln!(writer, r#"{{"shutting_down":true}}"#)?;
                svc.stopping.store(true, Ordering::SeqCst);
                // poke the accept loop with a dummy connection
                let _ = TcpStream::connect(writer.local_addr()?);
                break;
            }
            Ok(Inbound::Infer(req)) => {
                let resp = svc.infer_blocking(req);
                writeln!(writer, "{}", resp.to_json().dump())?;
            }
            Err(e) => {
                writeln!(writer, r#"{{"error":"bad request: {e}"}}"#).ok();
            }
        }
    }
    let _ = peer;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativePfpBackend;
    use crate::model::{Arch, PosteriorWeights, Schedules};

    fn test_service() -> Service {
        let mut svc = Service::new(ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        let arch = Arch::mlp();
        let w = PosteriorWeights::synthetic(&arch, 1);
        svc.register(
            "mlp",
            784,
            Box::new(NativePfpBackend::new(arch, w, Schedules::default())),
        );
        svc
    }

    #[test]
    fn in_process_roundtrip() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 1,
            model: "mlp".into(),
            input: vec![0.5; 784],
        });
        let p = resp.result.expect("inference should succeed");
        assert!((0..10).contains(&p.pred));
        assert_eq!(p.mu.len(), 10);
        assert!(p.total >= p.mi - 1e-9);
    }

    #[test]
    fn unknown_model_rejected() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 2,
            model: "nope".into(),
            input: vec![0.0; 784],
        });
        assert!(resp.result.is_err());
    }

    #[test]
    fn wrong_feature_count_rejected() {
        let svc = test_service();
        let resp = svc.infer_blocking(protocol::Request {
            id: 3,
            model: "mlp".into(),
            input: vec![0.0; 10],
        });
        assert!(resp.result.unwrap_err().contains("features"));
    }

    #[test]
    fn concurrent_submissions_batched() {
        let svc = Arc::new(test_service());
        let mut handles = Vec::new();
        for i in 0..20 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                svc.infer_blocking(protocol::Request {
                    id: i,
                    model: "mlp".into(),
                    input: vec![0.1 * (i as f32 % 10.0); 784],
                })
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.result.is_ok());
        }
        // dynamic batching must have coalesced at least some requests
        assert!(svc.metrics.mean_batch_size() >= 1.0);
        assert_eq!(
            svc.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
            20
        );
    }
}
