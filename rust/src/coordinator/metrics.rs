//! Serving metrics: counters + latency histograms, queryable in-band via
//! `{"cmd":"metrics"}`.
//!
//! Latency and pipelining-depth distributions are tracked by a
//! lock-free [`Histogram`] (fixed log-linear buckets of atomics) rather
//! than the old `Mutex<Vec<f64>>` reservoir: recording is a few relaxed
//! atomic ops with no lock, no allocation, and no 100k-sample cap, so
//! the IO threads and lane workers can record from any context — and
//! tail quantiles (p99, p99.9) are exact to bucket resolution instead
//! of being at the mercy of reservoir eviction.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Linear buckets below this value record integers exactly.
const LINEAR: usize = 64;
/// 64 linear buckets + 8 sub-buckets per power of two for msb 6..=63.
const BUCKETS: usize = LINEAR + (64 - 6) * 8;

/// Lock-free log-linear histogram of non-negative values.
///
/// Values below [`LINEAR`] land in exact unit-width buckets; above
/// that, each power of two is split into 8 sub-buckets, bounding the
/// relative quantile error at 1/16 (6.25%) while keeping the whole
/// table at 528 counters. Bucket representatives are chosen so common
/// exact values round-trip (e.g. 100, 200 report as 100, 200).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

fn bucket_index(v: u64) -> usize {
    if v < LINEAR as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as usize; // >= 6
        let sub = ((v >> (msb - 3)) & 7) as usize;
        LINEAR + (msb - 6) * 8 + sub
    }
}

/// Midpoint of the bucket's value range (its reported quantile value).
fn representative(idx: usize) -> f64 {
    if idx < LINEAR {
        idx as f64
    } else {
        let rel = idx - LINEAR;
        let msb = rel / 8 + 6;
        let sub = rel % 8;
        let lower = ((8 + sub) as u64) << (msb - 3);
        let width = 1u64 << (msb - 3);
        (lower + width / 2) as f64
    }
}

impl Histogram {
    /// Record one observation. Negative/NaN inputs clamp to 0.
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v as u64 } else { 0 };
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max.load(Ordering::Relaxed) as f64
    }

    /// Quantile by cumulative bucket walk; `p` in [0, 100]. Empty
    /// histograms report 0. `p >= 100` reports the exact maximum.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        if p >= 100.0 {
            return self.max();
        }
        let mut rank = ((p / 100.0) * total as f64).ceil() as u64;
        rank = rank.clamp(1, total);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return representative(idx);
            }
        }
        // Racing writers can make `count` momentarily exceed the bucket
        // sums; the max is the only honest answer then.
        self.max()
    }
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub ood_flagged: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Gauge: requests admitted into a lane queue and not yet answered.
    pub in_flight: AtomicU64,
    /// Requests refused for exceeding a connection's pipeline depth.
    pub depth_rejected: AtomicU64,
    /// TCP connections admitted by the accept loop.
    pub connections: AtomicU64,
    /// Connections turned away at accept time (admission limit).
    pub conns_rejected: AtomicU64,
    /// Connections dropped because the peer stopped draining responses:
    /// the bounded output buffer overflowed, or a writability stall
    /// outlived the configured deadline. The slow-client kill switch.
    pub conns_dropped_slow: AtomicU64,
    /// Connections closed because a socket option (nonblocking mode,
    /// TCP_NODELAY) could not be applied at accept time — serving on a
    /// half-configured socket is worse than a counted, logged reject.
    pub conns_setup_failed: AtomicU64,
    /// Requests shed by per-tenant (per-model) admission control before
    /// reaching a lane queue.
    pub tenant_rejected: AtomicU64,
    /// Protocol lines rejected for exceeding the line-length cap.
    pub lines_oversized: AtomicU64,
    /// Cold plan compiles: a backend lowered the network for a batch
    /// size it had not served yet. Steady state this stops moving — every
    /// batcher bucket is served from a cached compiled plan.
    pub plan_compiles: AtomicU64,
    /// Plans evicted from a backend's bounded LRU plan cache. A moving
    /// value at steady state means the batcher's bucket-size working set
    /// exceeds the cache cap and buckets keep recompiling (cache thrash
    /// that was previously invisible).
    pub plan_cache_evictions: AtomicU64,
    latencies_us: Histogram, // end-to-end per request
    conn_depth: Histogram,   // per-connection in-flight depth at submit
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latencies_us.record(us);
    }

    /// Record the connection's in-flight depth observed when a request was
    /// admitted (the pipelining occupancy histogram).
    pub fn record_conn_depth(&self, depth: f64) {
        self.conn_depth.record(depth);
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Decrement a gauge. Wrapping subtraction: every `dec` must pair
    /// with an `inc` that happened-before it (the gauge would otherwise
    /// wrap to u64::MAX).
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Mean batch occupancy (items per executed batch).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> Json {
        let l = &self.latencies_us;
        let d = &self.conn_depth;
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("responses", Json::Num(self.responses.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("ood_flagged", Json::Num(self.ood_flagged.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            ("in_flight", Json::Num(self.in_flight.load(Ordering::Relaxed) as f64)),
            (
                "depth_rejected",
                Json::Num(self.depth_rejected.load(Ordering::Relaxed) as f64),
            ),
            ("connections", Json::Num(self.connections.load(Ordering::Relaxed) as f64)),
            (
                "conns_rejected",
                Json::Num(self.conns_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "conns_dropped_slow",
                Json::Num(self.conns_dropped_slow.load(Ordering::Relaxed) as f64),
            ),
            (
                "conns_setup_failed",
                Json::Num(self.conns_setup_failed.load(Ordering::Relaxed) as f64),
            ),
            (
                "tenant_rejected",
                Json::Num(self.tenant_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "lines_oversized",
                Json::Num(self.lines_oversized.load(Ordering::Relaxed) as f64),
            ),
            (
                "plan_compiles",
                Json::Num(self.plan_compiles.load(Ordering::Relaxed) as f64),
            ),
            (
                "plan_cache_evictions",
                Json::Num(self.plan_cache_evictions.load(Ordering::Relaxed) as f64),
            ),
            ("conn_depth_p50", Json::Num(d.percentile(50.0))),
            ("conn_depth_p95", Json::Num(d.percentile(95.0))),
            ("conn_depth_max", Json::Num(d.max())),
            ("latency_p50_us", Json::Num(l.percentile(50.0))),
            ("latency_p95_us", Json::Num(l.percentile(95.0))),
            ("latency_p99_us", Json::Num(l.percentile(99.0))),
            ("latency_p999_us", Json::Num(l.percentile(99.9))),
            ("latency_mean_us", Json::Num(l.mean())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histogram() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::inc(&m.requests);
        Metrics::add(&m.batched_items, 8);
        Metrics::inc(&m.batches);
        for us in [100.0, 200.0, 300.0] {
            m.record_latency_us(us);
        }
        let snap = m.snapshot();
        assert_eq!(snap.num_field("requests").unwrap(), 2.0);
        assert_eq!(snap.num_field("mean_batch_size").unwrap(), 8.0);
        assert_eq!(snap.num_field("latency_p50_us").unwrap(), 200.0);
    }

    #[test]
    fn gauge_and_depth_histogram() {
        let m = Metrics::new();
        Metrics::inc(&m.in_flight);
        Metrics::inc(&m.in_flight);
        Metrics::dec(&m.in_flight);
        Metrics::inc(&m.connections);
        Metrics::inc(&m.conns_rejected);
        for d in [1.0, 2.0, 4.0] {
            m.record_conn_depth(d);
        }
        let snap = m.snapshot();
        assert_eq!(snap.num_field("in_flight").unwrap(), 1.0);
        assert_eq!(snap.num_field("connections").unwrap(), 1.0);
        assert_eq!(snap.num_field("conns_rejected").unwrap(), 1.0);
        assert_eq!(snap.num_field("conn_depth_p50").unwrap(), 2.0);
        assert_eq!(snap.num_field("conn_depth_max").unwrap(), 4.0);
    }

    #[test]
    fn plan_cache_counters_surface_in_snapshot() {
        let m = Metrics::new();
        Metrics::add(&m.plan_compiles, 3);
        Metrics::add(&m.plan_cache_evictions, 2);
        let snap = m.snapshot();
        assert_eq!(snap.num_field("plan_compiles").unwrap(), 3.0);
        assert_eq!(snap.num_field("plan_cache_evictions").unwrap(), 2.0);
    }

    #[test]
    fn reactor_counters_surface_in_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.conns_dropped_slow);
        Metrics::add(&m.conns_setup_failed, 2);
        Metrics::add(&m.tenant_rejected, 3);
        Metrics::add(&m.lines_oversized, 4);
        let snap = m.snapshot();
        assert_eq!(snap.num_field("conns_dropped_slow").unwrap(), 1.0);
        assert_eq!(snap.num_field("conns_setup_failed").unwrap(), 2.0);
        assert_eq!(snap.num_field("tenant_rejected").unwrap(), 3.0);
        assert_eq!(snap.num_field("lines_oversized").unwrap(), 4.0);
    }

    #[test]
    fn histogram_small_values_are_exact() {
        let h = Histogram::default();
        for v in 0..LINEAR as u64 {
            h.record(v as f64);
        }
        // Each recorded integer < LINEAR must round-trip exactly.
        for v in 0..LINEAR as u64 {
            let p = ((v + 1) as f64 / LINEAR as f64) * 100.0;
            assert_eq!(h.percentile(p), v as f64, "p{p} of 0..{LINEAR}");
        }
        assert_eq!(h.max(), (LINEAR - 1) as f64);
    }

    #[test]
    fn histogram_large_values_bounded_relative_error() {
        let h = Histogram::default();
        let vals = [
            1_000.0,
            10_000.0,
            123_456.0,
            5_000_000.0,
            987_654_321.0,
        ];
        for &v in &vals {
            let h1 = Histogram::default();
            h1.record(v);
            let got = h1.percentile(50.0);
            let rel = (got - v).abs() / v;
            assert!(rel <= 1.0 / 16.0, "value {v} reported as {got} (rel err {rel})");
        }
        for &v in &vals {
            h.record(v);
        }
        assert_eq!(h.max(), 987_654_321.0, "max is tracked exactly");
    }

    #[test]
    fn histogram_tail_quantiles_separate() {
        let h = Histogram::default();
        // 997 fast + 2 medium + 1 catastrophically slow request.
        for _ in 0..997 {
            h.record(100.0);
        }
        h.record(10_000.0);
        h.record(10_000.0);
        h.record(1_000_000.0);
        assert_eq!(h.percentile(50.0), 100.0);
        assert_eq!(h.percentile(99.0), 100.0);
        let p999 = h.percentile(99.9);
        assert!(
            (9_000.0..=11_000.0).contains(&p999),
            "p99.9 must surface the medium outliers, got {p999}"
        );
        assert_eq!(h.percentile(100.0), 1_000_000.0);
    }

    #[test]
    fn histogram_unbounded_volume_stays_fixed_size() {
        let m = Metrics::new();
        for i in 0..120_000 {
            m.record_latency_us(i as f64);
        }
        // The histogram has no reservoir to overflow: every sample
        // counts, storage is a fixed bucket table.
        assert_eq!(m.latencies_us.count(), 120_000);
        let p50 = m.latencies_us.percentile(50.0);
        let rel = (p50 - 60_000.0).abs() / 60_000.0;
        assert!(rel <= 1.0 / 16.0, "p50 of 0..120k was {p50}");
    }

    #[test]
    fn histogram_handles_junk_input() {
        let h = Histogram::default();
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 3);
        // NaN and negatives clamp to 0; +inf clamps to 0 too (not
        // finite) rather than poisoning the max.
        assert_eq!(h.max(), 0.0);
    }
}
