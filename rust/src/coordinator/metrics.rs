//! Serving metrics: counters + latency histogram, queryable in-band via
//! `{"cmd":"metrics"}`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub ood_flagged: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Gauge: requests admitted into a lane queue and not yet answered.
    pub in_flight: AtomicU64,
    /// Requests refused for exceeding a connection's pipeline depth.
    pub depth_rejected: AtomicU64,
    /// TCP connections admitted by the accept loop.
    pub connections: AtomicU64,
    /// Connections turned away at accept time (admission limit).
    pub conns_rejected: AtomicU64,
    /// Cold plan compiles: a backend lowered the network for a batch
    /// size it had not served yet. Steady state this stops moving — every
    /// batcher bucket is served from a cached compiled plan.
    pub plan_compiles: AtomicU64,
    /// Plans evicted from a backend's bounded LRU plan cache. A moving
    /// value at steady state means the batcher's bucket-size working set
    /// exceeds the cache cap and buckets keep recompiling (cache thrash
    /// that was previously invisible).
    pub plan_cache_evictions: AtomicU64,
    latencies_us: Mutex<Vec<f64>>, // end-to-end per request
    conn_depth: Mutex<Vec<f64>>,   // per-connection in-flight depth at submit
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency_us(&self, us: f64) {
        let mut l = self.latencies_us.lock().unwrap();
        // bounded reservoir: keep the most recent 100k
        if l.len() >= 100_000 {
            l.drain(..50_000);
        }
        l.push(us);
    }

    /// Record the connection's in-flight depth observed when a request was
    /// admitted (the pipelining occupancy histogram).
    pub fn record_conn_depth(&self, depth: f64) {
        let mut d = self.conn_depth.lock().unwrap();
        if d.len() >= 100_000 {
            d.drain(..50_000);
        }
        d.push(depth);
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Decrement a gauge. Wrapping subtraction: every `dec` must pair
    /// with an `inc` that happened-before it (the gauge would otherwise
    /// wrap to u64::MAX).
    pub fn dec(counter: &AtomicU64) {
        counter.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Mean batch occupancy (items per executed batch).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> Json {
        let l = self.latencies_us.lock().unwrap();
        let d = self.conn_depth.lock().unwrap();
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("responses", Json::Num(self.responses.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::Num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("ood_flagged", Json::Num(self.ood_flagged.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            ("in_flight", Json::Num(self.in_flight.load(Ordering::Relaxed) as f64)),
            (
                "depth_rejected",
                Json::Num(self.depth_rejected.load(Ordering::Relaxed) as f64),
            ),
            ("connections", Json::Num(self.connections.load(Ordering::Relaxed) as f64)),
            (
                "conns_rejected",
                Json::Num(self.conns_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "plan_compiles",
                Json::Num(self.plan_compiles.load(Ordering::Relaxed) as f64),
            ),
            (
                "plan_cache_evictions",
                Json::Num(self.plan_cache_evictions.load(Ordering::Relaxed) as f64),
            ),
            ("conn_depth_p50", Json::Num(stats::percentile(&d, 50.0))),
            ("conn_depth_p95", Json::Num(stats::percentile(&d, 95.0))),
            ("conn_depth_max", Json::Num(stats::percentile(&d, 100.0))),
            ("latency_p50_us", Json::Num(stats::percentile(&l, 50.0))),
            ("latency_p95_us", Json::Num(stats::percentile(&l, 95.0))),
            ("latency_p99_us", Json::Num(stats::percentile(&l, 99.0))),
            ("latency_mean_us", Json::Num(stats::mean(&l))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histogram() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::inc(&m.requests);
        Metrics::add(&m.batched_items, 8);
        Metrics::inc(&m.batches);
        for us in [100.0, 200.0, 300.0] {
            m.record_latency_us(us);
        }
        let snap = m.snapshot();
        assert_eq!(snap.num_field("requests").unwrap(), 2.0);
        assert_eq!(snap.num_field("mean_batch_size").unwrap(), 8.0);
        assert_eq!(snap.num_field("latency_p50_us").unwrap(), 200.0);
    }

    #[test]
    fn gauge_and_depth_histogram() {
        let m = Metrics::new();
        Metrics::inc(&m.in_flight);
        Metrics::inc(&m.in_flight);
        Metrics::dec(&m.in_flight);
        Metrics::inc(&m.connections);
        Metrics::inc(&m.conns_rejected);
        for d in [1.0, 2.0, 4.0] {
            m.record_conn_depth(d);
        }
        let snap = m.snapshot();
        assert_eq!(snap.num_field("in_flight").unwrap(), 1.0);
        assert_eq!(snap.num_field("connections").unwrap(), 1.0);
        assert_eq!(snap.num_field("conns_rejected").unwrap(), 1.0);
        assert_eq!(snap.num_field("conn_depth_p50").unwrap(), 2.0);
        assert_eq!(snap.num_field("conn_depth_max").unwrap(), 4.0);
    }

    #[test]
    fn plan_cache_counters_surface_in_snapshot() {
        let m = Metrics::new();
        Metrics::add(&m.plan_compiles, 3);
        Metrics::add(&m.plan_cache_evictions, 2);
        let snap = m.snapshot();
        assert_eq!(snap.num_field("plan_compiles").unwrap(), 3.0);
        assert_eq!(snap.num_field("plan_cache_evictions").unwrap(), 2.0);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::new();
        for i in 0..120_000 {
            m.record_latency_us(i as f64);
        }
        // must not grow unboundedly
        assert!(m.latencies_us.lock().unwrap().len() <= 100_000);
    }
}
