//! Dependency-free readiness polling for the connection reactor.
//!
//! `mio` is not in the offline crate set, so this module declares the
//! handful of syscalls the event loop needs directly, the same way
//! `util/mmap.rs` declares `mmap`: on unix targets `std` already links
//! libc, so `extern "C"` declarations resolve without any build-time
//! dependency. Three small types are exported:
//!
//! * [`Poller`] — an epoll (Linux) / kqueue (macOS, iOS) instance.
//!   Level-triggered on both backends: a readiness bit stays set until
//!   the condition is drained, so a short read never loses data and the
//!   loop never needs edge-triggered bookkeeping.
//! * [`Events`] — a reusable, pre-sized event buffer so the steady-state
//!   [`Poller::wait`] call allocates nothing.
//! * [`Waker`] — a nonblocking self-pipe registered with the poller;
//!   any thread can [`Waker::wake`] a blocked `wait` call (used for
//!   cross-thread reply delivery, new-connection handoff, and prompt
//!   shutdown — this is what retires the old 200ms read-timeout tick).
//!
//! Other unix flavors compile but report the server as unsupported at
//! [`Poller::new`] (the FreeBSD `kevent` layout differs from Apple's;
//! gating beats silently declaring the wrong struct). Non-unix targets
//! get the same stub.

#![allow(clippy::new_without_default)]

use std::io;
use std::time::Duration;

/// One readiness notification, translated out of the OS-specific event
/// struct. Error/hangup conditions are folded into `readable` so the
/// subsequent read observes the EOF/error — the loop has no separate
/// error path to forget.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Reusable event buffer: sized once, filled by every [`Poller::wait`].
pub struct Events {
    /// Translated events, rebuilt in place each `wait`.
    list: Vec<Event>,
    /// OS-native scratch, written by the kernel.
    #[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
    raw: Vec<sys::RawEvent>,
    #[cfg(not(any(target_os = "linux", target_os = "macos", target_os = "ios")))]
    _cap: usize,
}

impl Events {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            list: Vec::with_capacity(cap),
            #[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
            raw: vec![sys::RawEvent::default(); cap],
            #[cfg(not(any(target_os = "linux", target_os = "macos", target_os = "ios")))]
            _cap: cap,
        }
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Event> {
        self.list.iter()
    }

    pub fn len(&self) -> usize {
        self.list.len()
    }

    pub fn is_empty(&self) -> bool {
        self.list.is_empty()
    }
}

impl<'a> IntoIterator for &'a Events {
    type Item = &'a Event;
    type IntoIter = std::slice::Iter<'a, Event>;
    fn into_iter(self) -> Self::IntoIter {
        self.list.iter()
    }
}

/// Clamp an optional timeout to whole milliseconds, rounding up so a
/// 100µs deadline polls after 1ms rather than spinning at 0ms.
#[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let mut ms = d.as_millis();
            if d.subsec_nanos() % 1_000_000 != 0 {
                ms += 1;
            }
            if ms > i32::MAX as u128 {
                i32::MAX
            } else {
                ms as i32
            }
        }
    }
}

const EINTR: i32 = 4;

fn last_errno() -> i32 {
    io::Error::last_os_error().raw_os_error().unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Linux: epoll
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::ffi::c_void;

    pub const EPOLL_CLOEXEC: i32 = 0x80000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const O_NONBLOCK: i32 = 0o4000;
    pub const O_CLOEXEC: i32 = 0o2000000;

    /// Kernel UAPI `struct epoll_event`: packed on x86_64 only (the
    /// 32-bit-era layout the kernel kept for compatibility); natural
    /// alignment everywhere else.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy, Default)]
    pub struct RawEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut RawEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut RawEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn pipe2(fds: *mut i32, flags: i32) -> i32;
        pub fn read(fd: i32, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: i32) -> i32;
    }
}

/// epoll-backed poller. One instance per IO thread; each fd belongs to
/// exactly one poller.
#[cfg(target_os = "linux")]
pub struct Poller {
    epfd: i32,
}

// SAFETY: the wrapped epoll fd is a kernel object; `epoll_ctl` and
// `epoll_wait` are documented thread-safe on the same epfd, and the fd
// is closed exactly once, in Drop. No interior pointers.
#[cfg(target_os = "linux")]
unsafe impl Send for Poller {}
// SAFETY: see Send — all methods take `&self` and go straight to
// thread-safe syscalls on an fd that outlives every borrow.
#[cfg(target_os = "linux")]
unsafe impl Sync for Poller {}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> io::Result<Self> {
        // SAFETY: plain FFI call with a valid flag; the result is
        // checked for the error sentinel before use.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { epfd })
    }

    fn interest_mask(readable: bool, writable: bool) -> u32 {
        // ERR/HUP are always delivered regardless of the mask; RDHUP is
        // requested explicitly so half-closed peers wake the read path.
        let mut mask = sys::EPOLLRDHUP;
        if readable {
            mask |= sys::EPOLLIN;
        }
        if writable {
            mask |= sys::EPOLLOUT;
        }
        mask
    }

    fn ctl(&self, op: i32, fd: i32, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        let mut ev = sys::RawEvent { events: Self::interest_mask(readable, writable), data: token };
        // SAFETY: `ev` is a live, properly initialized RawEvent for the
        // duration of the call; `fd` is owned by the caller. Return
        // value is checked.
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` under `token` with the given interest set.
    pub fn add(&self, fd: i32, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, readable, writable)
    }

    /// Replace the interest set of an already-registered fd.
    pub fn modify(&self, fd: i32, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, readable, writable)
    }

    /// Deregister `fd`. Must be called before the fd is closed.
    pub fn delete(&self, fd: i32) -> io::Result<()> {
        let mut ev = sys::RawEvent::default();
        // SAFETY: pre-2.6.9 kernels required a non-null event pointer
        // for EPOLL_CTL_DEL; passing a live dummy satisfies both eras.
        // Return value is checked.
        let rc = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Block until at least one event, the timeout, or a wakeup.
    /// `None` blocks indefinitely. EINTR is surfaced as an empty set.
    pub fn wait(&self, events: &mut Events, timeout: Option<Duration>) -> io::Result<()> {
        events.list.clear();
        let ms = timeout_ms(timeout);
        // SAFETY: `raw` is a live, len ≥ 1 buffer for the duration of
        // the call and the kernel writes at most `capacity` entries;
        // the return count is checked before the buffer is read.
        let n = unsafe {
            sys::epoll_wait(self.epfd, events.raw.as_mut_ptr(), events.raw.len() as i32, ms)
        };
        if n < 0 {
            if last_errno() == EINTR {
                return Ok(());
            }
            return Err(io::Error::last_os_error());
        }
        for i in 0..n as usize {
            let raw = events.raw[i];
            let bits = raw.events;
            events.list.push(Event {
                token: raw.data,
                readable: bits & (sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP)
                    != 0,
                writable: bits & (sys::EPOLLOUT | sys::EPOLLERR | sys::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: closing an fd this struct exclusively owns; nothing
        // uses it after Drop.
        unsafe { sys::close(self.epfd) };
    }
}

// ---------------------------------------------------------------------------
// macOS / iOS: kqueue
// ---------------------------------------------------------------------------

#[cfg(any(target_os = "macos", target_os = "ios"))]
mod sys {
    use std::ffi::c_void;

    pub const EVFILT_READ: i16 = -1;
    pub const EVFILT_WRITE: i16 = -2;
    pub const EV_ADD: u16 = 0x1;
    pub const EV_DELETE: u16 = 0x2;
    pub const EV_ENABLE: u16 = 0x4;
    pub const EV_DISABLE: u16 = 0x8;
    pub const EV_EOF: u16 = 0x8000;

    pub const F_SETFD: i32 = 2;
    pub const F_SETFL: i32 = 4;
    pub const FD_CLOEXEC: i32 = 1;
    pub const O_NONBLOCK: i32 = 0x4;

    /// Apple's `struct kevent` (differs from FreeBSD's — which is why
    /// other BSDs are gated off rather than guessed at).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct RawEvent {
        pub ident: usize,
        pub filter: i16,
        pub flags: u16,
        pub fflags: u32,
        pub data: isize,
        pub udata: *mut c_void,
    }

    impl Default for RawEvent {
        fn default() -> Self {
            Self {
                ident: 0,
                filter: 0,
                flags: 0,
                fflags: 0,
                data: 0,
                udata: std::ptr::null_mut(),
            }
        }
    }

    // SAFETY: `udata` is a token smuggled as a pointer-sized integer,
    // never dereferenced; RawEvent is plain data.
    unsafe impl Send for RawEvent {}
    // SAFETY: see Send.
    unsafe impl Sync for RawEvent {}

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    extern "C" {
        pub fn kqueue() -> i32;
        pub fn kevent(
            kq: i32,
            changelist: *const RawEvent,
            nchanges: i32,
            eventlist: *mut RawEvent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        pub fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        pub fn read(fd: i32, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: i32) -> i32;
    }
}

/// kqueue-backed poller. Read and write filters are registered together
/// (enabled or disabled per the interest set) so `modify` is a pure
/// enable/disable toggle.
#[cfg(any(target_os = "macos", target_os = "ios"))]
pub struct Poller {
    kq: i32,
}

// SAFETY: the wrapped kqueue fd is a kernel object; `kevent` is
// thread-safe on the same kq, and the fd is closed exactly once, in
// Drop. No interior pointers.
#[cfg(any(target_os = "macos", target_os = "ios"))]
unsafe impl Send for Poller {}
// SAFETY: see Send — all methods take `&self` and go straight to
// thread-safe syscalls on an fd that outlives every borrow.
#[cfg(any(target_os = "macos", target_os = "ios"))]
unsafe impl Sync for Poller {}

#[cfg(any(target_os = "macos", target_os = "ios"))]
impl Poller {
    pub fn new() -> io::Result<Self> {
        // SAFETY: plain FFI call, result checked before use.
        let kq = unsafe { sys::kqueue() };
        if kq < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { kq })
    }

    fn submit(&self, changes: &[sys::RawEvent]) -> io::Result<()> {
        // SAFETY: `changes` is a live slice for the duration of the
        // call; no eventlist is passed (nevents = 0). Return checked.
        let rc = unsafe {
            sys::kevent(
                self.kq,
                changes.as_ptr(),
                changes.len() as i32,
                std::ptr::null_mut(),
                0,
                std::ptr::null(),
            )
        };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn interest(fd: i32, token: u64, readable: bool, writable: bool) -> [sys::RawEvent; 2] {
        let ev = |filter: i16, on: bool| sys::RawEvent {
            ident: fd as usize,
            filter,
            flags: sys::EV_ADD | if on { sys::EV_ENABLE } else { sys::EV_DISABLE },
            fflags: 0,
            data: 0,
            udata: token as *mut std::ffi::c_void,
        };
        [ev(sys::EVFILT_READ, readable), ev(sys::EVFILT_WRITE, writable)]
    }

    pub fn add(&self, fd: i32, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.submit(&Self::interest(fd, token, readable, writable))
    }

    pub fn modify(&self, fd: i32, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.submit(&Self::interest(fd, token, readable, writable))
    }

    pub fn delete(&self, fd: i32) -> io::Result<()> {
        let mk = |filter: i16| sys::RawEvent {
            ident: fd as usize,
            filter,
            flags: sys::EV_DELETE,
            fflags: 0,
            data: 0,
            udata: std::ptr::null_mut(),
        };
        // A filter that was never activated reports ENOENT on delete;
        // deregistering per-filter and ignoring errors keeps `delete`
        // idempotent like the epoll path.
        let _ = self.submit(&[mk(sys::EVFILT_READ)]);
        let _ = self.submit(&[mk(sys::EVFILT_WRITE)]);
        Ok(())
    }

    pub fn wait(&self, events: &mut Events, timeout: Option<Duration>) -> io::Result<()> {
        events.list.clear();
        let ts;
        let ts_ptr = match timeout {
            None => std::ptr::null(),
            Some(d) => {
                ts = sys::Timespec {
                    tv_sec: d.as_secs() as i64,
                    tv_nsec: d.subsec_nanos() as i64,
                };
                &ts as *const sys::Timespec
            }
        };
        // SAFETY: `raw` is a live, len ≥ 1 buffer for the duration of
        // the call and the kernel writes at most `nevents` entries; the
        // return count is checked before the buffer is read. `ts`
        // outlives the call when non-null.
        let n = unsafe {
            sys::kevent(
                self.kq,
                std::ptr::null(),
                0,
                events.raw.as_mut_ptr(),
                events.raw.len() as i32,
                ts_ptr,
            )
        };
        if n < 0 {
            if last_errno() == EINTR {
                return Ok(());
            }
            return Err(io::Error::last_os_error());
        }
        for i in 0..n as usize {
            let raw = events.raw[i];
            let eof = raw.flags & sys::EV_EOF != 0;
            events.list.push(Event {
                token: raw.udata as u64,
                readable: raw.filter == sys::EVFILT_READ || eof,
                writable: raw.filter == sys::EVFILT_WRITE,
            });
        }
        Ok(())
    }
}

#[cfg(any(target_os = "macos", target_os = "ios"))]
impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: closing an fd this struct exclusively owns; nothing
        // uses it after Drop.
        unsafe { sys::close(self.kq) };
    }
}

// ---------------------------------------------------------------------------
// Waker: nonblocking self-pipe (both supported platforms)
// ---------------------------------------------------------------------------

/// Cross-thread wakeup for a blocked [`Poller::wait`].
///
/// The read end is registered with the poller (level-triggered: a
/// buffered byte keeps the poller hot until drained, so a wake posted
/// between `wait` calls is never lost); any thread writes to the write
/// end to interrupt the wait. Both ends are nonblocking — a full pipe
/// just means a wakeup is already pending, which is exactly the
/// semantic `wake` wants.
#[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
pub struct Waker {
    read_fd: i32,
    write_fd: i32,
}

// SAFETY: the two pipe fds are kernel objects; `read`/`write` on
// distinct (or even the same) fds are thread-safe, and each fd is
// closed exactly once, in Drop.
#[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
unsafe impl Send for Waker {}
// SAFETY: see Send — `wake`/`drain` take `&self` and are single
// syscalls on fds that outlive every borrow.
#[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
unsafe impl Sync for Waker {}

#[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
impl Waker {
    #[cfg(target_os = "linux")]
    pub fn new() -> io::Result<Self> {
        let mut fds = [-1i32; 2];
        // SAFETY: `fds` is a live 2-slot buffer; pipe2 fills both on
        // success. Return value is checked.
        let rc = unsafe { sys::pipe2(fds.as_mut_ptr(), sys::O_NONBLOCK | sys::O_CLOEXEC) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { read_fd: fds[0], write_fd: fds[1] })
    }

    #[cfg(any(target_os = "macos", target_os = "ios"))]
    pub fn new() -> io::Result<Self> {
        let mut fds = [-1i32; 2];
        // SAFETY: `fds` is a live 2-slot buffer; pipe fills both on
        // success. Return value is checked.
        let rc = unsafe { sys::pipe(fds.as_mut_ptr()) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        for fd in fds {
            // SAFETY: plain fcntl on fds we just created; macOS has no
            // pipe2, so nonblocking/cloexec are set after the fact (the
            // momentary race with exec is acceptable for a server that
            // never forks). Return values are checked.
            let rc1 = unsafe { sys::fcntl(fd, sys::F_SETFL, sys::O_NONBLOCK) };
            // SAFETY: as above.
            let rc2 = unsafe { sys::fcntl(fd, sys::F_SETFD, sys::FD_CLOEXEC) };
            if rc1 < 0 || rc2 < 0 {
                let err = io::Error::last_os_error();
                // SAFETY: closing fds this constructor exclusively
                // owns; they escape to no one on the error path.
                unsafe {
                    sys::close(fds[0]);
                    sys::close(fds[1]);
                }
                return Err(err);
            }
        }
        Ok(Self { read_fd: fds[0], write_fd: fds[1] })
    }

    /// The fd to register (readable) with the owning poller.
    pub fn read_fd(&self) -> i32 {
        self.read_fd
    }

    /// Interrupt the owning poller's `wait`. Callable from any thread;
    /// never blocks. A full pipe (EAGAIN) means a wakeup is already
    /// pending, so the error is deliberately ignored.
    pub fn wake(&self) {
        let byte = [1u8];
        // SAFETY: 1-byte write from a live stack buffer to a
        // nonblocking fd; the result needs no check (see doc above).
        unsafe { sys::write(self.write_fd, byte.as_ptr() as *const std::ffi::c_void, 1) };
    }

    /// Drain pending wakeup bytes after the poller reported the read
    /// end readable. Level-triggered pollers re-fire until this runs.
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: read into a live stack buffer of the stated
            // length on a nonblocking fd; the return value terminates
            // the loop on EAGAIN (-1), EOF (0), or a short read.
            let n = unsafe {
                sys::read(self.read_fd, buf.as_mut_ptr() as *mut std::ffi::c_void, buf.len())
            };
            if n < buf.len() as isize {
                break;
            }
        }
    }
}

#[cfg(any(target_os = "linux", target_os = "macos", target_os = "ios"))]
impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: closing the two fds this struct exclusively owns;
        // nothing uses them after Drop.
        unsafe {
            sys::close(self.read_fd);
            sys::close(self.write_fd);
        }
    }
}

// ---------------------------------------------------------------------------
// Unsupported platforms: compile, but refuse to start
// ---------------------------------------------------------------------------

#[cfg(not(any(target_os = "linux", target_os = "macos", target_os = "ios")))]
pub struct Poller {}

#[cfg(not(any(target_os = "linux", target_os = "macos", target_os = "ios")))]
impl Poller {
    pub fn new() -> io::Result<Self> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "connection reactor requires epoll (Linux) or kqueue (macOS)",
        ))
    }
    pub fn add(&self, _fd: i32, _token: u64, _r: bool, _w: bool) -> io::Result<()> {
        unreachable!("Poller::new never succeeds on this platform")
    }
    pub fn modify(&self, _fd: i32, _token: u64, _r: bool, _w: bool) -> io::Result<()> {
        unreachable!("Poller::new never succeeds on this platform")
    }
    pub fn delete(&self, _fd: i32) -> io::Result<()> {
        unreachable!("Poller::new never succeeds on this platform")
    }
    pub fn wait(&self, _events: &mut Events, _timeout: Option<Duration>) -> io::Result<()> {
        unreachable!("Poller::new never succeeds on this platform")
    }
}

#[cfg(not(any(target_os = "linux", target_os = "macos", target_os = "ios")))]
pub struct Waker {}

#[cfg(not(any(target_os = "linux", target_os = "macos", target_os = "ios")))]
impl Waker {
    pub fn new() -> io::Result<Self> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "connection reactor requires epoll (Linux) or kqueue (macOS)",
        ))
    }
    pub fn read_fd(&self) -> i32 {
        -1
    }
    pub fn wake(&self) {}
    pub fn drain(&self) {}
}

#[cfg(all(test, any(target_os = "linux", target_os = "macos", target_os = "ios")))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn tcp_data_reports_readable() {
        let poller = Poller::new().unwrap();
        let (mut client, server) = pair();
        poller.add(server.as_raw_fd(), 7, true, false).unwrap();
        client.write_all(b"hello").unwrap();
        let mut events = Events::with_capacity(8);
        // A couple of retries tolerate scheduler lag on loopback.
        let mut seen = false;
        for _ in 0..50 {
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                seen = true;
                break;
            }
        }
        assert!(seen, "pending TCP data must surface as a readable event");
    }

    #[test]
    fn fresh_stream_reports_writable() {
        let poller = Poller::new().unwrap();
        let (client, _server) = pair();
        poller.add(client.as_raw_fd(), 3, false, true).unwrap();
        let mut events = Events::with_capacity(8);
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert!(
            events.iter().any(|e| e.token == 3 && e.writable),
            "an empty socket buffer must surface as writable"
        );
    }

    #[test]
    fn modify_disables_and_reenables_read_interest() {
        let poller = Poller::new().unwrap();
        let (mut client, server) = pair();
        poller.add(server.as_raw_fd(), 9, true, false).unwrap();
        client.write_all(b"x").unwrap();
        let mut events = Events::with_capacity(8);
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.readable));
        // Interest off: pending data must no longer wake the poller.
        poller.modify(server.as_raw_fd(), 9, false, false).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(
            !events.iter().any(|e| e.token == 9 && e.readable),
            "read interest was disabled"
        );
        // Interest back on: the still-buffered byte re-fires (level-triggered).
        poller.modify(server.as_raw_fd(), 9, true, false).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.readable));
    }

    #[test]
    fn waker_interrupts_blocking_wait() {
        let poller = Poller::new().unwrap();
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        poller.add(waker.read_fd(), 0, true, false).unwrap();
        let w2 = std::sync::Arc::clone(&waker);
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            w2.wake();
        });
        let mut events = Events::with_capacity(8);
        // No timeout: only the waker can unblock this.
        poller.wait(&mut events, None).unwrap();
        assert!(events.iter().any(|e| e.token == 0 && e.readable));
        waker.drain();
        // Drained: the next bounded wait must be quiet.
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(!events.iter().any(|e| e.token == 0));
        handle.join().unwrap();
    }

    #[test]
    fn wakes_are_coalesced_not_lost() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.add(waker.read_fd(), 0, true, false).unwrap();
        // Many wakes before any drain: the pipe coalesces them (and
        // EAGAIN on a full pipe is fine by contract).
        for _ in 0..1000 {
            waker.wake();
        }
        let mut events = Events::with_capacity(8);
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert!(events.iter().any(|e| e.token == 0 && e.readable));
        waker.drain();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(!events.iter().any(|e| e.token == 0), "drain must clear every pending byte");
    }

    #[test]
    fn idle_wait_times_out() {
        let poller = Poller::new().unwrap();
        let (_client, server) = pair();
        poller.add(server.as_raw_fd(), 1, true, false).unwrap();
        let mut events = Events::with_capacity(8);
        let t0 = Instant::now();
        poller.wait(&mut events, Some(Duration::from_millis(120))).unwrap();
        assert!(events.is_empty(), "no data was sent");
        assert!(
            t0.elapsed() >= Duration::from_millis(80),
            "wait returned after only {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn delete_stops_event_delivery() {
        let poller = Poller::new().unwrap();
        let (mut client, server) = pair();
        poller.add(server.as_raw_fd(), 5, true, false).unwrap();
        client.write_all(b"x").unwrap();
        let mut events = Events::with_capacity(8);
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert!(events.iter().any(|e| e.token == 5));
        poller.delete(server.as_raw_fd()).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(!events.iter().any(|e| e.token == 5), "deleted fd must go quiet");
    }
}
