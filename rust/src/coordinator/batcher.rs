//! Dynamic batcher: coalesce single-image requests into mini-batches.
//!
//! The paper tunes PFP per mini-batch size and shows (Fig. 7) that PFP
//! latency is nearly batch-size independent while SVI scales terribly at
//! small batches — dynamic batching is how a server exploits that: wait at
//! most `max_wait` for up to `max_batch` requests, then run one forward
//! pass for the whole group.
//!
//! Registry lanes pin a model *version* per request (the `Arc` captured at
//! submit time). One mini-batch runs one forward pass on one executor, so
//! a batch must never mix versions: [`Batcher::next_batch`] drains only
//! the longest version-contiguous prefix of the queue. Around a hot swap
//! this splits the stream exactly at the cutover point — old-version
//! requests batch together and finish on the old executor, new-version
//! requests batch behind them.
//!
//! Backpressure: the queue is bounded (`capacity`); when full, requests
//! are rejected immediately (the caller sees an error response rather than
//! unbounded latency).

use std::collections::VecDeque;
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::protocol::ProtoVersion;
use crate::coordinator::server::Reply;
use crate::registry::ModelVersion;

/// A queued unit of work: one request row + its response sink.
pub struct WorkItem {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Where the response goes: an mpsc channel (in-process callers) or
    /// a reactor connection's bounded output buffer (TCP callers).
    pub reply: Reply,
    /// Protocol generation the request arrived under (its response is
    /// serialized in kind).
    pub proto: ProtoVersion,
    /// Registry lanes: the model version pinned at submit time. `None` on
    /// legacy `register()`ed lanes.
    pub model: Option<Arc<ModelVersion>>,
    /// The owning lane's in-flight gauge (per-tenant admission control);
    /// decremented by whoever delivers this item's response. `None` when
    /// the submit path predates the lane gauge (tests).
    pub lane_inflight: Option<Arc<AtomicUsize>>,
}

impl WorkItem {
    /// Whether two items may share a mini-batch (same pinned version, by
    /// identity — one `Arc` per published version).
    pub fn same_version(&self, other: &WorkItem) -> bool {
        match (&self.model, &other.model) {
            (None, None) => true,
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 10,
            max_wait: Duration::from_millis(2),
            capacity: 1024,
        }
    }
}

struct Inner {
    queue: VecDeque<WorkItem>,
    closed: bool,
}

impl Inner {
    /// Longest batchable prefix: capped by `max` and by the first version
    /// boundary (items behind a boundary can never join this batch, so
    /// waiting for more arrivals cannot grow the prefix past it).
    fn contiguous_prefix(&self, max: usize) -> usize {
        let Some(first) = self.queue.front() else {
            return 0;
        };
        let cap = max.min(self.queue.len());
        let mut n = 1;
        while n < cap && self.queue[n].same_version(first) {
            n += 1;
        }
        n
    }
}

/// Bounded, condvar-signalled batching queue.
pub struct Batcher {
    cfg: BatcherConfig,
    inner: Mutex<Inner>,
    signal: Condvar,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            signal: Condvar::new(),
        }
    }

    pub fn config(&self) -> BatcherConfig {
        self.cfg
    }

    /// Enqueue; `Err(item)` = queue full (backpressure) or closed.
    pub fn push(&self, item: WorkItem) -> Result<(), WorkItem> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.queue.len() >= self.cfg.capacity {
            return Err(item);
        }
        inner.queue.push_back(item);
        drop(inner);
        self.signal.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slots left before `push` starts rejecting (0 = saturated).
    pub fn remaining_capacity(&self) -> usize {
        self.cfg.capacity.saturating_sub(self.len())
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Blocking collect of the next batch: waits for the first item, then
    /// up to `max_wait` (since the first arrival) for more, capped at
    /// `max_batch` *and at the first model-version boundary* (a batch is
    /// one forward pass on one executor). Returns `None` when closed and
    /// drained.
    pub fn next_batch(&self) -> Option<Vec<WorkItem>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.queue.is_empty() {
                break;
            }
            if inner.closed {
                return None;
            }
            inner = self.signal.wait(inner).unwrap();
        }
        // first arrival defines the deadline
        let deadline = inner.queue.front().unwrap().enqueued + self.cfg.max_wait;
        loop {
            let prefix = inner.contiguous_prefix(self.cfg.max_batch);
            if prefix >= self.cfg.max_batch || inner.closed {
                break;
            }
            if prefix < inner.queue.len() {
                // capped by a version boundary: later arrivals can never
                // extend this batch, flush it now
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (next, timeout) = self
                .signal
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = next;
            if timeout.timed_out() {
                break;
            }
        }
        let take = inner.contiguous_prefix(self.cfg.max_batch);
        Some(inner.queue.drain(..take).collect())
    }

    /// Close the queue; wakes all waiters. Remaining items are still
    /// drained by `next_batch` until empty.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.signal.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Response;
    use crate::registry;
    use std::sync::mpsc::channel;

    fn item(id: u64) -> (WorkItem, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            WorkItem {
                id,
                input: vec![0.0; 4],
                enqueued: Instant::now(),
                reply: Reply::Channel(tx),
                proto: ProtoVersion::V0,
                model: None,
                lane_inflight: None,
            },
            rx,
        )
    }

    fn versioned_item(
        id: u64,
        model: &Arc<ModelVersion>,
    ) -> (WorkItem, std::sync::mpsc::Receiver<Response>) {
        let (mut it, rx) = item(id);
        it.model = Some(Arc::clone(model));
        (it, rx)
    }

    #[test]
    fn batches_up_to_max() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(20),
            capacity: 16,
        });
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (it, rx) = item(i);
            b.push(it).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn pipelined_burst_coalesces_into_one_batch() {
        // the pipelining contract: a consumer already waiting when a full
        // max_batch burst lands (one connection's in-flight window) must
        // hand the whole burst to the backend as a single batch
        let b = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(300),
            capacity: 64,
        }));
        let b2 = b.clone();
        let consumer = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20)); // consumer is waiting
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (it, rx) = item(i);
            b.push(it).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let batch = consumer.join().unwrap().unwrap();
        assert_eq!(batch.len(), 8, "burst must coalesce into one batch");
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            capacity: 16,
        }));
        let (it, _rx) = item(1);
        b.push(it).map_err(|_| ()).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn version_boundary_splits_batches() {
        // a hot swap mid-queue: the batch must cut exactly at the version
        // boundary so each forward pass runs on one executor
        let v1 = registry::synthetic_version("m", 1);
        let v2 = registry::synthetic_version("m", 2);
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            capacity: 16,
        });
        let mut rxs = Vec::new();
        for (id, mv) in [(0, &v1), (1, &v1), (2, &v2), (3, &v2), (4, &v2)] {
            let (it, rx) = versioned_item(id, mv);
            b.push(it).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        let first = b.next_batch().unwrap();
        assert_eq!(first.len(), 2, "v1 prefix only");
        assert!(first.iter().all(|it| it.same_version(&first[0])));
        assert_eq!(first[0].model.as_ref().unwrap().version, 1);
        let second = b.next_batch().unwrap();
        assert_eq!(second.len(), 3, "v2 run batches together");
        assert_eq!(second[0].model.as_ref().unwrap().version, 2);
    }

    #[test]
    fn boundary_flushes_without_waiting_for_deadline() {
        // a boundary caps the prefix: the batch flushes immediately even
        // though max_wait is far away and max_batch is not reached
        let v1 = registry::synthetic_version("m", 1);
        let v2 = registry::synthetic_version("m", 2);
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(5),
            capacity: 16,
        });
        let (i1, _r1) = versioned_item(0, &v1);
        let (i2, _r2) = versioned_item(1, &v2);
        b.push(i1).map_err(|_| ()).unwrap();
        b.push(i2).map_err(|_| ()).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_secs(1),
            "boundary must flush early, waited {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn legacy_and_versioned_items_never_mix() {
        let v1 = registry::synthetic_version("m", 1);
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            capacity: 16,
        });
        let (i1, _r1) = item(0);
        let (i2, _r2) = versioned_item(1, &v1);
        b.push(i1).map_err(|_| ()).unwrap();
        b.push(i2).map_err(|_| ()).unwrap();
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 2,
        });
        assert_eq!(b.remaining_capacity(), 2);
        let (i1, _r1) = item(1);
        let (i2, _r2) = item(2);
        let (i3, _r3) = item(3);
        assert!(b.push(i1).is_ok());
        assert!(b.push(i2).is_ok());
        assert_eq!(b.remaining_capacity(), 0);
        assert!(b.push(i3).is_err());
        assert!(!b.is_closed());
        b.close();
        assert!(b.is_closed());
    }

    #[test]
    fn close_unblocks_consumer() {
        let b = Arc::new(Batcher::new(BatcherConfig::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn drains_after_close() {
        let b = Batcher::new(BatcherConfig::default());
        let (it, _rx) = item(7);
        b.push(it).map_err(|_| ()).unwrap();
        b.close();
        // queued item still delivered
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
        // no new pushes accepted
        let (it2, _rx2) = item(8);
        assert!(b.push(it2).is_err());
    }
}
