//! Incremental line codec for the connection reactor.
//!
//! The reactor reads whatever bytes the kernel has ready into a fixed
//! scratch buffer and hands them to a per-connection [`LineCodec`]; the
//! codec accumulates partial lines across reads and yields complete
//! newline-terminated frames without re-scanning bytes it has already
//! seen. This is the codec half of the codec/engine split: framing
//! lives here, protocol semantics stay in `server.rs`/`protocol.rs`,
//! and the event loop itself never parses JSON.
//!
//! Design points:
//!
//! * **High-water scanning.** `scan` remembers how far the newline
//!   search has progressed, so a line delivered one byte per read costs
//!   O(len) total, not O(len²).
//! * **Amortized compaction.** Consumed bytes are dropped from the
//!   front of the buffer only once `COMPACT_AT` bytes have accumulated
//!   (or the buffer is fully consumed), keeping the per-line memmove
//!   cost amortized O(1).
//! * **Bounded lines.** A line longer than `max_line` flips the codec
//!   into *discard* mode: the oversized bytes are dropped (not
//!   buffered), and the next newline yields [`Line::Oversized`] so the
//!   caller can send an error and keep the connection alive. A hostile
//!   client can therefore never grow the buffer past
//!   `max_line + read-chunk` bytes.

/// Compact the buffer once this many consumed bytes sit at the front.
const COMPACT_AT: usize = 4096;

/// One decoded frame, borrowed from the codec's internal buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum Line<'a> {
    /// A complete line (without the trailing `\n`; a trailing `\r` is
    /// preserved — callers trim whitespace before parsing).
    Full(&'a [u8]),
    /// A line exceeded the configured maximum and was dropped. `len` is
    /// the number of payload bytes discarded (newline excluded).
    Oversized { len: usize },
}

/// Incremental, allocation-conscious line splitter.
pub struct LineCodec {
    buf: Vec<u8>,
    /// Start of unconsumed bytes in `buf`.
    start: usize,
    /// High-water mark of the newline scan (absolute index into `buf`).
    scan: usize,
    /// Maximum accepted payload length of a single line.
    max_line: usize,
    /// True while dropping bytes of an oversized line, until `\n`.
    discarding: bool,
    /// Bytes dropped so far for the current oversized line.
    dropped: usize,
}

impl LineCodec {
    pub fn new(max_line: usize) -> Self {
        Self {
            buf: Vec::new(),
            start: 0,
            scan: 0,
            max_line: max_line.max(1),
            discarding: false,
            dropped: 0,
        }
    }

    /// Number of buffered, not-yet-consumed bytes (for tests/metrics).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Append freshly read bytes. Amortized compaction happens here so
    /// the hot `next_line` path never memmoves.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.start > 0 && (self.start >= COMPACT_AT || self.start == self.buf.len()) {
            self.buf.drain(..self.start);
            self.scan -= self.start;
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, if one is buffered.
    ///
    /// Returns `None` when more bytes are needed; call again after the
    /// next `push`. Callers must loop until `None` — one `push` can
    /// complete several pipelined lines.
    pub fn next_line(&mut self) -> Option<Line<'_>> {
        if self.discarding {
            match find_nl(&self.buf, self.start) {
                Some(pos) => {
                    let total = self.dropped + (pos - self.start);
                    self.start = pos + 1;
                    self.scan = self.start;
                    self.discarding = false;
                    self.dropped = 0;
                    return Some(Line::Oversized { len: total });
                }
                None => {
                    // No terminator yet: drop everything buffered and
                    // keep waiting. The buffer never grows while a
                    // line is being discarded.
                    self.dropped += self.buf.len() - self.start;
                    self.buf.clear();
                    self.start = 0;
                    self.scan = 0;
                    return None;
                }
            }
        }
        match find_nl(&self.buf, self.scan) {
            Some(pos) => {
                let s = self.start;
                let len = pos - s;
                self.start = pos + 1;
                self.scan = pos + 1;
                if len > self.max_line {
                    Some(Line::Oversized { len })
                } else {
                    Some(Line::Full(&self.buf[s..pos]))
                }
            }
            None => {
                self.scan = self.buf.len();
                if self.buf.len() - self.start > self.max_line {
                    // Oversized with no newline in sight: switch to
                    // discard mode so memory stays bounded.
                    self.dropped = self.buf.len() - self.start;
                    self.discarding = true;
                    self.buf.clear();
                    self.start = 0;
                    self.scan = 0;
                }
                None
            }
        }
    }
}

/// Position of the next `\n` at or after `from` (absolute index).
#[inline]
fn find_nl(buf: &[u8], from: usize) -> Option<usize> {
    buf[from..].iter().position(|&b| b == b'\n').map(|i| from + i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full(c: &mut LineCodec) -> Option<Vec<u8>> {
        match c.next_line() {
            Some(Line::Full(b)) => Some(b.to_vec()),
            Some(Line::Oversized { .. }) => panic!("unexpected oversized"),
            None => None,
        }
    }

    #[test]
    fn whole_line_in_one_push() {
        let mut c = LineCodec::new(1024);
        c.push(b"{\"cmd\":\"ping\"}\n");
        assert_eq!(full(&mut c).unwrap(), b"{\"cmd\":\"ping\"}");
        assert!(c.next_line().is_none());
    }

    #[test]
    fn partial_line_split_across_reads() {
        let mut c = LineCodec::new(1024);
        c.push(b"{\"id\":1,\"in");
        assert!(c.next_line().is_none());
        c.push(b"put\":[1.0]}");
        assert!(c.next_line().is_none());
        c.push(b"\n");
        assert_eq!(full(&mut c).unwrap(), b"{\"id\":1,\"input\":[1.0]}");
    }

    #[test]
    fn byte_at_a_time_still_decodes() {
        let mut c = LineCodec::new(64);
        let msg = b"{\"id\":42}\n{\"id\":43}\n";
        let mut got = Vec::new();
        for &b in msg.iter() {
            c.push(&[b]);
            while let Some(l) = c.next_line() {
                match l {
                    Line::Full(f) => got.push(f.to_vec()),
                    Line::Oversized { .. } => panic!("oversized"),
                }
            }
        }
        assert_eq!(got, vec![b"{\"id\":42}".to_vec(), b"{\"id\":43}".to_vec()]);
    }

    #[test]
    fn multiple_pipelined_lines_one_push() {
        let mut c = LineCodec::new(1024);
        c.push(b"a\nbb\nccc\n");
        assert_eq!(full(&mut c).unwrap(), b"a");
        assert_eq!(full(&mut c).unwrap(), b"bb");
        assert_eq!(full(&mut c).unwrap(), b"ccc");
        assert!(c.next_line().is_none());
    }

    #[test]
    fn crlf_and_empty_lines_pass_through() {
        let mut c = LineCodec::new(1024);
        c.push(b"ping\r\n\nlast\n");
        assert_eq!(full(&mut c).unwrap(), b"ping\r");
        assert_eq!(full(&mut c).unwrap(), b"");
        assert_eq!(full(&mut c).unwrap(), b"last");
    }

    #[test]
    fn oversized_line_with_newline_is_rejected() {
        let mut c = LineCodec::new(4);
        c.push(b"abcdefgh\nok\n");
        assert_eq!(c.next_line(), Some(Line::Oversized { len: 8 }));
        assert_eq!(full(&mut c).unwrap(), b"ok");
    }

    #[test]
    fn oversized_line_without_newline_bounds_memory_then_recovers() {
        let mut c = LineCodec::new(8);
        c.push(b"0123456789abcdef"); // 16 bytes, no newline
        assert!(c.next_line().is_none());
        assert_eq!(c.buffered(), 0, "oversized bytes must be dropped, not buffered");
        c.push(b"ghij"); // still the same monster line
        assert!(c.next_line().is_none());
        c.push(b"\n{\"ok\":1}\n");
        assert_eq!(c.next_line(), Some(Line::Oversized { len: 20 }));
        assert_eq!(full(&mut c).unwrap(), b"{\"ok\":1}");
    }

    #[test]
    fn exactly_max_line_is_accepted() {
        let mut c = LineCodec::new(4);
        c.push(b"abcd\n");
        assert_eq!(full(&mut c).unwrap(), b"abcd");
    }

    #[test]
    fn compaction_preserves_pending_partial_line() {
        let mut c = LineCodec::new(16 * 1024);
        // Consume enough full lines to cross the compaction threshold,
        // then make sure a partial line straddling the compaction still
        // decodes correctly.
        let line = [b'x'; 512];
        for _ in 0..12 {
            c.push(&line);
            c.push(b"\n");
            assert_eq!(full(&mut c).unwrap().len(), 512);
        }
        c.push(b"tail-before");
        c.push(b"-compact\n");
        assert_eq!(full(&mut c).unwrap(), b"tail-before-compact");
        assert!(c.buffered() == 0);
    }
}
