//! Rust mirror of `python/compile/data.py` — the synthetic Dirty-MNIST
//! generator, draw-for-draw identical (same SplitMix64 streams, same
//! formulas; floating-point transcendentals may differ in the last ulp,
//! so cross-language tests compare with 1e-5 tolerance).

use crate::tensor::Tensor;
use crate::util::rng::{derive_seed, SplitMix64};

use super::Split;

pub const H: usize = 28;
pub const W: usize = 28;
pub const IMG: usize = H * W;
pub const NUM_CLASSES: usize = 10;
pub const NOISE_STD: f64 = 0.08;
pub const MAX_SHIFT: i64 = 2;

/// Stream ids — must match data.py's STREAM_* constants.
#[derive(Clone, Copy, Debug)]
pub enum Stream {
    IndomainTrain = 1,
    AmbiguousTrain = 2,
    IndomainTest = 3,
    AmbiguousTest = 4,
    OodTest = 5,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Indomain,
    Ambiguous,
    Ood,
}

/// Deterministic class prototype (mirror of `data.class_prototype`).
pub fn class_prototype(c: usize) -> Vec<f32> {
    let fx = 1.0 + (c % 3) as f64;
    let fy = 1.0 + (c / 3) as f64;
    let phase = 0.7 * c as f64;
    let mut img = vec![0.0f32; IMG];
    for i in 0..H {
        for j in 0..W {
            let u = i as f64 / (H - 1) as f64;
            let v = j as f64 / (W - 1) as f64;
            let env = (-((u - 0.5).powi(2) + (v - 0.5).powi(2)) * 4.0).exp();
            let s = (2.0 * std::f64::consts::PI * (fx * u + fy * v) + phase).sin();
            let t = (2.0 * std::f64::consts::PI * (fy * u - fx * v) - phase).cos();
            img[i * W + j] = (env * (0.5 + 0.25 * s + 0.25 * t)) as f32;
        }
    }
    img
}

/// The synthetic Dirty-MNIST generator.
pub struct Generator {
    base_seed: u64,
    protos: Vec<Vec<f32>>,
}

impl Generator {
    pub fn new(base_seed: u64) -> Self {
        Self {
            base_seed,
            protos: (0..NUM_CLASSES).map(class_prototype).collect(),
        }
    }

    fn shift(img: &[f32], dy: i64, dx: i64) -> Vec<f32> {
        let mut out = vec![0.0f32; IMG];
        for i in 0..H as i64 {
            for j in 0..W as i64 {
                let si = i - dy;
                let sj = j - dx;
                if (0..H as i64).contains(&si) && (0..W as i64).contains(&sj) {
                    out[(i * W as i64 + j) as usize] = img[(si * W as i64 + sj) as usize];
                }
            }
        }
        out
    }

    fn add_noise(img: &mut [f32], rng: &mut SplitMix64, std: f64) {
        for v in img.iter_mut() {
            let noisy = *v as f64 + std * rng.normal();
            *v = (noisy as f32).clamp(0.0, 1.0);
        }
    }

    /// In-domain sample (mirror of `data.sample_indomain`).
    pub fn sample_indomain(&self, seed: u64) -> (Vec<f32>, i32) {
        let mut rng = SplitMix64::new(seed);
        let c = rng.randint(NUM_CLASSES as u64) as usize;
        let dy = rng.randint(2 * MAX_SHIFT as u64 + 1) as i64 - MAX_SHIFT;
        let dx = rng.randint(2 * MAX_SHIFT as u64 + 1) as i64 - MAX_SHIFT;
        let mut img = Self::shift(&self.protos[c], dy, dx);
        Self::add_noise(&mut img, &mut rng, NOISE_STD);
        (img, c as i32)
    }

    /// Ambiguous between-class blend (mirror of `data.sample_ambiguous`).
    pub fn sample_ambiguous(&self, seed: u64) -> (Vec<f32>, i32) {
        let mut rng = SplitMix64::new(seed);
        let a = rng.randint(NUM_CLASSES as u64) as usize;
        let b = (a + 1 + rng.randint(NUM_CLASSES as u64 - 1) as usize) % NUM_CLASSES;
        let lam = (0.35 + 0.30 * rng.uniform()) as f32;
        let dy = rng.randint(2 * MAX_SHIFT as u64 + 1) as i64 - MAX_SHIFT;
        let dx = rng.randint(2 * MAX_SHIFT as u64 + 1) as i64 - MAX_SHIFT;
        let blend: Vec<f32> = self.protos[a]
            .iter()
            .zip(&self.protos[b])
            .map(|(&pa, &pb)| lam * pa + (1.0 - lam) * pb)
            .collect();
        let mut img = Self::shift(&blend, dy, dx);
        Self::add_noise(&mut img, &mut rng, NOISE_STD);
        (img, a as i32)
    }

    /// OOD texture sample (mirror of `data.sample_ood`).
    pub fn sample_ood(&self, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        let kind = rng.randint(3);
        let mut img = vec![0.0f32; IMG];
        match kind {
            0 => {
                let p = 2 + rng.randint(3) as usize;
                let hi = (0.5 + 0.5 * rng.uniform()) as f32;
                let lo = (0.2 * rng.uniform()) as f32;
                for i in 0..H {
                    for j in 0..W {
                        img[i * W + j] = if ((i / p) + (j / p)) % 2 == 0 { hi } else { lo };
                    }
                }
            }
            1 => {
                let n_rect = 3 + rng.randint(4);
                for _ in 0..n_rect {
                    let y0 = rng.randint((H - 4) as u64) as usize;
                    let x0 = rng.randint((W - 4) as u64) as usize;
                    let h = 3 + rng.randint(10) as usize;
                    let w = 3 + rng.randint(10) as usize;
                    let val = rng.uniform() as f32;
                    for i in y0..(y0 + h).min(H) {
                        for j in x0..(x0 + w).min(W) {
                            img[i * W + j] = val;
                        }
                    }
                }
            }
            _ => {
                let p = 2 + rng.randint(4) as usize;
                let horiz = rng.randint(2) == 0;
                let hi = (0.4 + 0.6 * rng.uniform()) as f32;
                for i in 0..H {
                    for j in 0..W {
                        let k = if horiz { i } else { j };
                        img[i * W + j] = if (k / p) % 2 == 0 { hi } else { 0.1 };
                    }
                }
            }
        }
        Self::add_noise(&mut img, &mut rng, NOISE_STD);
        img
    }

    /// A full split of `n` samples (mirror of `data.make_split`).
    pub fn split(&self, stream: Stream, n: usize, kind: Kind) -> Split {
        let mut xs = Vec::with_capacity(n * IMG);
        let mut ys = Vec::with_capacity(n);
        for idx in 0..n {
            let seed = derive_seed(self.base_seed, stream as u64, idx as u64);
            match kind {
                Kind::Indomain => {
                    let (img, y) = self.sample_indomain(seed);
                    xs.extend_from_slice(&img);
                    ys.push(y);
                }
                Kind::Ambiguous => {
                    let (img, y) = self.sample_ambiguous(seed);
                    xs.extend_from_slice(&img);
                    ys.push(y);
                }
                Kind::Ood => {
                    let img = self.sample_ood(seed);
                    xs.extend_from_slice(&img);
                    ys.push(-1);
                }
            }
        }
        Split { x: Tensor::new(vec![n, IMG], xs).unwrap(), y: ys }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_distinct_and_bounded() {
        let protos: Vec<Vec<f32>> = (0..NUM_CLASSES).map(class_prototype).collect();
        for a in 0..NUM_CLASSES {
            assert!(protos[a].iter().all(|v| v.is_finite()));
            for b in a + 1..NUM_CLASSES {
                let d: f32 = protos[a]
                    .iter()
                    .zip(&protos[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum::<f32>()
                    / IMG as f32;
                assert!(d > 0.05, "prototypes {a}/{b} too similar: {d}");
            }
        }
    }

    #[test]
    fn samples_deterministic_and_in_range() {
        let g = Generator::new(2025);
        let (a, ya) = g.sample_indomain(42);
        let (b, yb) = g.sample_indomain(42);
        assert_eq!(a, b);
        assert_eq!(ya, yb);
        for seed in 0..20 {
            let (img, y) = g.sample_indomain(seed);
            assert!((0..10).contains(&y));
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let ood = g.sample_ood(seed);
            assert!(ood.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn ood_off_manifold() {
        let g = Generator::new(2025);
        let protos: Vec<Vec<f32>> = (0..NUM_CLASSES).map(class_prototype).collect();
        let dist = |img: &[f32]| -> f32 {
            protos
                .iter()
                .map(|p| {
                    img.iter().zip(p).map(|(a, b)| (a - b).abs()).sum::<f32>() / IMG as f32
                })
                .fold(f32::INFINITY, f32::min)
        };
        let mut d_in = 0.0;
        let mut d_ood = 0.0;
        for seed in 0..30 {
            d_in += dist(&g.sample_indomain(seed).0);
            d_ood += dist(&g.sample_ood(seed));
        }
        assert!(d_ood > 1.5 * d_in, "ood {d_ood} vs in {d_in}");
    }

    #[test]
    fn split_layout() {
        let g = Generator::new(7);
        let s = g.split(Stream::AmbiguousTest, 5, Kind::Ambiguous);
        assert_eq!(s.x.shape(), &[5, IMG]);
        assert_eq!(s.y.len(), 5);
    }
}
