//! Dataset substrate: the synthetic Dirty-MNIST substitute and loaders for
//! the python-exported splits.

pub mod synth;

use std::path::Path;

use crate::error::Result;
use crate::model::npz::Npz;
use crate::tensor::Tensor;

/// One evaluation split: images `[N, 784]` + labels (`-1` for OOD).
#[derive(Clone, Debug)]
pub struct Split {
    pub x: Tensor,
    pub y: Vec<i32>,
}

/// The synthetic Dirty-MNIST evaluation sets (as exported by
/// `python/compile/train.py` into `artifacts/data.npz`).
pub struct DirtyMnist {
    pub train: Split,
    pub test_mnist: Split,
    pub test_ambiguous: Split,
    pub test_ood: Split,
}

impl DirtyMnist {
    pub fn load(dir: &Path) -> Result<Self> {
        let npz = Npz::open(&dir.join("data.npz"))?;
        let split = |x: &str, y: &str| -> Result<Split> {
            Ok(Split { x: npz.tensor(x)?, y: npz.labels(y)? })
        };
        Ok(Self {
            train: split("train_x", "train_y")?,
            test_mnist: split("test_mnist_x", "test_mnist_y")?,
            test_ambiguous: split("test_ambiguous_x", "test_ambiguous_y")?,
            test_ood: split("test_ood_x", "test_ood_y")?,
        })
    }

    /// Generate in-process (no artifacts needed) with the Rust mirror of
    /// the python generator.
    pub fn generate(base_seed: u64, n_test: usize) -> Self {
        let g = synth::Generator::new(base_seed);
        DirtyMnist {
            train: g.split(synth::Stream::IndomainTrain, n_test, synth::Kind::Indomain),
            test_mnist: g.split(synth::Stream::IndomainTest, n_test, synth::Kind::Indomain),
            test_ambiguous: g.split(
                synth::Stream::AmbiguousTest,
                n_test,
                synth::Kind::Ambiguous,
            ),
            test_ood: g.split(synth::Stream::OodTest, n_test, synth::Kind::Ood),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_shapes() {
        let d = DirtyMnist::generate(2025, 16);
        assert_eq!(d.test_mnist.x.shape(), &[16, 784]);
        assert_eq!(d.test_ood.y, vec![-1; 16]);
        assert!(d.test_mnist.y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn loads_artifact_data_when_present() {
        let dir = crate::artifacts_dir();
        if !dir.join("data.npz").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let d = DirtyMnist::load(&dir).unwrap();
        assert_eq!(d.test_mnist.x.cols(), 784);
        assert_eq!(d.test_mnist.x.rows(), d.test_mnist.y.len());
    }
}
