//! `pfp-lint` — the project-invariant lint gate (`cargo run --bin
//! pfp-lint`, or `make lint`).
//!
//! Runs every rule in [`pfp::verify::lint`] over the repository and
//! exits nonzero on any finding; CI's `lint` job blocks on it. Pass a
//! repo root as the first argument to lint a different checkout.

use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(std::path::PathBuf::from)
        .unwrap_or_else(pfp::verify::lint::repo_root);
    let findings = match pfp::verify::lint::lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pfp-lint: cannot read tree at {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if findings.is_empty() {
        println!(
            "pfp-lint: clean ({} ok: SAFETY discipline, hot-path alloc ban, \
             version single-sourcing, bench gate)",
            root.display()
        );
        return ExitCode::SUCCESS;
    }
    for f in &findings {
        println!("{f}");
    }
    eprintln!("pfp-lint: {} violation(s)", findings.len());
    ExitCode::FAILURE
}
