//! # pfp — Accelerated Bayesian Neural Networks via a Single Probabilistic Forward Pass
//!
//! Reproduction of *"Accelerated Execution of Bayesian Neural Networks using
//! a Single Probabilistic Forward Pass and Code Generation"* (Klein et al.,
//! 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the PFP
//!   operator algebra: Gaussian-propagating dense/conv (Eq. 12),
//!   moment-matched ReLU (Eqs. 8/9) and Gaussian max-pool.
//! * **L2** — JAX models (`python/compile/model.py`) compose the kernels
//!   into MLP / LeNet-5 graphs and are AOT-lowered to HLO text.
//! * **L3** — this crate: the serving coordinator (router, dynamic
//!   batcher, uncertainty post-processing), the PJRT runtime that executes
//!   the AOT artifacts, and a **native PFP operator library** with an
//!   explicit schedule system + auto-tuner (the paper's TVM-operator
//!   analog, used by the Table 2-5 / Fig. 5-7 benchmarks).
//!
//! Python runs only at build time (`make artifacts`); the serving binary is
//! self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | dense f32 tensors + Gaussian (mu, var)/(mu, E\[x²\]) pairs |
//! | [`ops`] | PFP / deterministic / SVI operators with schedules |
//! | [`plan`] | static lowering: compiled per-batch-size plans + zero-alloc workspace |
//! | [`tuner`] | random + evolutionary schedule search (Meta-Scheduler analog) |
//! | [`model`] | architecture specs, weight store (NPZ), native executor |
//! | [`runtime`] | PJRT engine: HLO-text artifacts → compiled executables |
//! | [`coordinator`] | TCP server, router, dynamic batcher, metrics |
//! | [`registry`] | multi-model registry: mmap'd weights, hot swap, refcount drain |
//! | [`uncertainty`] | logit sampling (Eq. 11), entropy/SME/MI (Eqs. 1-3), AUROC |
//! | [`data`] | synthetic Dirty-MNIST (mirrors `python/compile/data.py`) |
//! | [`profiling`] | per-operator timing (Table 4 / Fig. 6) |
//! | [`util`] | offline substrate: RNG, JSON, stats, thread pool, prop tests |
//! | [`verify`] | static analysis: concurrency model checker + project lints |

pub mod coordinator;
pub mod data;
pub mod error;
pub mod model;
pub mod ops;
pub mod plan;
pub mod profiling;
pub mod registry;
pub mod runtime;
pub mod tensor;
pub mod tuner;
pub mod uncertainty;
pub mod util;
pub mod verify;

pub use error::{Error, Result};

/// Default location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$PFP_ARTIFACTS`, else `artifacts/`
/// relative to the current directory, else relative to the crate root.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PFP_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(ARTIFACTS_DIR);
    if cwd.exists() {
        return cwd;
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR)
}
